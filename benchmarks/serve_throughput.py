"""Serving throughput: seed-style per-slot reference engine vs the batched
SLR-native engine, across HPA keep-ratios.

The paper's deployment story only matters if the serving path is fast:
this benchmark drives BOTH engines over the same request trace at several
served capacities and emits ``BENCH_serve.json`` with tokens/sec (steady
state — a warmup pass absorbs compilation, which the per-slot engine pays
per shape anyway). The batched engine must clear >= 5x on the reduced
config; on real hardware the gap grows with slot count.

  PYTHONPATH=src python -m benchmarks.serve_throughput --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.core.hpa import hpa_keep_ratio
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, ReferenceEngine, ServingEngine

from .common import bench_arch, emit, salaad_cfg, train_salaad

KEEP_RATIOS = (1.0, 0.6, 0.3)


def _drive(engine, requests: int, max_new: int) -> float:
    """Submit a fixed trace, run to completion, return tokens/sec."""
    for i in range(requests):
        engine.submit([1 + (i % 7), 2, 3, 4], max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    assert len(done) == requests, (len(done), requests)
    return tokens / max(dt, 1e-9)


def run(
    steps: int = 30,
    requests: int = 8,
    max_new: int = 16,
    max_slots: int = 4,
    fmt: str = "factored",
    keep_ratios=KEEP_RATIOS,
) -> list[dict]:
    cfg = bench_arch()
    tr, state = train_salaad(cfg, steps=steps, scfg=salaad_cfg())
    ecfg = EngineConfig(max_slots=max_slots, max_len=64)

    rows = []
    for keep in keep_ratios:
        slr_c, rep = hpa_keep_ratio(state.slr, tr.blocks, keep, kappa=0.7)
        deployed = DeployedModel.build(cfg, state.params, slr_c, tr.blocks, fmt=fmt)
        dense = DeployedModel.build(cfg, state.params, slr_c, tr.blocks, fmt="dense")

        engines = {
            "reference_per_slot": ReferenceEngine(ModelBank.single(cfg, dense), ecfg),
            "batched_dense": ServingEngine(ModelBank.single(cfg, dense), ecfg),
        }
        if fmt != "dense":  # avoid key collision with the dense baseline
            engines[f"batched_{fmt}"] = ServingEngine(
                ModelBank.single(cfg, deployed), ecfg
            )
        row = {"keep": keep, "slr_params": rep["params_after"],
               "served_bytes": deployed.param_bytes()["total_bytes"]}
        for name, eng in engines.items():
            _drive(eng, max(requests // 2, 2), max_new)   # warmup: compile
            row[f"tok_per_s_{name}"] = round(_drive(eng, requests, max_new), 1)
        row["speedup_batched_vs_reference"] = round(
            row["tok_per_s_batched_dense"] / max(row["tok_per_s_reference_per_slot"], 1e-9), 2
        )
        rows.append(row)
    return rows


def main(steps: int = 30, out: str = "BENCH_serve.json", **kw):
    rows = run(steps=steps, **kw)
    Path(out).write_text(json.dumps(rows, indent=2))
    for r in rows:
        emit(
            f"serve/keep={r['keep']}", 0.0,
            f"ref={r['tok_per_s_reference_per_slot']};batched={r['tok_per_s_batched_dense']};"
            f"speedup={r['speedup_batched_vs_reference']}x",
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fmt", default="factored",
                    choices=("dense", "factored", "bsr", "fused"))
    ap.add_argument("--out", default="BENCH_serve.json")
    a = ap.parse_args()
    main(steps=10 if a.quick else 30, out=a.out, fmt=a.fmt,
         requests=4 if a.quick else 8, max_new=8 if a.quick else 16)
