"""App. I.2 (scaled down): ADMM update frequency K/J.

Expected trends: smaller K (more frequent stage-2) => lower reconstruction
error + stronger structure (lower rank/density), slightly worse task loss.
"""
from __future__ import annotations

import numpy as np

from repro.core.admm import slr_param_count

from .common import bench_arch, emit, eval_loss, ppl, salaad_cfg, train_salaad


def run(steps: int = 40, ks=(2, 5, 10)) -> list[dict]:
    cfg = bench_arch()
    rows = []
    for k in ks:
        tr, state = train_salaad(cfg, steps=steps, scfg=salaad_cfg(update_every=k))
        recon = [m["admm_recon_err"] for m in tr.metrics_log if "admm_recon_err" in m]
        rows.append(
            {
                "K": k,
                "ppl_x": ppl(eval_loss(state.params, cfg)),
                "final_recon": recon[-1] if recon else float("nan"),
                "slr_params": slr_param_count(state.slr, tr.blocks)["_total"],
            }
        )
    return rows


def main(steps: int = 40):
    for r in run(steps):
        emit(
            f"table10/K={r['K']}", 0.0,
            f"ppl_x={r['ppl_x']:.2f};recon={r['final_recon']:.3f};slr_params={r['slr_params']}",
        )


if __name__ == "__main__":
    main()
