"""Fig. 4 (scaled down): effect of the HPA allocation ratio kappa.

Paper claim: optimal kappa sits in a narrow band with kappa > 0.5 (budget
preferentially taken from the low-rank component), stable across budgets.
"""
from __future__ import annotations

from repro.core.admm import surrogate_params
from repro.core.hpa import hpa_keep_ratio

from .common import bench_arch, emit, eval_loss, ppl, train_salaad


def run(steps: int = 50, kappas=(0.0, 0.25, 0.5, 0.7, 0.9, 1.0), keeps=(0.7, 0.5)):
    cfg = bench_arch()
    tr, state = train_salaad(cfg, steps=steps)
    rows = []
    for keep in keeps:
        for kappa in kappas:
            slr_c, rep = hpa_keep_ratio(state.slr, tr.blocks, keep, kappa)
            params_c = surrogate_params(state.params, slr_c, tr.blocks)
            rows.append(
                {"keep": keep, "kappa": kappa, "ppl": ppl(eval_loss(params_c, cfg))}
            )
    return rows


def main(steps: int = 50):
    for r in run(steps):
        emit(f"fig4/keep={r['keep']}/kappa={r['kappa']}", 0.0, f"ppl={r['ppl']:.2f}")


if __name__ == "__main__":
    main()
