"""Shared helpers for the benchmark harness (scaled-down paper experiments).

Every benchmark runs the REAL pipeline (SALAAD trainer, baselines, HPA,
RPCA) on a small LLaMA-family config + synthetic-C4 so it completes on this
CPU container; the harness accepts --scale to grow toward the paper's sizes
on real hardware.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, slr_param_count, surrogate_params
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.models import model as model_lib
from repro.optim.adam import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig

SEQ = 32
BATCH = 8


def bench_arch(scale: str = "tiny"):
    cfg = get_arch("salaad_llama_60m")
    if scale == "tiny":
        cfg = cfg.reduced()
    return cfg


def make_data(cfg, seed=0):
    return SyntheticC4(DataConfig(cfg.vocab_size, SEQ, BATCH, seed=seed))


def salaad_cfg(update_every=5, rho_constant=0.5, **kw):
    """rho_constant=0.5 at toy scale keeps the penalty ~0.5% of the task loss
    (measured) — the same task/structure balance the paper's rho=5e-8 strikes
    at 350M. Stronger pulls visibly hurt the 60-step loss (see table3)."""
    return SalaadConfig(
        selection=SelectionConfig(min_dim=16),
        rho_constant=rho_constant,
        update_every=update_every,
        exact_svd=True,
        **kw,
    )


def train_salaad(cfg, steps=40, scfg=None, seed=0, lr=1e-3):
    scfg = scfg or salaad_cfg()
    from repro.optim.schedule import constant

    # constant LR to match train_baseline exactly — with the default
    # warmup-cosine the comparison measured the schedule, not SALAAD
    tcfg = TrainerConfig(
        total_steps=steps, salaad=scfg, adam=AdamConfig(lr=lr),
        schedule=constant, log_every=max(steps // 4, 1),
    )
    tr = Trainer(cfg, tcfg)
    state = tr.init(jax.random.PRNGKey(seed))
    state = tr.fit(state, make_data(cfg, seed))
    return tr, state


def eval_loss(params, cfg, seed=0, batches=4):
    """Held-out eval: SAME synthetic language (seed-0 bigram tables) but
    far-future steps never seen in training. (A different data seed is a
    different Markov language — an OOD eval that floors at unigram entropy
    and masks every method difference; found the hard way.)"""
    data = make_data(cfg, seed)
    tot = 0.0
    for i in range(batches):
        loss, _ = model_lib.loss_fn(params, data.batch(50_000 + i), cfg)
        tot += float(loss)
    return tot / batches


def ppl(loss: float) -> float:
    return float(np.exp(min(loss, 20.0)))


def param_count(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def timed(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def engine_provenance(engine) -> dict:
    """Engine provenance recorded inside every BENCH_*.json payload — a thin
    delegate to :func:`repro.serving.telemetry.engine_provenance`. The schema
    is generated CENTRALLY from the full ``EngineConfig`` dataclass plus the
    telemetry-registry snapshot, so every benchmark payload carries identical
    provenance keys and a new config field or counter shows up everywhere at
    once instead of per-script."""
    from repro.serving.telemetry import engine_provenance as _provenance

    return _provenance(engine)
