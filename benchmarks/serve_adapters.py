"""Multi-tenant adapter serving: one AdapterBank engine vs a per-tenant fleet.

Trains the reduced 60m config with the real SALAAD trainer, materializes N
tenant adapters (HPA views at spread keep budgets, each ``adapterize``-d onto
ONE shared fused-format base), and drives the SAME mixed-tenant Poisson
trace through two deployments at EQUAL aggregate KV budget:

1. **multi_tenant** — one ``PagedServingEngine`` over an ``AdapterBank``:
   every decode tick batches slots running DIFFERENT adapters through one
   ``slr_matmul_multi`` call (the adapter gather rides the kernel's
   scalar-prefetched index maps), so tenant diversity costs no batch
   occupancy. The whole trace shares one ``num_blocks`` page pool.
2. **per_tenant_fleet** — the status quo: one single-tenant engine per
   adapter, each with ``1/N`` of the slots and ``1/N`` of the page pool
   (equal aggregate HBM), round-robin ticked on the same host. Each tenant's
   requests can only batch with themselves, so the fleet decodes at ~batch-1
   per engine while the multi-tenant engine decodes at full occupancy.

Reported per arm: aggregate tok/s, p50/p99 TTFT (scheduled-arrival basis via
backdated ``submitted_at``), decode batch occupancy, and for the bank arm
the adapter-pool report (residency, swaps) and the zero-retrace check across
adapter switches. Results → ``BENCH_adapters.json``.

  PYTHONPATH=src python -m benchmarks.serve_adapters --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.hpa import hpa_keep_ratio
from repro.serving.adapters import AdapterBank, adapterize
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineConfig,
    PagedServingEngine,
    decode_emitted_tokens,
)
from repro.serving.telemetry import request_ttft

from .common import bench_arch, emit, engine_provenance, salaad_cfg, train_salaad


def build_trace(n: int, rate_hz: float, vocab: int, max_new: int,
                n_adapters: int, seed: int):
    """Poisson arrivals with a uniform tenant mix:
    [(arrival_offset_s, prompt, adapter_id, max_new), ...]."""
    rng = np.random.RandomState(seed)
    offsets = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    return [
        (float(offsets[i]),
         rng.randint(0, vocab, size=rng.randint(4, 8)).tolist(),
         int(rng.randint(0, n_adapters)),
         max_new)
        for i in range(n)
    ]


def build_tenants(cfg, state, blocks, n: int, kappa: float = 0.7):
    """One shared fused base + n adapter views at spread HPA budgets."""
    slr_c, _ = hpa_keep_ratio(state.slr, blocks, 1.0, kappa)
    base = DeployedModel.build(cfg, state.params, slr_c, blocks, fmt="fused",
                               bsr_block=32)
    tenants = []
    for keep in np.linspace(1.0, 0.4, n):
        slr_k, _ = hpa_keep_ratio(state.slr, blocks, float(keep), kappa)
        tenants.append(adapterize(base, DeployedModel.build(
            cfg, state.params, slr_k, blocks, fmt="fused", bsr_block=32)))
    return base, tenants


def _row(done, dt: float, decode_calls: int) -> dict:
    tokens = sum(len(r.out_tokens) for r in done)
    ttft = [request_ttft(r) * 1e3 for r in done if r.first_token_at]
    return {
        "requests": len(done),
        "tokens": tokens,
        "wall_s": round(dt, 3),
        "tok_per_s": round(tokens / max(dt, 1e-9), 1),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)), 1),
        "ttft_p99_ms": round(float(np.percentile(ttft, 99)), 1),
        "tokens_per_step": round(
            decode_emitted_tokens(done) / max(decode_calls, 1), 2
        ),
    }


def drive_bank(engine, trace) -> dict:
    """Open loop against the one multi-tenant engine: arrivals land on the
    clock with their tenant id, submits backdated to the scheduled arrival."""
    done, i = [], 0
    calls0 = engine.decode_calls
    t0 = time.monotonic()
    while i < len(trace) or engine.has_work:
        now = time.monotonic() - t0
        while i < len(trace) and trace[i][0] <= now:
            off, prompt, aid, max_new = trace[i]
            engine.submit(prompt, max_new_tokens=max_new, adapter=aid,
                          submitted_at=t0 + off)
            i += 1
        if engine.has_work:
            done.extend(engine.step())
        elif i < len(trace):
            time.sleep(max(trace[i][0] - (time.monotonic() - t0), 0.0))
    dt = time.monotonic() - t0
    return _row(done, dt, engine.decode_calls - calls0)


def drive_fleet(engines: list, trace) -> dict:
    """Open loop against one engine PER tenant, round-robin ticked: each
    arrival goes to its tenant's engine, and every engine with work gets one
    ``step()`` per scheduler pass — the one-host fleet deployment."""
    done, i = [], 0
    calls0 = sum(e.decode_calls for e in engines)
    t0 = time.monotonic()
    while i < len(trace) or any(e.has_work for e in engines):
        now = time.monotonic() - t0
        while i < len(trace) and trace[i][0] <= now:
            off, prompt, aid, max_new = trace[i]
            engines[aid].submit(prompt, max_new_tokens=max_new,
                                submitted_at=t0 + off)
            i += 1
        busy = [e for e in engines if e.has_work]
        for e in busy:
            done.extend(e.step())
        if not busy and i < len(trace):
            time.sleep(max(trace[i][0] - (time.monotonic() - t0), 0.0))
    dt = time.monotonic() - t0
    return _row(done, dt, sum(e.decode_calls for e in engines) - calls0)


def run(
    steps: int = 60,
    n_adapters: int = 8,
    requests: int = 32,
    rate_hz: float = 200.0,
    max_new: int = 12,
    max_slots: int = 8,
    max_len: int = 64,
    block_size: int = 8,
    seed: int = 0,
) -> dict:
    cfg = bench_arch()
    tr, state = train_salaad(cfg, steps=steps, scfg=salaad_cfg(), seed=seed)
    base, tenants = build_tenants(cfg, state, tr.blocks, n_adapters)
    trace = build_trace(requests, rate_hz, cfg.vocab_size, max_new,
                        n_adapters, seed)
    # equal aggregate KV budget: the bank arm pools it, the fleet splits it
    num_blocks = max_slots * max_len // block_size

    bank = AdapterBank(base, tenants,
                       names=[f"tenant{i}" for i in range(n_adapters)])
    eng = PagedServingEngine(bank, EngineConfig(
        adapters=True, max_slots=max_slots, max_len=max_len,
        block_size=block_size, num_blocks=num_blocks))
    for aid in range(n_adapters):              # warm every tenant's path
        eng.submit([1 + aid, 2, 3], max_new_tokens=2, adapter=aid)
    eng.run()
    retraces0 = eng.metrics.retraces()
    multi = drive_bank(eng, trace)
    multi["adapter_pool"] = bank.adapter_report()
    multi["jit_retraces_during_run"] = eng.metrics.retraces() - retraces0
    multi["engine_config"] = engine_provenance(eng)
    assert multi["jit_retraces_during_run"] == 0, multi

    fleet = []
    per_slots = max(max_slots // n_adapters, 1)
    per_blocks = max(num_blocks // n_adapters, 2)
    for t in tenants:
        e = PagedServingEngine(ModelBank.single(cfg, t), EngineConfig(
            max_slots=per_slots, max_len=max_len, block_size=block_size,
            num_blocks=per_blocks))
        e.submit([1, 2, 3], max_new_tokens=2)  # warm: compile outside window
        e.run()
        fleet.append(e)
    single = drive_fleet(fleet, trace)
    single["engines"] = n_adapters
    single["slots_per_engine"] = per_slots
    single["blocks_per_engine"] = per_blocks
    single["engine_config"] = engine_provenance(fleet[0])

    return {
        "n_adapters": n_adapters,
        "kv_budget_tokens": num_blocks * block_size,
        "multi_tenant": multi,
        "per_tenant_fleet": single,
        "summary": {
            "tok_per_s_speedup": round(
                multi["tok_per_s"] / max(single["tok_per_s"], 1e-9), 2
            ),
            "ttft_p99_speedup": round(
                single["ttft_p99_ms"] / max(multi["ttft_p99_ms"], 1e-9), 2
            ),
            "batch_occupancy_multi": multi["tokens_per_step"],
            "batch_occupancy_fleet": single["tokens_per_step"],
        },
        "train_steps": steps,
    }


def main(out: str = "BENCH_adapters.json", **kw):
    rows = run(**kw)
    Path(out).write_text(json.dumps(rows, indent=2))
    s = rows["summary"]
    emit(
        "serve_adapters", 0.0,
        f"{rows['n_adapters']} tenants: bank {rows['multi_tenant']['tok_per_s']}"
        f" tok/s vs fleet {rows['per_tenant_fleet']['tok_per_s']} tok/s "
        f"(x{s['tok_per_s_speedup']}); p99 TTFT x{s['ttft_p99_speedup']}; "
        f"occupancy {s['batch_occupancy_fleet']} -> "
        f"{s['batch_occupancy_multi']} tok/step",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--adapters", type=int, default=8)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate-hz", type=float, default=None)
    ap.add_argument("--out", default="BENCH_adapters.json")
    a = ap.parse_args()
    main(out=a.out, steps=10 if a.quick else 60, n_adapters=a.adapters,
         requests=a.requests or (16 if a.quick else 32),
         rate_hz=a.rate_hz or 200.0,
         max_new=8 if a.quick else 12)
