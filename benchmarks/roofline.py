"""Roofline table assembled from the dry-run sweep (results/dryrun/*.json).

Reads the per-cell compiled-artifact records and prints EXPERIMENTS.md's
§Roofline table: the three terms, the dominant bottleneck, useful-FLOPs
ratio, and per-device memory fit.

A second, MEASURED section reads BENCH_fused.json (``make bench-fused``):
per-serving-kernel HBM bytes and achieved vs roofline FLOP/s for the
separate-call SLR path vs the fused one-pass kernel at decode/prefill
shapes.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
BENCH_FUSED = os.path.join(os.path.dirname(__file__), "..", "BENCH_fused.json")
HBM_LIMIT = 16e9  # v5e


def load_records(pattern: str = "*") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"{pattern}.json"))):
        with open(path) as f:
            data = json.load(f)
        recs.extend(data.get("records", []))
    return recs


def fmt_row(r: dict) -> str:
    fits = "Y" if (r.get("peak_memory_per_device") or 0) < HBM_LIMIT else "N"
    return (
        f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<9} "
        f"{r['compute_s']:>9.4f} {r['memory_s']:>9.4f} {r['collective_s']:>9.4f} "
        f"{r['dominant']:<10} {r['useful_flops_ratio']:>6.3f} "
        f"{(r.get('peak_memory_per_device') or 0)/1e9:>7.2f} {fits}"
    )


def serving_kernels_section(path: str = BENCH_FUSED) -> None:
    """Measured serving-kernel roofline from the fused-SLR benchmark."""
    if not os.path.exists(path):
        print("roofline/serving-kernels/no-data,0.0,run make bench-fused first")
        return
    with open(path) as f:
        bench = json.load(f)
    backend = bench.get("backend", "?")
    print(f"\nserving kernels (measured on backend={backend}, "
          f"roofline at nominal v5e)")
    print(f"{'kernel':<22} {'HBM bytes':>10} {'meas us':>8} "
          f"{'achieved F/s':>12} {'roofline F/s':>12} {'of roof':>8}")
    for kr in bench.get("kernels", []):
        for p in ("separate", "fused"):
            ach = kr["achieved_flops_per_s"][p]
            roof = kr["roofline_flops_per_s_at_v5e"][p]
            name = f"slr/{kr['phase']}/{p}"
            print(
                f"{name:<22} {kr['hbm_bytes'][p]:>10} "
                f"{kr['measured_us'][p]:>8} {ach:>12.3g} {roof:>12.3g} "
                f"{ach / max(roof, 1):>7.1%}"
            )
            print(
                f"roofline/serving/{kr['phase']}/{p},{kr['measured_us'][p]},"
                f"hbm_bytes={kr['hbm_bytes'][p]};achieved={ach:.3g};"
                f"roofline={roof:.3g}"
            )


def main():
    recs = load_records()
    if not recs:
        print("roofline/no-data,0.0,run scripts_sweep.sh first")
        serving_kernels_section()
        return
    header = (
        f"{'arch':<18} {'shape':<12} {'mesh':<9} "
        f"{'compute_s':>9} {'memory_s':>9} {'collect_s':>9} {'dominant':<10} "
        f"{'useful':>6} {'peakGB':>7} fit"
    )
    print(header)
    for r in recs:
        print(fmt_row(r))
    # CSV lines for the harness contract
    for r in recs:
        print(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
            f"compute={r['compute_s']:.4f};memory={r['memory_s']:.4f};"
            f"collective={r['collective_s']:.4f};dominant={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.3f}"
        )
    serving_kernels_section()


if __name__ == "__main__":
    main()
