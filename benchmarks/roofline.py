"""Roofline table assembled from the dry-run sweep (results/dryrun/*.json).

Reads the per-cell compiled-artifact records and prints EXPERIMENTS.md's
§Roofline table: the three terms, the dominant bottleneck, useful-FLOPs
ratio, and per-device memory fit.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
HBM_LIMIT = 16e9  # v5e


def load_records(pattern: str = "*") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"{pattern}.json"))):
        with open(path) as f:
            data = json.load(f)
        recs.extend(data.get("records", []))
    return recs


def fmt_row(r: dict) -> str:
    fits = "Y" if (r.get("peak_memory_per_device") or 0) < HBM_LIMIT else "N"
    return (
        f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<9} "
        f"{r['compute_s']:>9.4f} {r['memory_s']:>9.4f} {r['collective_s']:>9.4f} "
        f"{r['dominant']:<10} {r['useful_flops_ratio']:>6.3f} "
        f"{(r.get('peak_memory_per_device') or 0)/1e9:>7.2f} {fits}"
    )


def main():
    recs = load_records()
    if not recs:
        print("roofline/no-data,0.0,run scripts_sweep.sh first")
        return
    header = (
        f"{'arch':<18} {'shape':<12} {'mesh':<9} "
        f"{'compute_s':>9} {'memory_s':>9} {'collect_s':>9} {'dominant':<10} "
        f"{'useful':>6} {'peakGB':>7} fit"
    )
    print(header)
    for r in recs:
        print(fmt_row(r))
    # CSV lines for the harness contract
    for r in recs:
        print(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
            f"compute={r['compute_s']:.4f};memory={r['memory_s']:.4f};"
            f"collective={r['collective_s']:.4f};dominant={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.3f}"
        )


if __name__ == "__main__":
    main()
