"""Telemetry-overhead benchmark: the zero-cost-on-device-path claim, measured.

Three arms drive the SAME paged-engine workload (chunked prefill + prefix
cache — the hook-densest scheduler path) over the SAME closed-loop trace:

``telemetry_off``
    A ``NullTelemetry`` installed — every hook is a no-op, the timing
    context managers never read the clock.
``telemetry_on``
    Full registry accounting (counters, gauges, histograms, per-program
    wall clocks, the retrace detector).
``traced``
    Telemetry on PLUS a live ``RequestTracer`` recording per-request spans.

All three arms run on ONE engine instance, swapping only the installed
telemetry object between passes. A null experiment on this box showed three
bit-identical engines differing by up to ~6% steady-state tok/s purely from
construction order (jit code / allocator memory layout), so separate
per-arm engines cannot resolve a sub-2% effect; with one engine the jitted
programs, page pool, and caches are shared and the only variable left is
the hooks themselves. Each arm's cost is the mean of its 3 smallest wall
times over ``passes`` rotated rounds (a damped timeit estimator —
everything above the floor is scheduler noise).

The overhead run uses the FULL 60m config, not ``.reduced()``: the claim
is about serving overhead, so the hook cost must be weighed against a
realistic per-tick device workload (~ms), not the test-sized model's
sub-ms ticks where any fixed host cost is relatively inflated. ``--quick``
keeps the reduced model for CI smoke — there the bitwise-equality check is
the point and the overhead column is indicative only.

The benchmark asserts the greedy token streams are BITWISE IDENTICAL across
arms (telemetry is host-side only — it must never touch the device path),
then reports each instrumented arm's tok/s delta against the off arm. The
PR target is < 2% telemetry overhead; the measured delta and the verdict
ride in ``BENCH_obs.json`` along with the metric/event volume that bought
it.

  PYTHONPATH=src python -m benchmarks.serve_obs --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import model as model_lib
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, PagedServingEngine
from repro.serving.telemetry import NullTelemetry

from .common import emit, engine_provenance


def _trace(n: int, vocab: int, max_new: int, shared_len: int, seed: int):
    """Closed-loop prompt list: a shared system prefix + unique tails, so
    the prefix-cache hooks (lookup/hit/CoW) actually fire."""
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, vocab, size=shared_len).tolist()
    return [
        (shared + rng.randint(0, vocab, size=int(rng.randint(3, 8))).tolist(),
         max_new)
        for _ in range(n)
    ]


def _drive(engine, trace) -> tuple[float, list[list[int]]]:
    """Submit everything, run to completion; returns (wall seconds, streams
    in submission order) — the streams are the bitwise-equality evidence."""
    for prompt, max_new in trace:
        engine.submit(list(prompt), max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    assert len(done) == len(trace), (len(done), len(trace))
    streams = [r.out_tokens for r in sorted(done, key=lambda r: r.uid)]
    return dt, streams


def run(
    requests: int = 8,
    max_new: int = 16,
    shared_len: int = 32,
    max_slots: int = 8,
    max_len: int = 96,
    block_size: int = 16,
    num_blocks: int = 64,
    prefill_chunk: int = 16,
    passes: int = 12,
    reduced: bool = False,
    seed: int = 0,
) -> dict:
    """Decode-dominated workload (long generations across many slots), so
    the overhead figure reflects the per-tick hook cost RELATIVE to a tick's
    device work — the claim the PR makes — rather than the pathological
    all-host toy regime."""
    cfg = get_arch("salaad_llama_60m")
    if reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
    bank = ModelBank.single(cfg, params)
    trace = _trace(requests, cfg.vocab_size, max_new, shared_len, seed)

    eng = PagedServingEngine(bank, EngineConfig(
        max_slots=max_slots, max_len=max_len, block_size=block_size,
        num_blocks=num_blocks, prefill_chunk=prefill_chunk,
        prefix_cache=True,
    ))
    on_tel = eng.metrics
    off_tel = NullTelemetry(type(eng).__name__)
    tracer = eng.start_trace()

    # ONE engine, three telemetry configurations swapped in between passes
    def install(arm: str):
        tel = off_tel if arm == "telemetry_off" else on_tel
        tr = tracer if arm == "traced" else None
        eng.metrics, eng.tracer, tel.tracer = tel, tr, tr

    _drive(eng, trace)                         # warmup (traced: every path)

    arms = ("telemetry_off", "telemetry_on", "traced")
    walls: dict[str, list[float]] = {a: [] for a in arms}
    streams: dict[str, list] = {}
    for p in range(passes):
        # rotate the arm order every round so slow-drift box noise (thermal,
        # cache pressure) never lands on one arm systematically
        for arm in arms[p % len(arms):] + arms[:p % len(arms)]:
            install(arm)
            dt, out = _drive(eng, trace)
            walls[arm].append(dt)
            streams[arm] = out
    install("telemetry_on")                    # leave a live registry behind

    identical = (streams["telemetry_off"] == streams["telemetry_on"]
                 == streams["traced"])
    assert identical, "telemetry/tracing changed the token stream"

    def floor(arm: str) -> float:
        """Mean of the 3 smallest walls — a damped minimum."""
        return sum(sorted(walls[arm])[:3]) / 3

    tokens = sum(len(s) for s in streams["telemetry_off"])
    rows: dict = {
        arm: {"tok_per_s": round(tokens / floor(arm), 1)}
        for arm in arms
    }
    snap = eng.stats_snapshot()
    rows["telemetry_on"]["jit_retraces"] = snap["jit_retraces"]
    rows["telemetry_on"]["metric_series"] = sum(
        len(m["values"]) if isinstance(m["values"], dict) else 1
        for m in snap["metrics"].values()
    )
    rows["traced"]["trace_events"] = len(tracer.events)
    rows["engine_config"] = engine_provenance(eng)

    def overhead(arm: str) -> float:
        base = floor("telemetry_off")
        return round(100 * (floor(arm) - base) / base, 2)

    rows["summary"] = {
        "streams_bitwise_identical": identical,
        "tok_per_s_off": rows["telemetry_off"]["tok_per_s"],
        "tok_per_s_on": rows["telemetry_on"]["tok_per_s"],
        "tok_per_s_traced": rows["traced"]["tok_per_s"],
        "telemetry_overhead_pct": overhead("telemetry_on"),
        "trace_overhead_pct": overhead("traced"),
        "overhead_under_2pct": overhead("telemetry_on") < 2.0,
        "passes": passes,
        "reduced_model": reduced,
    }
    return rows


def main(out: str = "BENCH_obs.json", **kw):
    rows = run(**kw)
    Path(out).write_text(json.dumps(rows, indent=2))
    s = rows["summary"]
    emit(
        "serve_obs", 0.0,
        f"tok/s off={s['tok_per_s_off']} on={s['tok_per_s_on']} "
        f"traced={s['tok_per_s_traced']} "
        f"(overhead {s['telemetry_overhead_pct']}% / "
        f"{s['trace_overhead_pct']}%); streams identical={s['streams_bitwise_identical']}",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced model, fewer passes: bitwise-equality "
                         "smoke; the overhead column is indicative only")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--passes", type=int, default=None)
    ap.add_argument("--out", default="BENCH_obs.json")
    a = ap.parse_args()
    main(out=a.out,
         requests=a.requests or 8,
         max_new=16,
         reduced=a.quick,
         passes=a.passes or (6 if a.quick else 12))
