"""Prefix-sharing harness: radix prompt cache on vs off at EQUAL KV budget.

Three workloads where prompts repeat structure, against the same paged
engine config with ``prefix_cache`` as the only difference:

``shared_prefix``
    A burst of requests carrying one long common prefix (a system prompt)
    plus short unique suffixes. Cache-off prefills the full prompt per
    request; cache-on attaches the prefix pages read-only from the radix
    index and prefills only the suffix — the headline is p99 TTFT of the
    CACHE-HIT requests (everything after the first, which prefills cold and
    publishes).

``multi_turn``
    One conversation re-submitted turn after turn (prior prompt + generated
    reply + new user tokens). Every turn's prompt extends the last turn's
    published pages, so the hit rate climbs to ~all-but-the-tail and per-turn
    prefill work stays flat instead of growing with the transcript.

``evict_resume``
    A decode-phase request is evicted under pressure and resumes with pool
    slack. Cache-off resumes by re-prefilling prompt + generated tokens from
    scratch; cache-on reattaches the pages its eviction published
    (``reattached_pages`` > 0) and re-prefills only the final partial block —
    measured as the widest inter-token gap (the eviction gap) per mode.

Counters (hits, hit tokens, CoW copies, reattached pages) live in the
engine's telemetry registry (``serve_prefix_events_total``) and ride in each
row's ``engine_config`` provenance via ``engine_provenance``; TTFT
percentiles read from the ``serve_ttft_seconds`` registry histogram (reset
after warmup so the measured burst is clean). Results merge into
``BENCH_prefix.json``.

  PYTHONPATH=src python -m benchmarks.serve_prefix --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import model as model_lib
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, PagedServingEngine
from repro.serving.telemetry import request_itls, request_ttft

from .common import emit, engine_provenance


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else float("nan")


def _bank(seed: int = 0):
    cfg = get_arch("salaad_llama_60m").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, ModelBank.single(cfg, params)


def _engine(bank, prefix_cache: bool, **kw):
    return PagedServingEngine(bank, EngineConfig(prefix_cache=prefix_cache, **kw))


def _drain(engine):
    done = []
    while engine.has_work:
        done.extend(engine.step())
    return sorted(done, key=lambda r: r.uid)


def _prompts(prefix_len: int, n: int, suffix_len: int, vocab: int, seed: int):
    rng = np.random.RandomState(seed)
    prefix = rng.randint(0, vocab, size=prefix_len).tolist()
    return [prefix + rng.randint(0, vocab, size=suffix_len).tolist()
            for _ in range(n)]


# ---------------------------------------------------------- shared prefix ---


def run_shared_prefix(
    requests: int = 12,
    prefix_len: int = 256,
    suffix_len: int = 6,
    max_new: int = 4,
    max_slots: int = 4,
    max_len: int = 288,
    block_size: int = 16,
    num_blocks: int = 96,
    prefill_chunk: int = 32,
    seed: int = 0,
) -> dict:
    cfg, bank = _bank(seed)
    prompts = _prompts(prefix_len, requests, suffix_len, cfg.vocab_size, seed)
    ecfg = dict(max_slots=max_slots, max_len=max_len, block_size=block_size,
                num_blocks=num_blocks, prefill_chunk=prefill_chunk)
    rows = {}
    for name, pc in (("cache_off", False), ("cache_on", True)):
        eng = _engine(bank, pc, **ecfg)
        # warm compilation AND (cache-on) publish the shared prefix, exactly
        # like a production system prompt served once before the burst; the
        # second submit is already a HIT, so the hit-admission path (suffix
        # chunk widths, the length-reset scatter) compiles here too
        for _ in range(2):
            eng.submit(prompts[0], max_new_tokens=max_new)
            _drain(eng)
        hits0 = getattr(eng, "prefix_hits", 0)
        eng.metrics.reset_histograms()         # measured burst only
        t0 = time.monotonic()
        for p in prompts:
            # the burst "arrives" at t0: backdate submitted_at so the
            # registry TTFT histogram shares the burst-start basis
            eng.submit(p, max_new_tokens=max_new, submitted_at=t0)
        done = _drain(eng)
        dt = time.monotonic() - t0
        tel = eng.metrics
        # cache-hit requests = the measured burst (the cold publish ran in
        # warmup); keep the same slice for cache_off so rows compare 1:1
        rows[name] = {
            "requests": len(done),
            "wall_s": round(dt, 3),
            "tokens": sum(len(r.out_tokens) for r in done),
            "ttft_p50_ms": round(tel.ttft.percentile(50, tel.engine) * 1e3, 1),
            "ttft_p99_ms": round(tel.ttft.percentile(99, tel.engine) * 1e3, 1),
            "burst_hits": getattr(eng, "prefix_hits", 0) - hits0,
            "engine_config": engine_provenance(eng),
        }
    off, on = rows["cache_off"], rows["cache_on"]
    rows["summary"] = {
        "prefix_len": prefix_len,
        "equal_kv_budget_tokens": num_blocks * block_size,
        "hit_ttft_p99_speedup": round(
            off["ttft_p99_ms"] / max(on["ttft_p99_ms"], 1e-9), 2
        ),
        "hit_ttft_p50_speedup": round(
            off["ttft_p50_ms"] / max(on["ttft_p50_ms"], 1e-9), 2
        ),
        "wall_speedup": round(off["wall_s"] / max(on["wall_s"], 1e-9), 2),
    }
    return rows


# ------------------------------------------------------------- multi-turn ---


def run_multi_turn(
    turns: int = 6,
    turn_len: int = 16,
    max_new: int = 8,
    max_slots: int = 2,
    max_len: int = 256,
    block_size: int = 16,
    prefill_chunk: int = 32,
    seed: int = 1,
) -> dict:
    """One growing conversation: turn t submits the full transcript so far
    plus ``turn_len`` fresh user tokens."""
    cfg, bank = _bank(seed)
    rng = np.random.RandomState(seed)
    rows = {}
    for name, pc in (("cache_off", False), ("cache_on", True)):
        eng = _engine(bank, pc, max_slots=max_slots, max_len=max_len,
                      block_size=block_size, prefill_chunk=prefill_chunk)
        warm = list(range(4, 44))                    # absorb compilation; the
        for _ in range(2):                           # repeat warms the hit-
            eng.submit(warm, max_new_tokens=2)       # admission path too
            _drain(eng)
        transcript = []
        per_turn = []
        turn_rng = np.random.RandomState(seed + 1)   # same turns both modes
        for t in range(turns):
            transcript = transcript + turn_rng.randint(
                0, cfg.vocab_size, size=turn_len
            ).tolist()
            hit0 = getattr(eng, "prefix_hit_tokens", 0)
            eng.submit(list(transcript), max_new_tokens=max_new)
            (req,) = _drain(eng)
            per_turn.append({
                "turn": t,
                "prompt_len": len(transcript),
                "ttft_ms": round(request_ttft(req) * 1e3, 1),
                "hit_tokens": getattr(eng, "prefix_hit_tokens", 0) - hit0,
            })
            transcript += req.out_tokens
        rows[name] = {
            "turns": per_turn,
            "last_turn_ttft_ms": per_turn[-1]["ttft_ms"],
            "engine_config": engine_provenance(eng),
        }
    off, on = rows["cache_off"], rows["cache_on"]
    last = on["turns"][-1]
    rows["summary"] = {
        "turns": turns,
        "last_turn_prompt_len": last["prompt_len"],
        "last_turn_hit_tokens": last["hit_tokens"],
        "last_turn_hit_rate": round(
            last["hit_tokens"] / max(last["prompt_len"], 1), 3
        ),
        "last_turn_ttft_speedup": round(
            off["last_turn_ttft_ms"] / max(on["last_turn_ttft_ms"], 1e-9), 2
        ),
    }
    return rows


# ----------------------------------------------------------- evict/resume ---


def run_evict_resume(
    prompt_len: int = 96,
    max_new: int = 16,
    max_slots: int = 2,
    max_len: int = 160,
    block_size: int = 16,
    num_blocks: int = 32,
    prefill_chunk: int = 32,
    evict_tick: int = 6,
    seed: int = 2,
) -> dict:
    """Evict a decode-phase long request at a fixed tick (the pressure path's
    decision, made deterministic so both modes see the identical schedule),
    with enough pool slack for it to resume immediately. The resume cost is
    the request's widest inter-token gap."""
    cfg, bank = _bank(seed)
    rng = np.random.RandomState(seed)
    prompt = rng.randint(0, cfg.vocab_size, size=prompt_len).tolist()
    rows = {}
    for name, pc in (("cache_off", False), ("cache_on", True)):
        eng = _engine(bank, pc, max_slots=max_slots, max_len=max_len,
                      block_size=block_size, num_blocks=num_blocks,
                      prefill_chunk=prefill_chunk)
        for _ in range(2):                           # absorb compilation (the
            eng.submit(prompt, max_new_tokens=2)     # repeat warms the hit-
            _drain(eng)                              # admission path) and
        #                                              publish the prompt
        eng.submit(prompt, max_new_tokens=max_new)
        tick, done = 0, []
        while eng.has_work:
            tick += 1
            if tick == evict_tick and eng._active:
                eng._evict(next(iter(eng._active)), [])
            done.extend(eng.step())
        (req,) = sorted(done, key=lambda r: r.uid)
        gaps = request_itls(req)
        rows[name] = {
            "out_tokens": len(req.out_tokens),
            "evictions": req.evictions,
            "resume_gap_ms": round(max(gaps) * 1e3, 1) if gaps else None,
            "median_gap_ms": round(percentile(gaps, 50) * 1e3, 1),
            "engine_config": engine_provenance(eng),
        }
        if pc:
            rows[name]["reattached_pages"] = eng.reattached_pages
    off, on = rows["cache_off"], rows["cache_on"]
    rows["summary"] = {
        "prompt_len": prompt_len,
        "reattached_pages": on["reattached_pages"],
        "resume_gap_speedup": round(
            (off["resume_gap_ms"] or 0.0) / max(on["resume_gap_ms"] or 1e-9,
                                                1e-9), 2
        ),
    }
    return rows


# ----------------------------------------------------------------- driver ---


def _merge_out(out: str, key: str, rows: dict):
    path = Path(out)
    payload = {}
    if path.exists():
        try:
            payload = json.loads(path.read_text())
        except ValueError:
            payload = {}
    payload[key] = rows
    path.write_text(json.dumps(payload, indent=2))


def main(out: str = "BENCH_prefix.json", quick: bool = False) -> dict:
    shared = run_shared_prefix(requests=8 if quick else 12)
    _merge_out(out, "shared_prefix", shared)
    s = shared["summary"]
    emit(
        "serve_prefix_shared", 0.0,
        f"hit p99 TTFT off={shared['cache_off']['ttft_p99_ms']}ms "
        f"on={shared['cache_on']['ttft_p99_ms']}ms "
        f"(x{s['hit_ttft_p99_speedup']}) prefix={s['prefix_len']}tok",
    )

    turns = run_multi_turn(turns=4 if quick else 6)
    _merge_out(out, "multi_turn", turns)
    s = turns["summary"]
    emit(
        "serve_prefix_turns", 0.0,
        f"turn {s['turns']} hit_rate={s['last_turn_hit_rate']} "
        f"ttft x{s['last_turn_ttft_speedup']} at "
        f"prompt={s['last_turn_prompt_len']}tok",
    )

    ev = run_evict_resume()
    _merge_out(out, "evict_resume", ev)
    s = ev["summary"]
    emit(
        "serve_prefix_resume", 0.0,
        f"resume gap off={ev['cache_off']['resume_gap_ms']}ms "
        f"on={ev['cache_on']['resume_gap_ms']}ms "
        f"(x{s['resume_gap_speedup']}), reattached={s['reattached_pages']}",
    )
    return {"shared_prefix": shared, "multi_turn": turns, "evict_resume": ev}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_prefix.json")
    a = ap.parse_args()
    main(out=a.out, quick=a.quick)
