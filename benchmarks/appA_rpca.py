"""App. A (scaled down): post-hoc RPCA is weak on standard-trained weights,
but recovers latent SLR structure from SALAAD-trained surrogates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prox import density, effective_rank_ratio
from repro.core.rpca import rpca
from repro.models import model as model_lib
from repro.optim.adam import AdamConfig, adam_update, init_adam

from .common import bench_arch, emit, make_data, train_salaad


def rpca_stats(weight) -> tuple[float, float]:
    l, s, _ = rpca(jnp.asarray(weight, jnp.float32), n_iter=40)
    return float(effective_rank_ratio(l)), float(density(s, eps=1e-6))


def run(steps: int = 40) -> dict:
    cfg = bench_arch()
    data = make_data(cfg)

    # standard-trained weights
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)

    @jax.jit
    def step_fn(p, o, batch):
        (l, _), g = jax.value_and_grad(
            lambda pp: model_lib.loss_fn(pp, batch, cfg), has_aux=True
        )(p)
        return (*adam_update(g, o, p, AdamConfig(lr=1e-3)), l)

    for s in range(steps):
        params, opt, _ = step_fn(params, opt, data.batch(s))
    w_vanilla = params["layers"]["q"][0]

    rr_v, dens_v = rpca_stats(w_vanilla)

    # SALAAD-trained surrogate (ground-truth SLR by construction)
    tr, state = train_salaad(cfg, steps=steps)
    surr = tr.surrogate(state)
    w_salaad = surr["layers"]["q"][0]
    rr_s, dens_s = rpca_stats(w_salaad)
    blk = state.slr["layers/q"]
    gt_rank = float(np.sum(np.asarray(blk.s_vals)[0] > 0) / min(w_salaad.shape))
    gt_dens = float(np.sum(np.asarray(blk.s_coo.idx)[0] >= 0) / w_salaad.size)

    return {
        "vanilla": {"rank_ratio": rr_v, "density": dens_v},
        "salaad": {"rank_ratio": rr_s, "density": dens_s,
                   "gt_rank_ratio": gt_rank, "gt_density": gt_dens},
    }


def main(steps: int = 40):
    r = run(steps)
    emit(
        "appA/vanilla", 0.0,
        f"rpca_rank_ratio={r['vanilla']['rank_ratio']:.3f};rpca_density={r['vanilla']['density']:.3f}",
    )
    emit(
        "appA/salaad", 0.0,
        f"rpca_rank_ratio={r['salaad']['rank_ratio']:.3f};gt={r['salaad']['gt_rank_ratio']:.3f};"
        f"rpca_density={r['salaad']['density']:.3f};gt_d={r['salaad']['gt_density']:.3f}",
    )


if __name__ == "__main__":
    main()
