"""Fig. 1 / App. G + App. H (scaled down): component-wise SLR behavior.

(a) training loss with vs without the embedding layer included is unchanged
    while the embedding still develops SLR structure (benign);
(b) including the LM HEAD degrades the loss and/or fails to develop stable
    structure (non-benign, App. H) — the asymmetry the paper characterizes.
"""
from __future__ import annotations

import numpy as np

from repro.core.selection import SelectionConfig

from .common import bench_arch, emit, eval_loss, ppl, salaad_cfg, train_salaad


def run(steps: int = 50) -> dict:
    cfg = bench_arch()
    out = {}
    variants = {
        "with_embed": SelectionConfig(min_dim=16, include_embedding=True),
        "without_embed": SelectionConfig(min_dim=16, include_embedding=False),
        "with_lm_head": SelectionConfig(
            min_dim=16, include_embedding=True, include_lm_head=True
        ),
    }
    for name, sel in variants.items():
        scfg = salaad_cfg()
        scfg = type(scfg)(**{**scfg.__dict__, "selection": sel})
        tr, state = train_salaad(cfg, steps=steps, scfg=scfg)
        ev = eval_loss(state.params, cfg)
        emb_stats = {}
        for bname, blk in state.slr.items():
            if "embed" in bname or "lm_head" in bname:
                live = int(np.sum(np.asarray(blk.s_vals) > 0))
                nnz = int(np.sum(np.asarray(blk.s_coo.idx) >= 0))
                emb_stats[bname] = {
                    "rank_live": live,
                    "nnz": nnz,
                    "alpha": float(np.asarray(blk.alpha)),
                }
        out[name] = {"ppl": ppl(ev), "components": emb_stats}
    return out


def main(steps: int = 50):
    res = run(steps)
    for name, r in res.items():
        comps = ";".join(
            f"{k.split('/')[-1]}:rank={v['rank_live']},nnz={v['nnz']}"
            for k, v in r["components"].items()
        )
        emit(f"fig1/{name}", 0.0, f"ppl={r['ppl']:.2f};{comps}")


if __name__ == "__main__":
    main()
