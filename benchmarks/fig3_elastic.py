"""Fig. 3 (scaled down): elastic deployment — PPL vs parameter budget.

One SALAAD checkpoint HPA-compressed across a budget sweep, against the
vanilla path (full-rank training -> post-hoc RPCA -> the same HPA sweep).
The paper's qualitative claim to reproduce: SALAAD's curve is smooth and
dominates vanilla, whose quality collapses as the budget shrinks (because
post-hoc RPCA on standard-trained weights has weak SLR structure, App. A).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import FullRank, train_baseline
from repro.core import sparse
from repro.core.admm import BlockSLR, SalaadConfig, init_slr_state, surrogate_params
from repro.core.hpa import hpa_keep_ratio
from repro.core.rpca import rpca
from repro.core.rsvd import rank_cap
from repro.core.selection import SelectionConfig, select_blocks
from repro.models import model as model_lib

from .common import bench_arch, emit, eval_loss, make_data, ppl, salaad_cfg, train_salaad


def rpca_slr_state(params, scfg):
    """Post-hoc RPCA decomposition packed into an SLRState (vanilla path)."""
    state, blocks = init_slr_state(params, scfg)
    new_state = {}
    for info in blocks:
        blk = state[info.name]
        x = params
        for p in info.path:
            x = x[getattr(p, "key", getattr(p, "idx", None))]
        r = blk.p.shape[-1]
        cap = blk.s_coo.values.shape[-1]

        def decompose(mat):
            l, s, _ = rpca(mat.astype(jnp.float32), n_iter=40)
            u, sv, vt = jnp.linalg.svd(l, full_matrices=False)
            u, sv, vt = u[:, :r], sv[:r], vt[:r]
            coo = sparse.from_dense(s, cap)
            return u * sv[None], vt, sv, coo.values, coo.idx

        fn = decompose
        stack = info.stack_dims
        if stack:
            nb = int(np.prod(stack))
            outs = jax.vmap(decompose)(x.reshape(nb, info.n, info.m))
            outs = [o.reshape(*stack, *o.shape[1:]) for o in outs]
        else:
            outs = decompose(x)
        p_, vt_, sv_, cv_, ci_ = outs
        l_dense = p_ @ vt_
        s_dense = sparse.to_dense(sparse.CooMatrix(cv_, ci_, (info.n, info.m)))
        new_state[info.name] = BlockSLR(
            p=p_, vt=vt_, s_vals=sv_,
            s_coo=sparse.CooMatrix(cv_, ci_, (info.n, info.m)),
            y=blk.y, z=(l_dense + s_dense).astype(blk.z.dtype),
            alpha=blk.alpha, beta=blk.beta, rho=blk.rho,
        )
    return new_state, blocks


def run(steps: int = 60, budgets=(1.0, 0.8, 0.6, 0.4, 0.25)) -> list[dict]:
    cfg = bench_arch()
    rows = []

    # SALAAD path — each budget is ALSO evaluated through the deployed
    # factored (L + S) representation (serving/deployed.py): the elastic
    # spectrum must hold on the fast path, not just on re-materialized
    # dense weights.
    from repro.serving.deployed import DeployedModel

    tr, state = train_salaad(cfg, steps=steps)
    for keep in budgets:
        slr_c, rep = hpa_keep_ratio(state.slr, tr.blocks, keep, kappa=0.7)
        params_c = surrogate_params(state.params, slr_c, tr.blocks)
        deployed = DeployedModel.build(cfg, state.params, slr_c, tr.blocks, fmt="factored")
        rows.append(
            {"path": "salaad", "keep": keep, "ppl": ppl(eval_loss(params_c, cfg)),
             "ppl_deployed": ppl(eval_loss(deployed.params, cfg)),
             "served_bytes": deployed.param_bytes()["total_bytes"],
             "slr_params": rep["params_after"]}
        )

    # vanilla path: full-rank train -> RPCA -> same HPA sweep
    data = make_data(cfg)
    from repro.optim.adam import AdamConfig

    _, _, _ = 0, 0, 0
    ev, n, _ = train_baseline(FullRank(), cfg, data, steps, jax.random.PRNGKey(0), AdamConfig(lr=1e-3))
    # retrain to obtain the params (train_baseline doesn't return them; redo inline)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    from repro.optim.adam import adam_update, init_adam

    opt = init_adam(params)
    import functools

    @jax.jit
    def step_fn(p, o, batch):
        (l, _), g = jax.value_and_grad(lambda pp: model_lib.loss_fn(pp, batch, cfg), has_aux=True)(p)
        return (*adam_update(g, o, p, AdamConfig(lr=1e-3)), l)

    for s in range(steps):
        params, opt, _ = step_fn(params, opt, data.batch(s))

    scfg = salaad_cfg()
    vstate, vblocks = rpca_slr_state(params, scfg)
    for keep in budgets:
        slr_c, rep = hpa_keep_ratio(vstate, vblocks, keep, kappa=0.7)
        params_c = surrogate_params(params, slr_c, vblocks)
        rows.append(
            {"path": "vanilla-rpca", "keep": keep, "ppl": ppl(eval_loss(params_c, cfg)),
             "slr_params": rep["params_after"]}
        )
    return rows


def main(steps: int = 60):
    for r in run(steps):
        extra = (
            f";ppl_deployed={r['ppl_deployed']:.2f};served_bytes={r['served_bytes']}"
            if "ppl_deployed" in r else ""
        )
        emit(
            f"fig3/{r['path']}/keep={r['keep']}", 0.0,
            f"ppl={r['ppl']:.2f};slr_params={r['slr_params']}{extra}",
        )


if __name__ == "__main__":
    main()
