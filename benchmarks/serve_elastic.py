"""Elastic-serving benchmark: budget tiers as a serving dimension.

Trains the reduced 60m config with the real SALAAD trainer, materializes the
HPA spectrum as ONE ModelBank (factored views sharing the base pytree), and
measures the three things the elastic API promises:

1. **Per-tier decode throughput** — the same engine drives pinned batches at
   each tier: cheaper tiers step faster because HPA removed structure, and
   the engine switches between them without rebuilding anything.
2. **Tier-switch latency** — with every tier's program warmed, a mid-stream
   downshift must cost an ordinary tick: the benchmark measures the first
   tick after a forced shift vs the steady-state tick and records
   ``retraces_on_switch`` (MUST be 0 — each tier compiles exactly once).
3. **Admitted rate under page pressure** — a deliberately tight page pool
   driven closed-loop with the pressure controller ON vs OFF: the controller
   downshifts the serving tier (cheaper, faster steps → sooner completions →
   sooner frees) before the engine resorts to eviction.

Results → ``BENCH_elastic.json`` (per-row engine-config provenance included).

  PYTHONPATH=src python -m benchmarks.serve_elastic --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, PagedServingEngine

from .common import bench_arch, emit, engine_provenance, salaad_cfg, train_salaad


def drive(engine, requests: int, max_new: int, tier: int | None = None) -> dict:
    """Closed-loop: submit a fixed trace (optionally pinned to one tier),
    run to completion."""
    for i in range(requests):
        engine.submit([1 + (i % 7), 2, 3, 4], max_new_tokens=max_new,
                      tier=tier)
    # snapshot EVERY cumulative counter so warmup drives on the same engine
    # never pollute a measured row
    calls0 = engine.decode_calls
    evict0 = engine.evictions
    down0 = engine.downshift_ticks
    switch0 = engine.tier_switches
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    assert len(done) == requests, (len(done), requests)
    return {
        "requests": len(done),
        "tokens": tokens,
        "wall_s": round(dt, 4),
        "tok_per_s": round(tokens / max(dt, 1e-9), 1),
        "admitted_req_per_s": round(len(done) / max(dt, 1e-9), 2),
        "decode_calls": engine.decode_calls - calls0,
        "evictions": engine.evictions - evict0,
        "downshift_ticks": engine.downshift_ticks - down0,
        "tier_switches": engine.tier_switches - switch0,
    }


def per_tier_throughput(bank, ecfg_kw, requests, max_new) -> dict:
    """One engine, every tier exercised in turn (warmup absorbs each tier's
    single compilation; the engine is NOT rebuilt between budgets — that is
    the API change being measured)."""
    eng = PagedServingEngine(bank, EngineConfig(**ecfg_kw))
    rows = {}
    for t in range(len(bank)):
        drive(eng, max(requests // 2, 2), max_new, tier=t)   # warm tier t
        row = drive(eng, requests, max_new, tier=t)
        rows[bank[t].name] = {
            "tier": t,
            "served_bytes": bank[t].param_bytes,
            "tok_per_s": row["tok_per_s"],
        }
    rows["_engine"] = {
        "decode_traces": eng.decode_traces,    # <= one per tier, ever
        "jit_retraces": eng.stats_snapshot()["jit_retraces"],
        "engine_config": engine_provenance(eng),
    }
    return rows


def tier_switch_latency(bank, ecfg_kw, ticks: int = 6) -> dict:
    """Steady-tick vs first-tick-after-downshift wall time, with every
    tier's program already warmed — the no-re-jit claim, measured."""
    eng = PagedServingEngine(bank, EngineConfig(**ecfg_kw))
    for t in range(len(bank)):                 # warm every tier's programs
        drive(eng, 2, 4, tier=t)
    traces0 = eng.decode_traces
    # the registry's retrace detector generalizes this benchmark's original
    # decode-trace delta: serve_jit_retraces_total counts compilation-cache
    # misses on ANY (program, tier) pair that had already compiled, so the
    # no-re-jit contract now covers prefill/chunk programs too
    retraces0 = eng.metrics.retraces()

    eng.submit([5, 7, 11, 13], max_new_tokens=4 + 2 * ticks, tier=0)
    steady = []
    for _ in range(ticks):
        t0 = time.perf_counter()
        eng.step()
        steady.append(time.perf_counter() - t0)
    eng._tier_shift = len(bank) - 1            # force the controller's move
    t0 = time.perf_counter()
    eng.step()                                 # the switch tick
    switch_s = time.perf_counter() - t0
    eng.run()
    return {
        "steady_tick_ms": round(1e3 * sum(steady) / len(steady), 2),
        "switch_tick_ms": round(1e3 * switch_s, 2),
        "switch_over_steady": round(
            switch_s / max(sum(steady) / len(steady), 1e-9), 2
        ),
        "retraces_on_switch": eng.metrics.retraces() - retraces0,
        "decode_traces_delta": eng.decode_traces - traces0,
        "tier_switches": eng.tier_switches,
    }


def pressure_comparison(bank, ecfg_kw, requests, max_new) -> dict:
    """Tight pool, controller on vs off, same closed-loop trace."""
    rows = {}
    for name, policy in (("controller_off", "static"),
                         ("controller_on", "pressure")):
        eng = PagedServingEngine(bank, EngineConfig(
            **ecfg_kw, tier_policy=policy,
            tier_target_free=0.35, tier_gain=6.0,
        ))
        drive(eng, 2, 4)                       # warm tier 0 + admission
        if policy == "pressure":               # warm the downshift tiers too
            for t in range(1, len(bank)):
                drive(eng, 1, 2, tier=t)
        row = drive(eng, requests, max_new)
        row["engine_config"] = engine_provenance(eng)
        rows[name] = row
    off, on = rows["controller_off"], rows["controller_on"]
    rows["summary"] = {
        "admitted_rate_ratio": round(
            on["admitted_req_per_s"] / max(off["admitted_req_per_s"], 1e-9), 2
        ),
        "evictions_off": off["evictions"],
        "evictions_on": on["evictions"],
        "downshift_ticks_on": on["downshift_ticks"],
    }
    return rows


def run(
    steps: int = 120,
    budgets=(1.0, 0.6, 0.3),
    kappa: float = 0.7,
    requests: int = 8,
    max_new: int = 16,
    max_slots: int = 4,
    max_len: int = 64,
    block_size: int = 8,
    pressure_blocks: int = 10,
    fmt: str = "factored",
    seed: int = 0,
) -> dict:
    cfg = bench_arch()
    tr, state = train_salaad(cfg, steps=steps, scfg=salaad_cfg(), seed=seed)
    bank = ModelBank.build(cfg, state.params, state.slr, tr.blocks,
                           budgets=budgets, kappa=kappa, fmt=fmt)
    base_kw = dict(max_slots=max_slots, max_len=max_len,
                   block_size=block_size)
    tight_kw = dict(max_slots=max_slots, max_len=max_len,
                    block_size=block_size, num_blocks=pressure_blocks)
    return {
        "bank": bank.report(),
        "per_tier": per_tier_throughput(bank, base_kw, requests, max_new),
        "tier_switch": tier_switch_latency(bank, base_kw),
        "pressure": pressure_comparison(bank, tight_kw, requests, max_new),
        "train_steps": steps,
    }


def main(out: str = "BENCH_elastic.json", **kw):
    rows = run(**kw)
    Path(out).write_text(json.dumps(rows, indent=2))
    sw = rows["tier_switch"]
    pr = rows["pressure"]["summary"]
    tiers = {k: v["tok_per_s"] for k, v in rows["per_tier"].items()
             if not k.startswith("_")}
    assert sw["retraces_on_switch"] == 0, sw   # the no-re-jit contract
    emit(
        "serve_elastic", 0.0,
        f"per-tier tok/s {tiers}; switch {sw['switch_tick_ms']}ms vs steady "
        f"{sw['steady_tick_ms']}ms (retraces={sw['retraces_on_switch']}); "
        f"pressure admitted x{pr['admitted_rate_ratio']} "
        f"(evictions {pr['evictions_off']}→{pr['evictions_on']}, "
        f"downshift_ticks={pr['downshift_ticks_on']})",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--fmt", default="factored",
                    choices=("dense", "factored", "bsr"))
    ap.add_argument("--out", default="BENCH_elastic.json")
    a = ap.parse_args()
    steps = a.steps or (60 if a.quick else 120)
    main(out=a.out, steps=steps, fmt=a.fmt,
         requests=4 if a.quick else 8, max_new=8 if a.quick else 16)
