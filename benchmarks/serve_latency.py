"""Latency SLO harness: Poisson arrivals against paged vs slot-padded engines.

Throughput (serve_throughput.py) hides the queueing story: the slot-padded
engine reserves ``max_len`` KV positions per slot, so at a fixed memory
budget it can only decode ``max_slots`` requests at once and everything else
waits. The paged engine spends the SAME KV budget as a shared page pool, so
short requests stop paying for the worst case and more of them decode
concurrently — queue waits (and therefore tail TTFT) drop.

This harness drives both engines with the SAME Poisson request trace in open
loop (arrivals are submitted on the wall clock, whether or not the engine is
keeping up), then reports per-engine p50/p99 time-to-first-token, inter-token
latency, admitted-request rate, and SLO attainment → ``BENCH_latency.json``.

  PYTHONPATH=src python -m benchmarks.serve_latency --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import model as model_lib
from repro.serving.engine import (
    EngineConfig,
    PagedServingEngine,
    ServingEngine,
    decode_emitted_tokens,
)

from .common import emit, engine_provenance


def build_trace(n: int, rate_hz: float, vocab: int, max_new: int, seed: int):
    """Poisson arrival trace: [(arrival_offset_s, prompt, max_new), ...]."""
    rng = np.random.RandomState(seed)
    offsets = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    return [
        (float(offsets[i]),
         rng.randint(0, vocab, size=rng.randint(4, 8)).tolist(),
         max_new)
        for i in range(n)
    ]


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else float("nan")


def drive_open_loop(engine, trace, slo_ms: float) -> dict:
    """Submit the trace on the wall clock; tick the engine whenever it has
    work; measure TTFT against each request's SCHEDULED arrival time."""
    scheduled: dict[int, float] = {}
    done = []
    i = 0
    calls0 = getattr(engine, "decode_calls", 0)   # exclude warmup ticks
    t0 = time.time()
    while i < len(trace) or engine.has_work:
        now = time.time() - t0
        while i < len(trace) and trace[i][0] <= now:
            off, prompt, max_new = trace[i]
            uid = engine.submit(prompt, max_new_tokens=max_new,
                                deadline=t0 + off + slo_ms / 1e3)
            scheduled[uid] = t0 + off
            i += 1
        if engine.has_work:
            done.extend(engine.step())
        elif i < len(trace):
            time.sleep(max(trace[i][0] - (time.time() - t0), 0.0))
    dt = time.time() - t0

    ttft = [r.first_token_at - scheduled[r.uid] for r in done]
    itl = [b - a for r in done for a, b in zip(r.token_times, r.token_times[1:])]
    tokens = sum(len(r.out_tokens) for r in done)
    decode_tokens = decode_emitted_tokens(done)
    return {
        "requests": len(done),
        "tokens": tokens,
        "wall_s": round(dt, 3),
        "admitted_req_per_s": round(len(done) / max(dt, 1e-9), 3),
        "tok_per_s": round(tokens / max(dt, 1e-9), 1),
        "ttft_p50_ms": round(percentile(ttft, 50) * 1e3, 1),
        "ttft_p99_ms": round(percentile(ttft, 99) * 1e3, 1),
        "itl_mean_ms": round(float(np.mean(itl)) * 1e3, 1) if itl else None,
        "itl_p99_ms": round(percentile(itl, 99) * 1e3, 1) if itl else None,
        "slo_ms": slo_ms,
        "slo_attainment": round(
            sum(t * 1e3 <= slo_ms for t in ttft) / max(len(ttft), 1), 3
        ),
        "evictions": getattr(engine, "evictions", 0),
        # decode-emitted tokens per jitted decode step, across ALL slots —
        # i.e. mean batch occupancy x per-slot burst length (<= decode_slots
        # without speculation; speculative bursts raise it beyond the slot
        # count). Compare engines at equal decode_slots (also recorded).
        # acceptance_rate is null when not drafting.
        "tokens_per_step": round(
            decode_tokens / max(getattr(engine, "decode_calls", 0) - calls0, 1), 2
        ),
        "acceptance_rate": (
            round(engine.acceptance_rate, 3)
            if hasattr(engine, "acceptance_rate") else None
        ),
    }


def warmup(engine, vocab: int, max_new: int):
    """Absorb prefill-bucket + decode compilation outside the measured window."""
    engine.submit([1, 2, 3, 4, 5], max_new_tokens=max_new)
    engine.submit([6, 7], max_new_tokens=max_new)
    engine.run()


def run(
    requests: int = 32,
    rate_hz: float = 400.0,
    max_new: int = 16,
    padded_slots: int = 4,
    max_len: int = 64,
    block_size: int = 8,
    paged_slots: int = 10,
    slo_ms: float = 2000.0,
    kv_dtype: str = "float32",
    seed: int = 0,
) -> dict:
    """Both engines get the SAME KV memory budget (padded_slots * max_len
    tokens) and the SAME arrival trace; the paged engine turns that budget
    into a page pool shared by more decode slots."""
    cfg = get_arch("salaad_llama_60m").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
    trace = build_trace(requests, rate_hz, cfg.vocab_size, max_new, seed)
    num_blocks = padded_slots * max_len // block_size

    engines = {
        "padded_slots": ServingEngine(
            cfg, params,
            EngineConfig(max_slots=padded_slots, max_len=max_len),
        ),
        "paged": PagedServingEngine(
            cfg, params,
            EngineConfig(
                max_slots=paged_slots, max_len=max_len,
                block_size=block_size, num_blocks=num_blocks,
                kv_dtype=kv_dtype,
            ),
        ),
    }
    rows = {}
    for name, eng in engines.items():
        warmup(eng, cfg.vocab_size, max_new)
        rows[name] = drive_open_loop(eng, trace, slo_ms)
        rows[name]["engine"] = name
        rows[name]["kv_budget_tokens"] = padded_slots * max_len
        rows[name]["decode_slots"] = eng.ecfg.max_slots
        rows[name]["engine_config"] = engine_provenance(eng)

    pad, pg = rows["padded_slots"], rows["paged"]
    rows["summary"] = {
        "equal_kv_budget_tokens": padded_slots * max_len,
        "ttft_p99_speedup": round(
            pad["ttft_p99_ms"] / max(pg["ttft_p99_ms"], 1e-9), 2
        ),
        "ttft_p50_speedup": round(
            pad["ttft_p50_ms"] / max(pg["ttft_p50_ms"], 1e-9), 2
        ),
        "admitted_rate_ratio": round(
            pg["admitted_req_per_s"] / max(pad["admitted_req_per_s"], 1e-9), 2
        ),
        "slo_attainment_padded": pad["slo_attainment"],
        "slo_attainment_paged": pg["slo_attainment"],
    }
    return rows


def main(out: str = "BENCH_latency.json", **kw):
    rows = run(**kw)
    Path(out).write_text(json.dumps(rows, indent=2))
    s = rows["summary"]
    emit(
        "serve_latency", 0.0,
        f"p99_ttft padded={rows['padded_slots']['ttft_p99_ms']}ms "
        f"paged={rows['paged']['ttft_p99_ms']}ms "
        f"(x{s['ttft_p99_speedup']}); slo {s['slo_attainment_padded']} -> "
        f"{s['slo_attainment_paged']}",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate-hz", type=float, default=400.0)
    ap.add_argument("--slo-ms", type=float, default=2000.0)
    ap.add_argument("--kv-dtype", default="float32")
    ap.add_argument("--out", default="BENCH_latency.json")
    a = ap.parse_args()
    n = a.requests or (24 if a.quick else 32)
    main(out=a.out, requests=n, rate_hz=a.rate_hz, slo_ms=a.slo_ms,
         kv_dtype=a.kv_dtype)
