"""Latency SLO harness: Poisson arrivals against paged vs slot-padded engines.

Throughput (serve_throughput.py) hides the queueing story: the slot-padded
engine reserves ``max_len`` KV positions per slot, so at a fixed memory
budget it can only decode ``max_slots`` requests at once and everything else
waits. The paged engine spends the SAME KV budget as a shared page pool, so
short requests stop paying for the worst case and more of them decode
concurrently — queue waits (and therefore tail TTFT) drop.

This harness drives both engines with the SAME Poisson request trace in open
loop (arrivals are submitted on the clock, whether or not the engine is
keeping up), then reports per-engine p50/p99 time-to-first-token, inter-token
latency, admitted-request rate, and SLO attainment → ``BENCH_latency.json``.
All latency math runs on ``time.monotonic()`` (the engines timestamp tokens
on that clock); only request DEADLINES stay wall-clock, as an absolute SLO
contract.

``--mixed`` runs the chunked-prefill story instead: a mixed long/short-prompt
trace against the paged engine with one-shot vs chunked prefill at the SAME
KV budget. One monolithic long-prompt prefill stalls every decoding slot for
a whole tick (head-of-line blocking — visible as a p99 inter-token-latency
spike on the short requests); chunked prefill caps per-tick prefill work at
``prefill_chunk`` tokens, so short-request ITL stays flat while the long
prompt streams in. Rows merge into the same ``BENCH_latency.json``.

Percentile basis (changed with the telemetry registry): every TTFT figure —
headline p50/p99 AND the per-class ``--mixed`` columns — is measured from the
request's SCHEDULED arrival. The driver backdates ``submit(...,
submitted_at=scheduled)`` so the engine's own ``serve_ttft_seconds``
histogram records that basis, and the rows read their percentiles from the
registry. Earlier revisions mixed bases across the ``--mixed`` arms
(scheduled arrival for the headline, actual submit time for per-class
columns), which understated TTFT exactly when a monolithic prefill blocked
the driver loop — the effect under measurement.

  PYTHONPATH=src python -m benchmarks.serve_latency --quick
  PYTHONPATH=src python -m benchmarks.serve_latency --mixed --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import model as model_lib
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineConfig,
    PagedServingEngine,
    ServingEngine,
    decode_emitted_tokens,
)

from repro.serving.telemetry import request_itls, request_ttft

from .common import emit, engine_provenance


def build_trace(n: int, rate_hz: float, vocab: int, max_new: int, seed: int):
    """Poisson arrival trace: [(arrival_offset_s, prompt, max_new), ...]."""
    rng = np.random.RandomState(seed)
    offsets = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    return [
        (float(offsets[i]),
         rng.randint(0, vocab, size=rng.randint(4, 8)).tolist(),
         max_new)
        for i in range(n)
    ]


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else float("nan")


def drive_open_loop(engine, trace, slo_ms: float) -> tuple[dict, list]:
    """Submit the trace on the clock; tick the engine whenever it has work.
    Every submit is backdated (``submitted_at``) to the request's SCHEDULED
    arrival, so the engine's registry histograms (``serve_ttft_seconds``,
    ``serve_itl_seconds``) record the scheduled-arrival basis and the row's
    percentiles read straight out of them. Latency math runs on the
    monotonic clock (matching the engine's token timestamps); deadlines
    stay wall-clock. Returns (metrics row, done)."""
    done = []
    i = 0
    calls0 = getattr(engine, "decode_calls", 0)   # exclude warmup ticks
    engine.metrics.reset_histograms()             # warmup observations too
    t0 = time.monotonic()
    wall0 = time.time()
    while i < len(trace) or engine.has_work:
        now = time.monotonic() - t0
        while i < len(trace) and trace[i][0] <= now:
            off, prompt, max_new = trace[i]
            engine.submit(prompt, max_new_tokens=max_new,
                          deadline=wall0 + off + slo_ms / 1e3,
                          submitted_at=t0 + off)
            i += 1
        if engine.has_work:
            done.extend(engine.step())
        elif i < len(trace):
            time.sleep(max(trace[i][0] - (time.monotonic() - t0), 0.0))
    dt = time.monotonic() - t0

    tel = engine.metrics
    ttft = [request_ttft(r) for r in done if r.first_token_at]
    itl = [g for r in done for g in request_itls(r)]

    def pct(hist, xs, p):
        # registry histogram when telemetry is on; raw list otherwise
        if hist.count(tel.engine):
            return hist.percentile(p, tel.engine)
        return percentile(xs, p)

    tokens = sum(len(r.out_tokens) for r in done)
    decode_tokens = decode_emitted_tokens(done)
    return {
        "requests": len(done),
        "tokens": tokens,
        "wall_s": round(dt, 3),
        "admitted_req_per_s": round(len(done) / max(dt, 1e-9), 3),
        "tok_per_s": round(tokens / max(dt, 1e-9), 1),
        "ttft_p50_ms": round(pct(tel.ttft, ttft, 50) * 1e3, 1),
        "ttft_p99_ms": round(pct(tel.ttft, ttft, 99) * 1e3, 1),
        "itl_mean_ms": round(float(np.mean(itl)) * 1e3, 1) if itl else None,
        "itl_p99_ms": round(pct(tel.itl, itl, 99) * 1e3, 1) if itl else None,
        "slo_ms": slo_ms,
        "slo_attainment": round(
            sum(t * 1e3 <= slo_ms for t in ttft) / max(len(ttft), 1), 3
        ),
        "evictions": getattr(engine, "evictions", 0),
        # decode-emitted tokens per jitted decode step, across ALL slots —
        # i.e. mean batch occupancy x per-slot burst length (<= decode_slots
        # without speculation; speculative bursts raise it beyond the slot
        # count). Compare engines at equal decode_slots (also recorded).
        # acceptance_rate is null when not drafting.
        "tokens_per_step": round(
            decode_tokens / max(getattr(engine, "decode_calls", 0) - calls0, 1), 2
        ),
        "acceptance_rate": (
            round(engine.acceptance_rate, 3)
            if hasattr(engine, "acceptance_rate") else None
        ),
    }, done


def warmup(engine, vocab: int, max_new: int):
    """Absorb prefill-bucket + decode compilation outside the measured window."""
    engine.submit([1, 2, 3, 4, 5], max_new_tokens=max_new)
    engine.submit([6, 7], max_new_tokens=max_new)
    engine.run()


def run(
    requests: int = 32,
    rate_hz: float = 400.0,
    max_new: int = 16,
    padded_slots: int = 4,
    max_len: int = 64,
    block_size: int = 8,
    paged_slots: int = 10,
    slo_ms: float = 2000.0,
    kv_dtype: str = "float32",
    seed: int = 0,
) -> dict:
    """Both engines get the SAME KV memory budget (padded_slots * max_len
    tokens) and the SAME arrival trace; the paged engine turns that budget
    into a page pool shared by more decode slots."""
    cfg = get_arch("salaad_llama_60m").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
    bank = ModelBank.single(cfg, params)
    trace = build_trace(requests, rate_hz, cfg.vocab_size, max_new, seed)
    num_blocks = padded_slots * max_len // block_size

    engines = {
        "padded_slots": ServingEngine(
            bank, EngineConfig(max_slots=padded_slots, max_len=max_len),
        ),
        "paged": PagedServingEngine(
            bank,
            EngineConfig(
                max_slots=paged_slots, max_len=max_len,
                block_size=block_size, num_blocks=num_blocks,
                kv_dtype=kv_dtype,
            ),
        ),
    }
    rows = {}
    for name, eng in engines.items():
        warmup(eng, cfg.vocab_size, max_new)
        rows[name], _ = drive_open_loop(eng, trace, slo_ms)
        rows[name]["engine"] = name
        rows[name]["kv_budget_tokens"] = padded_slots * max_len
        rows[name]["decode_slots"] = eng.ecfg.max_slots
        rows[name]["engine_config"] = engine_provenance(eng)

    pad, pg = rows["padded_slots"], rows["paged"]
    rows["summary"] = {
        "equal_kv_budget_tokens": padded_slots * max_len,
        "ttft_p99_speedup": round(
            pad["ttft_p99_ms"] / max(pg["ttft_p99_ms"], 1e-9), 2
        ),
        "ttft_p50_speedup": round(
            pad["ttft_p50_ms"] / max(pg["ttft_p50_ms"], 1e-9), 2
        ),
        "admitted_rate_ratio": round(
            pg["admitted_req_per_s"] / max(pad["admitted_req_per_s"], 1e-9), 2
        ),
        "slo_attainment_padded": pad["slo_attainment"],
        "slo_attainment_paged": pg["slo_attainment"],
    }
    return rows


# ------------------------------------------------------ mixed (chunked) ---


def build_mixed_trace(n: int, rate_hz: float, vocab: int, max_new: int,
                      long_len: int, seed: int, long_every: int = 5):
    """Poisson arrivals where every ``long_every``-th request carries a
    ``long_len``-token prompt and the rest stay short (4-7 tokens) — the
    head-of-line-blocking workload: long prefills land while short requests
    are mid-decode. The long prompts share a common 2/3-length prefix (a
    system prompt) + unique tails — invisible to engines without a prefix
    cache, the whole point of the ``chunked_prefix`` arm."""
    rng = np.random.RandomState(seed)
    offsets = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    shared = rng.randint(0, vocab, size=2 * long_len // 3).tolist()
    trace = []
    for i in range(n):
        if i % long_every == long_every - 1:
            prompt = shared + rng.randint(
                0, vocab, size=long_len - len(shared)
            ).tolist()
        else:
            prompt = rng.randint(0, vocab, size=int(rng.randint(4, 8))).tolist()
        trace.append((float(offsets[i]), prompt, max_new))
    return trace


def _class_metrics(done, long_len: int) -> dict:
    """Short-request tail metrics: the requests a long prefill stalls.
    Per-class TTFT uses ``request_ttft`` — the same scheduled-arrival basis
    (backdated ``submitted_at``) as the headline columns and the registry
    histogram, so the arms never mix timestamp bases again."""
    short = [r for r in done if len(r.prompt) < long_len]
    long_ = [r for r in done if len(r.prompt) >= long_len]
    itl = [g for r in short for g in request_itls(r)]
    return {
        "short_requests": len(short),
        "long_requests": len(long_),
        "short_itl_p50_ms": round(percentile(itl, 50) * 1e3, 1),
        "short_itl_p99_ms": round(percentile(itl, 99) * 1e3, 1),
        "short_itl_max_ms": round(max(itl) * 1e3, 1) if itl else None,
        "short_ttft_p99_ms": round(
            percentile([request_ttft(r) for r in short], 99) * 1e3, 1
        ),
        "long_ttft_p99_ms": round(
            percentile([request_ttft(r) for r in long_], 99) * 1e3, 1
        ),
    }


def run_mixed(
    requests: int = 30,
    rate_hz: float = 120.0,
    max_new: int = 24,
    max_len: int = 256,
    block_size: int = 16,
    slots: int = 8,
    num_blocks: int = 96,
    prefill_chunk: int = 32,
    long_len: int = 192,
    slo_ms: float = 2000.0,
    kv_dtype: str = "float32",
    seed: int = 0,
) -> dict:
    """One-shot vs chunked prefill on the SAME paged engine config (equal KV
    budget, equal trace): the only difference is whether a long prompt
    prefills in one monolithic tick or in ``prefill_chunk``-token slices
    interleaved with the other slots' decode steps."""
    cfg = get_arch("salaad_llama_60m").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
    bank = ModelBank.single(cfg, params)
    trace = build_mixed_trace(
        requests, rate_hz, cfg.vocab_size, max_new, long_len, seed
    )
    rows = {}
    arms = (("oneshot", None, False), ("chunked", prefill_chunk, False),
            ("chunked_prefix", prefill_chunk, True))
    for name, chunk, pc in arms:
        eng = PagedServingEngine(
            bank,
            EngineConfig(
                max_slots=slots, max_len=max_len, block_size=block_size,
                num_blocks=num_blocks, prefill_chunk=chunk,
                kv_dtype=kv_dtype, prefix_cache=pc,
            ),
        )
        # absorb compilation of the short bucket, the long path (one-shot
        # bucket or chunk program), and decode outside the measured window;
        # the repeated long submit warms the prefix-cache hit-admission path
        # (a no-op for the cache-off arms)
        eng.submit([1, 2, 3, 4, 5], max_new_tokens=4)
        eng.run()
        for _ in range(2):
            eng.submit(list(range(1, long_len + 1)), max_new_tokens=4)
            eng.run()
        row, done = drive_open_loop(eng, trace, slo_ms)
        row.update(_class_metrics(done, long_len))
        row["engine_config"] = engine_provenance(eng)
        if pc:
            row["prefix_hits"] = eng.prefix_hits
        rows[name] = row
    one, chk = rows["oneshot"], rows["chunked"]
    rows["summary"] = {
        "kv_budget_tokens": num_blocks * block_size,
        "long_len": long_len,
        "prefill_chunk": prefill_chunk,
        # the headline: tail ITL of SHORT requests decoding while a long
        # prompt prefills — chunking should cut it
        "short_itl_p99_speedup": round(
            one["short_itl_p99_ms"] / max(chk["short_itl_p99_ms"], 1e-9), 2
        ),
        "short_itl_max_speedup": round(
            (one["short_itl_max_ms"] or 0.0)
            / max(chk["short_itl_max_ms"] or 1e-9, 1e-9), 2
        ),
        "short_ttft_p99_speedup": round(
            one["short_ttft_p99_ms"] / max(chk["short_ttft_p99_ms"], 1e-9), 2
        ),
        # the price: the long prompt itself streams in over several ticks
        "long_ttft_p99_ratio": round(
            chk["long_ttft_p99_ms"] / max(one["long_ttft_p99_ms"], 1e-9), 2
        ),
        # the prefix-cache arm: same chunked config, radix cache on — long
        # prompts that hit the shared-prefix pages skip most of their prefill
        "prefix_long_ttft_p99_speedup": round(
            chk["long_ttft_p99_ms"]
            / max(rows["chunked_prefix"]["long_ttft_p99_ms"], 1e-9), 2
        ),
        "prefix_hits": rows["chunked_prefix"]["prefix_hits"],
    }
    return rows


def _merge_out(out: str, key: str, rows: dict):
    """Merge one section into BENCH_latency.json, preserving other rows (the
    default and --mixed runs write different sections of the same file)."""
    path = Path(out)
    payload = {}
    if path.exists():
        try:
            payload = json.loads(path.read_text())
        except ValueError:
            payload = {}
    payload[key] = rows
    path.write_text(json.dumps(payload, indent=2))


def main(out: str = "BENCH_latency.json", mixed: bool = False, **kw):
    if mixed:
        rows = run_mixed(**kw)
        _merge_out(out, "mixed_prefill", rows)
        s = rows["summary"]
        emit(
            "serve_latency_mixed", 0.0,
            f"short-req p99 ITL oneshot={rows['oneshot']['short_itl_p99_ms']}"
            f"ms chunked={rows['chunked']['short_itl_p99_ms']}ms "
            f"(x{s['short_itl_p99_speedup']}) at chunk={s['prefill_chunk']}",
        )
        return rows
    rows = run(**kw)
    _merge_out(out, "engines", rows)
    s = rows["summary"]
    emit(
        "serve_latency", 0.0,
        f"p99_ttft padded={rows['padded_slots']['ttft_p99_ms']}ms "
        f"paged={rows['paged']['ttft_p99_ms']}ms "
        f"(x{s['ttft_p99_speedup']}); slo {s['slo_attainment_padded']} -> "
        f"{s['slo_attainment_paged']}",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed long/short-prompt workload: one-shot vs "
                         "chunked prefill on the paged engine")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate-hz", type=float, default=None)
    ap.add_argument("--slo-ms", type=float, default=2000.0)
    ap.add_argument("--kv-dtype", default="float32")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--long-len", type=int, default=192)
    ap.add_argument("--out", default="BENCH_latency.json")
    a = ap.parse_args()
    if a.mixed:
        n = a.requests or (20 if a.quick else 30)
        main(out=a.out, mixed=True, requests=n,
             rate_hz=a.rate_hz or 120.0, slo_ms=a.slo_ms,
             kv_dtype=a.kv_dtype, prefill_chunk=a.prefill_chunk,
             long_len=a.long_len)
    else:
        n = a.requests or (24 if a.quick else 32)
        main(out=a.out, requests=n, rate_hz=a.rate_hz or 400.0,
             slo_ms=a.slo_ms, kv_dtype=a.kv_dtype)
