"""Speculative-decode benchmark: elastic self-speculation vs the paged engine.

SALAAD's HPA spectrum means the serving stack already holds its own draft
model: a low-budget truncation of the SAME SLR weights. This benchmark trains
the reduced 60m config with the real SALAAD trainer (so the SLR state tracks
the weights and truncation is meaningful), deploys the spectrum's two ends —
full budget as the target, ``--spec-budget`` (default 0.4) as the draft — and
drives the PR 2 ``PagedServingEngine`` and the ``SpeculativeEngine`` over the
SAME request trace at the SAME total KV byte budget. The speculative engine
pays for its draft page pool out of that budget (fewer target pages), so the
comparison is memory-honest.

Reported per engine: steady-state decode tokens/sec (compilation absorbed by
a warmup pass), tokens per jitted step, acceptance rate, and the full engine
config (provenance) → ``BENCH_spec.json``. Target: >= 1.5x decode tokens/sec
for the speculative engine.

  PYTHONPATH=src python -m benchmarks.serve_spec --quick
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.hpa import hpa_keep_ratio
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineConfig,
    PagedServingEngine,
    decode_emitted_tokens,
)
from repro.serving.speculative import SpeculativeEngine

from .common import bench_arch, emit, engine_provenance, salaad_cfg, train_salaad

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "bf16": 2, "int8": 1}


def pool_bytes(cfg, num_blocks: int, block_size: int, kv_dtype: str) -> int:
    """KV page-pool bytes: k + v pools across layers."""
    per_tok = cfg.num_kv_heads * cfg.head_dim * _DTYPE_BYTES[kv_dtype]
    return 2 * cfg.num_layers * num_blocks * block_size * per_tok


def drive(engine, requests: int, max_new: int) -> dict:
    """Closed-loop: submit a fixed trace, run to completion."""
    for i in range(requests):
        engine.submit([1 + (i % 7), 2, 3, 4], max_new_tokens=max_new)
    calls0 = engine.decode_calls
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    assert len(done) == requests, (len(done), requests)
    decode_tokens = decode_emitted_tokens(done)
    return {
        "tokens": tokens,
        "wall_s": round(dt, 4),
        "tok_per_s": round(tokens / max(dt, 1e-9), 1),
        "tokens_per_step": round(
            decode_tokens / max(engine.decode_calls - calls0, 1), 2
        ),
        "evictions": engine.evictions,
    }


def run(
    steps: int = 400,
    spec_budget: float = 0.4,
    kappa: float = 0.7,
    spec_k: int = 6,
    requests: int = 8,
    max_new: int = 32,
    max_slots: int = 4,
    max_len: int = 64,
    block_size: int = 8,
    base_blocks: int = 48,
    fmt: str = "dense",
    seed: int = 0,
) -> dict:
    cfg = bench_arch()
    tr, state = train_salaad(cfg, steps=steps, scfg=salaad_cfg(), seed=seed)
    slr_full, _ = hpa_keep_ratio(state.slr, tr.blocks, 1.0, kappa)
    slr_draft, rep = hpa_keep_ratio(state.slr, tr.blocks, spec_budget, kappa)
    target = DeployedModel.build(cfg, state.params, slr_full, tr.blocks, fmt=fmt)
    draft = DeployedModel.build(cfg, state.params, slr_draft, tr.blocks, fmt=fmt)

    # equal KV bytes: the spec engine's target + draft pools together must not
    # exceed the baseline's single pool (draft pages are cheaper at bf16)
    draft_dtype = "bfloat16"
    per_page_base = pool_bytes(cfg, 1, block_size, "float32")
    per_page_spec = per_page_base + pool_bytes(cfg, 1, block_size, draft_dtype)
    spec_blocks = base_blocks * per_page_base // per_page_spec
    budget = base_blocks * per_page_base

    # ONE bank carries both ends of the elastic spectrum: tier 0 (full
    # budget) verifies, tier 1 (spec_budget) drafts
    bank = ModelBank(cfg, [target, draft], keeps=[1.0, spec_budget])
    base = PagedServingEngine(bank, EngineConfig(
        max_slots=max_slots, max_len=max_len, block_size=block_size,
        num_blocks=base_blocks,
    ))
    spec = SpeculativeEngine(bank, EngineConfig(
        max_slots=max_slots, max_len=max_len, block_size=block_size,
        num_blocks=spec_blocks, spec_k=spec_k,
        spec_draft_kv_dtype=draft_dtype,
    ))

    rows: dict = {}
    for name, eng in (("paged", base), ("speculative", spec)):
        drive(eng, requests, max_new)          # warmup: absorb compilation
        # best-of-3 measured passes: this box's scheduler noise swings
        # steady-state rates by ~2x run-to-run, on both engines
        rows[name] = max(
            (drive(eng, requests, max_new) for _ in range(3)),
            key=lambda r: r["tok_per_s"],
        )
        rows[name]["engine_config"] = engine_provenance(eng)
        # steady-state recompiles after the warmup pass (registry detector)
        rows[name]["jit_retraces"] = eng.stats_snapshot()["jit_retraces"]
        rows[name]["kv_budget_bytes"] = (
            pool_bytes(cfg, eng.num_blocks, block_size, eng.ecfg.kv_dtype)
            + (pool_bytes(cfg, eng.num_blocks, block_size, draft_dtype)
               if name == "speculative" else 0)
        )
    rows["speculative"]["acceptance_rate"] = round(spec.acceptance_rate, 3)

    rows["summary"] = {
        "decode_speedup": round(
            rows["speculative"]["tok_per_s"] / max(rows["paged"]["tok_per_s"], 1e-9), 2
        ),
        "acceptance_rate": rows["speculative"]["acceptance_rate"],
        "tokens_per_step_paged": rows["paged"]["tokens_per_step"],
        "tokens_per_step_spec": rows["speculative"]["tokens_per_step"],
        "draft_hpa_budget": spec_budget,
        "draft_slr_params": rep["params_after"],
        "spec_k": spec_k,
        "equal_kv_budget_bytes": budget,
        "train_steps": steps,
    }
    return rows


def main(out: str = "BENCH_spec.json", **kw):
    rows = run(**kw)
    Path(out).write_text(json.dumps(rows, indent=2))
    s = rows["summary"]
    emit(
        "serve_spec", 0.0,
        f"decode tok/s paged={rows['paged']['tok_per_s']} "
        f"spec={rows['speculative']['tok_per_s']} "
        f"(x{s['decode_speedup']}); acceptance={s['acceptance_rate']} "
        f"k={s['spec_k']} draft_budget={s['draft_hpa_budget']}",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--spec-budget", type=float, default=0.4)
    ap.add_argument("--spec-k", type=int, default=6)
    ap.add_argument("--fmt", default="dense", choices=("dense", "factored", "bsr"))
    ap.add_argument("--out", default="BENCH_spec.json")
    a = ap.parse_args()
    steps = a.steps or (120 if a.quick else 400)
    main(out=a.out, steps=steps, spec_budget=a.spec_budget, spec_k=a.spec_k,
         fmt=a.fmt, requests=4 if a.quick else 8, max_new=16 if a.quick else 32)
