"""Tensor-parallel serving: tokens/sec and per-device KV residency vs mesh size.

Drives the paged engine over the SAME request trace and the SAME total KV
budget (``num_blocks`` is held constant) at mesh sizes 1 / 2 / 4, and emits
``BENCH_shard.json``: tokens/sec plus the per-device KV pool bytes, which
must shrink ~1/N with the model-axis size — the whole point of sharding the
pools is that each device hosts 1/N of the pages, so an N-way mesh serves an
N-x KV budget at constant per-device HBM.

On forced-host-device CPU the tok/s column is NOT a speedup claim (8 virtual
devices share one socket; collectives are memcpys) — it documents that the
sharded program stays in the same performance regime. The residency column is
exact on any backend.

XLA_FLAGS is forced to 8 host devices at module import (must precede the
first jax import), mirroring ``launch/dryrun.py``:

  PYTHONPATH=src python -m benchmarks.serve_shard --quick
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineConfig,
    PagedServingEngine,
    _kv_pool_device_bytes,
)

from .common import bench_arch, emit, engine_provenance, salaad_cfg, train_salaad

# None = single-device baseline; the reduced arch is widened to 4 KV heads
# below so model=4 divides the head axis. The data axis is batch parallelism:
# weights and KV pools replicate (model_axis=1 keeps the residency assertion
# exact) and only the in-flight batch shards.
MESHES = (None, "model=2", "model=4", "model=2,data=2")


def _drive(engine, requests: int, max_new: int) -> float:
    """Submit a fixed trace, run to completion, return tokens/sec."""
    for i in range(requests):
        engine.submit([1 + (i % 7), 2, 3, 4], max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    assert len(done) == requests, (len(done), requests)
    return tokens / max(dt, 1e-9)


def run(
    steps: int = 30,
    requests: int = 8,
    max_new: int = 16,
    max_slots: int = 4,
    num_blocks: int = 32,
) -> list[dict]:
    cfg = replace(bench_arch(), num_heads=4, num_kv_heads=4)
    tr, state = train_salaad(cfg, steps=steps, scfg=salaad_cfg())
    bank = ModelBank.build(cfg, state.params, state.slr, tr.blocks,
                           budgets=(1.0,), fmt="factored")

    rows = []
    base_tokens = None
    for mesh in MESHES:
        ecfg = EngineConfig(max_slots=max_slots, max_len=64, block_size=8,
                            num_blocks=num_blocks, mesh=mesh)
        eng = PagedServingEngine(bank, ecfg)
        _drive(eng, max(requests // 2, 2), max_new)   # warmup: compile
        tok_s = _drive(eng, requests, max_new)
        per_dev = _kv_pool_device_bytes(eng.cache)
        sizes = sorted(set(per_dev.values()))
        assert len(sizes) == 1, f"unbalanced KV pool: {per_dev}"
        row = {
            "mesh": mesh,
            "model_axis": eng.mesh.model_size if eng.mesh is not None else 1,
            "tok_per_s": round(tok_s, 1),
            "kv_pool_device_bytes": sizes[0],
            "kv_pool_total_bytes": sum(per_dev.values()),
            "num_devices": len(per_dev),
            "jit_retraces": eng.stats_snapshot()["jit_retraces"],
            "provenance": engine_provenance(eng),
        }
        if base_tokens is None:
            base_tokens = row["kv_pool_device_bytes"]
        # equal total budget across meshes -> residency shrinks exactly 1/N
        assert row["kv_pool_device_bytes"] * row["model_axis"] == base_tokens, row
        assert row["jit_retraces"] == 0, row
        rows.append(row)
    return rows


def main(steps: int = 30, out: str = "BENCH_shard.json", **kw):
    rows = run(steps=steps, **kw)
    Path(out).write_text(json.dumps(rows, indent=2))
    for r in rows:
        emit(
            f"serve_shard/mesh={r['mesh'] or 'none'}", 0.0,
            f"tok_s={r['tok_per_s']};dev_bytes={r['kv_pool_device_bytes']};"
            f"devices={r['num_devices']}",
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_shard.json")
    a = ap.parse_args()
    main(steps=10 if a.quick else 30, out=a.out,
         requests=4 if a.quick else 8, max_new=8 if a.quick else 16)
