"""Fused SLR matmul: one Pallas pass (low-rank + sparse) vs separate calls.

Three measurements at equal HPA budget (keep=0.6) on the reduced 60m config:

  1. engine decode throughput — PagedServingEngine tokens/sec per deployment
     format (factored / bsr / fused), the acceptance headline: fused must
     clear >= 1.2x over the separate-call factored path;
  2. jitted decode-step latency — one ``model.decode_step`` call per format,
     isolating the per-tick win from scheduler overhead;
  3. per-kernel microbench — ``ops.slr_matmul`` (fused) vs
     ``lowrank_matmul + bsr_matmul`` (separate) at decode (T=4) and prefill
     (T=128) row widths, with analytic per-kernel HBM-bytes accounting and
     achieved vs roofline FLOP/s.

Timings on this container run CPU interpret-mode Pallas (recorded in the
payload provenance); the roofline columns are what-ifs at nominal v5e
bandwidth/compute, the byte ACCOUNTING is exact either way: the separate
path streams x twice and round-trips both partial products through HBM
(y_lr write + y_sp write + both reads + final write = 5 output streams);
the fused kernel reads x once and writes y once.

  PYTHONPATH=src python -m benchmarks.kernel_bench --quick
"""
from __future__ import annotations

import argparse
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hpa import hpa_keep_ratio
from repro.kernels import ops
from repro.kernels.bsr_matmul import bsr_from_dense
from repro.models import model as model_lib
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, PagedServingEngine

from .common import bench_arch, emit, engine_provenance, salaad_cfg, timed, train_salaad

FORMATS = ("factored", "bsr", "fused")
KEEP = 0.6
BSR_BLOCK = 32

# nominal v5e ceilings for the roofline what-if columns
HBM_BW = 819e9       # bytes/s
PEAK_FLOPS = 197e12  # bf16 MXU FLOP/s


# ------------------------------------------------- 1. engine decode tok/s ---


def _drive(engine, requests: int, max_new: int) -> float:
    for i in range(requests):
        engine.submit([1 + (i % 7), 2, 3, 4], max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    assert len(done) == requests, (len(done), requests)
    return tokens / max(dt, 1e-9)


def engine_decode(cfg, tr, state, slr_c, rep, requests: int, max_new: int,
                  iters: int) -> dict:
    ecfg = EngineConfig(max_slots=4, max_len=64, block_size=8)
    row: dict = {"keep": KEEP, "slr_params": rep["params_after"]}
    engines = {}
    for fmt in FORMATS:
        dm = DeployedModel.build(cfg, state.params, slr_c, tr.blocks,
                                 fmt=fmt, bsr_block=BSR_BLOCK)
        engines[fmt] = PagedServingEngine(ModelBank.single(cfg, dm), ecfg)
        _drive(engines[fmt], max(requests // 2, 2), max_new)  # warmup: compile
        if fmt == "fused":
            row["served_bytes"] = dm.param_bytes()["total_bytes"]
    # round-robin the formats inside each rep so machine-load drift on this
    # shared container lands on all of them, not whichever ran last
    best = {fmt: 0.0 for fmt in FORMATS}
    for _ in range(iters):
        for fmt in FORMATS:
            best[fmt] = max(best[fmt], _drive(engines[fmt], requests, max_new))
    for fmt in FORMATS:
        row[f"tok_per_s_{fmt}"] = round(best[fmt], 1)
    row["provenance"] = engine_provenance(engines["fused"])
    for base in ("factored", "bsr"):
        row[f"speedup_fused_vs_{base}"] = round(
            row["tok_per_s_fused"] / max(row[f"tok_per_s_{base}"], 1e-9), 3
        )
    return row


# --------------------------------------------- 2. decode-step latency (us) ---


def decode_step_latency(cfg, tr, state, slr_c, batch: int = 4,
                        iters: int = 30, reps: int = 3) -> dict:
    step = jax.jit(functools.partial(model_lib.decode_step, cfg=cfg))
    tok = jnp.ones((batch, 1), jnp.int32)
    ready = {}
    for fmt in FORMATS:
        dm = DeployedModel.build(cfg, state.params, slr_c, tr.blocks,
                                 fmt=fmt, bsr_block=BSR_BLOCK)
        prompt = {"tokens": jnp.ones((batch, 8), jnp.int32)}
        _, cache = model_lib.prefill(dm.params, prompt, cfg, max_len=64,
                                     cache_dtype=jnp.float32)
        logits, cache = step(dm.params, tok, cache)          # compile
        jax.block_until_ready(logits)
        ready[fmt] = (dm.params, cache)
    # interleave formats across reps (same rationale as engine_decode)
    best = {fmt: float("inf") for fmt in FORMATS}
    for _ in range(reps):
        for fmt in FORMATS:
            params, cache = ready[fmt]
            c = cache
            t0 = time.perf_counter()
            for _ in range(iters):
                logits, c = step(params, tok, c)
            jax.block_until_ready(logits)
            best[fmt] = min(best[fmt], (time.perf_counter() - t0) / iters)
    out = {f"step_us_{fmt}": round(best[fmt] * 1e6, 1) for fmt in FORMATS}
    out["speedup_fused_vs_factored"] = round(
        out["step_us_factored"] / max(out["step_us_fused"], 1e-9), 3
    )
    return out


# ------------------------------- 3. per-kernel microbench + byte accounting ---


def _site_bytes(t: int, k: int, m: int, r: int, bs: int, nnzb: int,
                itemsize: int) -> dict:
    """Analytic per-call HBM traffic for one SLR site, in bytes.

    Both paths pay the operand tables (P, Vt, sparse vals) and the sparse
    row-block gather of x (counts[j] row-tiles per output column). They
    differ in activation/output streaming:
      separate: x streamed by BOTH kernels, then y_lr + y_sp written, both
                read back, summed y written  -> 2 x-streams, 5 y-streams;
      fused:    x streamed once into the shared accumulator, y written once
                at the last slot of each column window.
    """
    tables = (k * r + r * m + nnzb * bs * bs) * itemsize
    gather = t * nnzb * bs * itemsize
    x_stream = t * k * itemsize
    y_stream = t * m * itemsize
    return {
        "separate": 2 * x_stream + gather + tables + 5 * y_stream,
        "fused": x_stream + gather + tables + y_stream,
    }


def kernel_microbench(iters: int = 3) -> list[dict]:
    bs, r, k, m, occ = BSR_BLOCK, 8, 128, 128, 0.4
    rng = np.random.RandomState(0)
    mask = np.repeat(np.repeat(rng.rand(k // bs, m // bs) < occ, bs, 0), bs, 1)
    bsr = bsr_from_dense((rng.randn(k, m) * mask).astype(np.float32), bs)
    nnzb = int(np.asarray(bsr.counts).sum())
    p = jnp.asarray(rng.randn(k, r).astype(np.float32) * 0.1)
    vt = jnp.asarray(rng.randn(r, m).astype(np.float32) * 0.1)

    fused_fn = jax.jit(lambda x: ops.slr_matmul(x, p, vt, bsr))
    sep_fn = jax.jit(lambda x: ops.lowrank_matmul(x, p, vt) + ops.bsr_matmul(x, bsr))

    rows = []
    for t, phase in ((4, "decode"), (128, "prefill")):
        x = jnp.asarray(rng.randn(t, k).astype(np.float32))
        s_fused, y_f = timed(fused_fn, x, warmup=1, iters=iters)
        s_sep, y_s = timed(sep_fn, x, warmup=1, iters=iters)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_s),
                                   atol=2e-3, rtol=2e-3)
        flops = 2 * t * (k * r + r * m + nnzb * bs * bs)
        hbm = _site_bytes(t, k, m, r, bs, nnzb, itemsize=4)
        roofline = {
            path: max(flops / PEAK_FLOPS, hbm[path] / HBM_BW)
            for path in ("separate", "fused")
        }
        rows.append({
            "phase": phase, "t": t, "k": k, "m": m, "r": r,
            "block": bs, "nnz_blocks": nnzb,
            "measured_us": {"separate": round(s_sep * 1e6, 1),
                            "fused": round(s_fused * 1e6, 1)},
            "flops": flops,
            "hbm_bytes": hbm,
            "hbm_bytes_saved": hbm["separate"] - hbm["fused"],
            "achieved_flops_per_s": {"separate": round(flops / s_sep),
                                     "fused": round(flops / s_fused)},
            "roofline_us_at_v5e": {path: round(s * 1e6, 3)
                                   for path, s in roofline.items()},
            "roofline_flops_per_s_at_v5e": {
                path: round(flops / roofline[path]) for path in roofline
            },
        })
    return rows


# ------------------------------------------------------------------- main ---


def main(steps: int = 30, requests: int = 8, max_new: int = 16,
         iters: int = 3, out: str = "BENCH_fused.json") -> dict:
    cfg = bench_arch()
    tr, state = train_salaad(cfg, steps=steps, scfg=salaad_cfg())
    slr_c, rep = hpa_keep_ratio(state.slr, tr.blocks, KEEP, kappa=0.7)

    payload = {
        "backend": jax.default_backend(),
        "interpret_kernels": ops._auto_interpret(),
        "nominal_hw": {"name": "v5e", "hbm_bytes_per_s": HBM_BW,
                       "peak_flops_per_s": PEAK_FLOPS},
        "engine_decode": engine_decode(cfg, tr, state, slr_c, rep,
                                       requests, max_new, iters),
        "decode_step": decode_step_latency(cfg, tr, state, slr_c,
                                           iters=10 * iters, reps=iters),
        "kernels": kernel_microbench(iters=iters),
    }
    Path(out).write_text(json.dumps(payload, indent=2))

    e = payload["engine_decode"]
    emit(
        f"fused/engine/keep={KEEP}", 0.0,
        f"factored={e['tok_per_s_factored']};bsr={e['tok_per_s_bsr']};"
        f"fused={e['tok_per_s_fused']};"
        f"fused_vs_factored={e['speedup_fused_vs_factored']}x",
    )
    d = payload["decode_step"]
    emit("fused/decode_step", d["step_us_fused"],
         f"factored_us={d['step_us_factored']};"
         f"speedup={d['speedup_fused_vs_factored']}x")
    for kr in payload["kernels"]:
        emit(
            f"fused/kernel/{kr['phase']}", kr["measured_us"]["fused"],
            f"separate_us={kr['measured_us']['separate']};"
            f"hbm_saved={kr['hbm_bytes_saved']}B;"
            f"roofline_fused_us={kr['roofline_us_at_v5e']['fused']}",
        )
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_fused.json")
    a = ap.parse_args()
    main(steps=10 if a.quick else 30, requests=4 if a.quick else 8,
         max_new=8 if a.quick else 24, iters=2 if a.quick else 5, out=a.out)
