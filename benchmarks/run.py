"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Use --quick for the CI-scale
run (fewer steps), --only <name> to run a single benchmark.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer steps")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    steps = 30 if args.quick else 60

    from . import (
        appA_rpca,
        fig1_embedding,
        fig2_overhead,
        fig3_elastic,
        fig4_kappa,
        roofline,
        serve_throughput,
        table1_pretrain,
        table3_ablation,
        table10_freq,
    )

    benches = {
        "table1": lambda: table1_pretrain.main(steps),
        "fig1": lambda: fig1_embedding.main(max(steps - 10, 20)),
        "fig2": lambda: fig2_overhead.main(),
        "fig3": lambda: fig3_elastic.main(steps),
        "fig4": lambda: fig4_kappa.main(max(steps - 10, 20)),
        "table3": lambda: table3_ablation.main(max(steps // 2, 20)),
        "table10": lambda: table10_freq.main(max(steps // 2, 20)),
        "appA": lambda: appA_rpca.main(max(steps // 2, 20)),
        "serve": lambda: serve_throughput.main(max(steps // 2, 10)),
        "roofline": roofline.main,
    }
    failures = 0
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name}/FAILED,0.0,see-traceback")
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
