"""Tables 3/4/7-9 (scaled down): sensitivity to (dalpha, dbeta, rho).

Expected paper trends: larger dalpha/dbeta/rho => more compression (fewer
SLR params) at worse PPL; rho behaves like a global step-size multiplier.
"""
from __future__ import annotations

from repro.core.admm import slr_param_count
from repro.core.controller import ControllerConfig

from .common import bench_arch, emit, eval_loss, ppl, salaad_cfg, train_salaad


def run(steps: int = 40) -> list[dict]:
    cfg = bench_arch()
    rows = []

    def one(tag, rho_constant=5.0, dalpha=0.1, dbeta=0.003):
        scfg = salaad_cfg(rho_constant=rho_constant)
        scfg = type(scfg)(
            **{
                **scfg.__dict__,
                "controller": ControllerConfig(dalpha=dalpha, dbeta=dbeta),
            }
        )
        tr, state = train_salaad(cfg, steps=steps, scfg=scfg)
        surr = tr.surrogate(state)
        rows.append(
            {
                "tag": tag,
                "ppl_x": ppl(eval_loss(state.params, cfg)),
                "ppl_ls": ppl(eval_loss(surr, cfg)),
                "slr_params": slr_param_count(state.slr, tr.blocks)["_total"],
            }
        )

    for db in (0.001, 0.01, 0.1):
        one(f"dbeta={db}", dbeta=db)
    for da in (0.05, 0.2, 0.8):
        one(f"dalpha={da}", dalpha=da)
    for rc in (1.0, 5.0, 25.0):
        one(f"rho_c={rc}", rho_constant=rc)
    return rows


def main(steps: int = 40):
    for r in run(steps):
        emit(
            f"table3/{r['tag']}", 0.0,
            f"ppl_x={r['ppl_x']:.2f};ppl_ls={r['ppl_ls']:.2f};slr_params={r['slr_params']}",
        )


if __name__ == "__main__":
    main()
