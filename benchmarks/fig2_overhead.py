"""Fig. 2 (adapted): wall-clock breakdown — stage-1 train step vs stage-2
ADMM update vs checkpoint save. Paper claim: ADMM dominates the overhead and
amortizes as 1/K; with K=40 the overhead is a few percent.
"""
from __future__ import annotations

import tempfile
import time

import jax

from repro.train import checkpoint

from .common import bench_arch, emit, make_data, salaad_cfg, timed, train_salaad


def run(steps: int = 6) -> dict:
    cfg = bench_arch()
    scfg = salaad_cfg(update_every=1000)  # manual stage-2 timing below
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.optim.adam import AdamConfig

    # donate=False: timed() replays the same state, donated buffers would die
    tr = Trainer(
        cfg,
        TrainerConfig(total_steps=steps, salaad=scfg, adam=AdamConfig(lr=1e-3), donate=False),
    )
    state = tr.init(jax.random.PRNGKey(0))
    data = make_data(cfg)
    batch = data.batch(0)

    t_train, (state2, _) = timed(tr._train_step, state, batch, warmup=1, iters=5)
    t_admm, _ = timed(tr._admm_step, state2, warmup=1, iters=3)

    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        checkpoint.save(d, 0, state2)
        t_ckpt = time.perf_counter() - t0

    k = 40  # paper App. C
    overhead = t_admm / (k * t_train)
    return {
        "train_step_s": t_train,
        "admm_step_s": t_admm,
        "ckpt_save_s": t_ckpt,
        "admm_overhead_at_K40": overhead,
    }


def main(steps: int = 6):
    r = run(steps)
    emit("fig2/train_step", r["train_step_s"] * 1e6, "stage-1 guided learning")
    emit("fig2/admm_step", r["admm_step_s"] * 1e6, "stage-2 proximal sweep")
    emit("fig2/ckpt_save", r["ckpt_save_s"] * 1e6, "atomic checkpoint")
    emit(
        "fig2/overhead", 0.0,
        f"admm_overhead_at_K40={r['admm_overhead_at_K40']*100:.1f}%",
    )


if __name__ == "__main__":
    main()
