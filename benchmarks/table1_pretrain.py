"""Table 1 (scaled down): SALAAD X / L+S / HPA-compressed vs baselines.

Reports eval PPL + deployable parameter count for: full-rank, LoRA, SLTrain,
GaLore, and the three SALAAD variants. The paper's ordering to reproduce:
SALAAD X and L+S beat full-rank; HPA-compressed stays competitive at a
SLTrain-like budget.
"""
from __future__ import annotations

import jax

from repro.baselines import FullRank, GaLoreAdam, LoRAReparam, SLTrainReparam, train_baseline
from repro.core.admm import slr_param_count, surrogate_params
from repro.core.hpa import hpa_keep_ratio, removable_params
from repro.optim.adam import AdamConfig

from .common import bench_arch, emit, eval_loss, make_data, param_count, ppl, train_salaad


def run(steps: int = 60) -> list[dict]:
    cfg = bench_arch()
    data = make_data(cfg)
    key = jax.random.PRNGKey(0)
    rows = []

    for method in (
        FullRank(),
        LoRAReparam(rank=4),
        SLTrainReparam(rank_ratio=0.15, density=0.05),
        GaLoreAdam(rank=8, refresh_every=20),
    ):
        ev, n, _ = train_baseline(method, cfg, data, steps, key, AdamConfig(lr=1e-3))
        rows.append({"method": method.name, "ppl": ppl(ev), "params": n})

    tr, state = train_salaad(cfg, steps=steps)
    ev_x = eval_loss(state.params, cfg)
    rows.append({"method": "salaad-X", "ppl": ppl(ev_x), "params": param_count(state.params)})

    surr = tr.surrogate(state)
    ev_s = eval_loss(surr, cfg)
    slr_n = slr_param_count(state.slr, tr.blocks)["_total"]
    other = param_count(state.params) - sum(
        b.num_blocks * b.matrix_params for b in tr.blocks
    )
    rows.append({"method": "salaad-L+S", "ppl": ppl(ev_s), "params": slr_n + other})

    comp_slr, rep = hpa_keep_ratio(state.slr, tr.blocks, keep_ratio=0.6, kappa=0.7)
    comp_params = surrogate_params(state.params, comp_slr, tr.blocks)
    ev_c = eval_loss(comp_params, cfg)
    rows.append(
        {"method": "salaad-HPA(0.6,k=0.7)", "ppl": ppl(ev_c), "params": rep["params_after"] + other}
    )
    return rows


def main(steps: int = 60):
    for r in run(steps):
        emit(f"table1/{r['method']}", 0.0, f"ppl={r['ppl']:.2f};params={r['params']}")


if __name__ == "__main__":
    main()
