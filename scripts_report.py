#!/usr/bin/env python
"""Render the §Roofline table + §Perf before/after into EXPERIMENTS.md
from results/dryrun (optimized) and results/dryrun_baseline (baseline)."""
import glob
import json
import os
import re

HBM_LIMIT = 16e9


def load(dirname):
    out = {}
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        d = json.load(open(f))
        for r in d.get("records", []):
            mesh = "multi" if "pod" in str(r.get("mesh")) or r["devices"] == 512 else "single"
            mesh = "multi" if os.path.basename(f).startswith("multi_") else "single"
            out[(mesh, r["arch"], r["shape"])] = r
    return out


def table(recs, mesh="single"):
    hdr = (
        "| arch | shape | compute_s | memory_s | collective_s | dominant | useful | peak GB | fits |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for (m, a, s), r in sorted(recs.items()):
        if m != mesh:
            continue
        peak = (r.get("peak_memory_per_device") or 0) / 1e9
        fits = "yes" if peak * 1e9 < HBM_LIMIT else "**no**"
        rows.append(
            f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {peak:.1f} | {fits} |"
        )
    return hdr + "\n".join(rows)


def before_after(base, opt):
    hdr = (
        "| cell | metric | baseline | optimized | Δ |\n|---|---|---|---|---|\n"
    )
    rows = []
    for key in sorted(opt):
        if key[0] != "single" or key not in base:
            continue
        b, o = base[key], opt[key]
        interesting = (
            abs(o["collective_s"] - b["collective_s"]) / max(b["collective_s"], 1e-9) > 0.05
            or abs((o.get("peak_memory_per_device") or 0) - (b.get("peak_memory_per_device") or 0))
            > 0.5e9
            or abs(o["compute_s"] - b["compute_s"]) / max(b["compute_s"], 1e-9) > 0.05
        )
        if not interesting:
            continue
        name = f"{key[1]}/{key[2]}"
        for metric, fmt in (
            ("compute_s", "{:.3f}"),
            ("memory_s", "{:.3f}"),
            ("collective_s", "{:.3f}"),
            ("peak_memory_per_device", "{:.1f}GB"),
        ):
            bv = b.get(metric) or 0
            ov = o.get(metric) or 0
            if metric == "peak_memory_per_device":
                bv, ov = bv / 1e9, ov / 1e9
            if bv == 0 and ov == 0:
                continue
            delta = (ov - bv) / bv * 100 if bv else float("nan")
            if abs(delta) < 2:
                continue
            rows.append(
                f"| {name} | {metric} | {fmt.format(bv)} | {fmt.format(ov)} | {delta:+.0f}% |"
            )
    return hdr + "\n".join(rows)


def main():
    opt = load("results/dryrun")
    base = load("results/dryrun_baseline")
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    roof = table(opt, "single")
    n_multi = sum(1 for k in opt if k[0] == "multi")
    n_single = sum(1 for k in opt if k[0] == "single")
    roof += (
        f"\n\nSingle-pod (16×16=256) cells above: **{n_single}**. "
        f"Multi-pod (2×16×16=512) compiles passed: **{n_multi}** "
        "(scanned program; compile success + memory fit is the pass criterion, "
        "see results/dryrun/multi_*.json)."
    )
    text = text.replace("TABLE-PLACEHOLDER-ROOFLINE", roof)
    text = text.replace("TABLE-PLACEHOLDER-BASELINE-VS-OPT", before_after(base, opt))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated:", n_single, "single +", n_multi, "multi cells")


if __name__ == "__main__":
    main()
