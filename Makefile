PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-sharded bench-smoke bench bench-latency bench-prefill bench-prefix bench-spec bench-elastic bench-fused bench-obs bench-shard bench-adapters serve-demo serve-adapters-demo

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# tensor-parallel parity matrix on 8 forced host devices (the env var must
# be set before the first jax import, so it lives on the pytest invocation,
# not inside the test module)
test-sharded:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PYTHON) -m pytest tests/test_sharded_serving.py -q

# quick serving-throughput benchmark (interpret-mode kernels on CPU)
bench-smoke:
	$(PYTHON) -m benchmarks.serve_throughput --quick

# latency SLO harness: paged vs slot-padded engine under Poisson arrivals
bench-latency:
	$(PYTHON) -m benchmarks.serve_latency --quick

# chunked prefill: mixed long/short-prompt workload, one-shot vs chunked
# prefill on the paged engine (short-request tail ITL is the headline)
bench-prefill:
	$(PYTHON) -m benchmarks.serve_latency --mixed --quick

# prefix sharing: radix prompt cache on vs off at equal KV budget
# (shared-prefix burst TTFT, multi-turn hit rate, eviction-resume reattach)
bench-prefix:
	$(PYTHON) -m benchmarks.serve_prefix --quick

# speculative decode: elastic low-budget draft vs the paged engine
bench-spec:
	$(PYTHON) -m benchmarks.serve_spec --quick

# elastic tiers: per-tier tok/s, tier-switch latency (no re-jit on switch),
# admitted rate under page pressure with the tier controller on vs off
bench-elastic:
	$(PYTHON) -m benchmarks.serve_elastic --quick

# fused SLR kernel: one-pass low-rank+sparse vs separate calls — engine
# tok/s, jitted decode-step latency, per-kernel HBM bytes + roofline
bench-fused:
	$(PYTHON) -m benchmarks.kernel_bench --quick

# telemetry overhead: metrics + tracing on vs off on one engine — streams
# must be bitwise identical, tok/s overhead target < 2% (BENCH_obs.json).
# Runs the FULL 60m model (not --quick): the overhead must be weighed
# against realistic per-tick device work for the percentage to mean much
bench-obs:
	$(PYTHON) -m benchmarks.serve_obs

# tensor-parallel serving: tok/s + per-device KV pool bytes at mesh 1/2/4
# under an equal total KV budget (the script forces 8 host devices itself)
bench-shard:
	$(PYTHON) -m benchmarks.serve_shard --quick

# multi-tenant adapters: one AdapterBank engine (batched heterogeneous-
# adapter kernel) vs a per-tenant engine fleet at equal aggregate KV budget
bench-adapters:
	$(PYTHON) -m benchmarks.serve_adapters --quick

# full scaled-down paper benchmark suite
bench:
	$(PYTHON) -m benchmarks.run --quick

# elastic-deployment spectrum: ONE engine serving all three budget tiers
serve-demo:
	$(PYTHON) -m repro.launch.serve --arch salaad_llama_60m --reduced \
	    --keep-ratios 1.0,0.6,0.3 --fmt factored --requests 8 \
	    --tier-policy pressure

# multi-tenant spectrum: ONE engine serving 8 registered adapters over a
# shared base, with a 4-row device pool exercising LRU swaps
serve-adapters-demo:
	$(PYTHON) -m repro.launch.serve --arch salaad_llama_60m --reduced \
	    --fmt fused --adapters 8 --max-resident-adapters 4 --requests 16
