"""Weight-space reparameterizations implementing the paper's baselines.

Each baseline stores TRANSFORMED parameters and materializes the model's
weight tree for the forward pass; autodiff flows through ``materialize``:

  * FullRank        — identity (the paper's vanilla baseline)
  * LoRAReparam     — W = sg(W0) + (alpha/r) B A; only (A, B) receive grads
                      (LoRA used as a pretraining baseline, as in Table 1)
  * SLTrainReparam  — W = B A + scatter(s_values at fixed random support)
                      (SLTrain: fixed rank + fixed sparse support chosen
                      before training — exactly the layer-agnostic scheduling
                      SALAAD's I-controller replaces)
  * GaLoreAdam      — full-rank W, but Adam moments live in a rank-r
                      projected gradient space; the projector is refreshed
                      from the gradient's randomized SVD every T steps.

Selection reuses core/selection.py so every baseline touches exactly the
blocks SALAAD would, on any architecture.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.rsvd import randomized_svd
from ..core.selection import BlockInfo, SelectionConfig, select_blocks
from ..optim.adam import AdamConfig, adam_update, init_adam


def _set_leaf(params, path, value):
    if not path:
        return value
    p = path[0]
    key = getattr(p, "key", getattr(p, "idx", getattr(p, "name", None)))
    if isinstance(params, dict):
        out = dict(params)
        out[key] = _set_leaf(params[key], path[1:], value)
        return out
    raise TypeError(type(params))


def _get_leaf(params, path):
    for p in path:
        key = getattr(p, "key", getattr(p, "idx", getattr(p, "name", None)))
        params = params[key]
    return params


class FullRank:
    name = "full-rank"

    def init(self, params, key):
        return {"base": params}

    def materialize(self, t):
        return t["base"]

    def param_count(self, t):
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(t["base"]))


@dataclass
class LoRAReparam:
    rank: int = 8
    alpha: float = 16.0
    selection: SelectionConfig = None
    name = "lora"

    def init(self, params, key):
        sel = self.selection or SelectionConfig(min_dim=16)
        blocks = select_blocks(params, sel)
        adapters = {}
        for i, info in enumerate(blocks):
            k = jax.random.fold_in(key, i)
            r = min(self.rank, info.n, info.m)
            adapters[info.name] = {
                "a": jax.random.normal(k, (*info.stack_dims, r, info.m)) * 0.01,
                "b": jnp.zeros((*info.stack_dims, info.n, r)),
            }
        return {"base": params, "adapters": adapters, "_blocks": blocks}

    def materialize(self, t):
        params = t["base"]
        for info in t["_blocks"]:
            ad = t["adapters"][info.name]
            w0 = jax.lax.stop_gradient(_get_leaf(params, info.path))
            r = ad["a"].shape[-2]
            w = w0 + (self.alpha / r) * (ad["b"] @ ad["a"]).astype(w0.dtype)
            params = _set_leaf(params, info.path, w)
        return params

    def param_count(self, t):
        # deployable params: base + adapters (they merge at deploy time)
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(t["base"]))


@dataclass
class SLTrainReparam:
    """Fixed rank-r + fixed random support density (Han et al. 2024 style)."""

    rank_ratio: float = 0.15
    density: float = 0.05
    selection: SelectionConfig = None
    name = "sltrain"

    def init(self, params, key):
        sel = self.selection or SelectionConfig(min_dim=16)
        blocks = select_blocks(params, sel)
        reps = {}
        supports = {}
        new_params = params
        for i, info in enumerate(blocks):
            k = jax.random.fold_in(key, i)
            n, m = info.n, info.m
            r = max(2, int(self.rank_ratio * min(n, m)))
            nnz = max(4, int(self.density * n * m))
            idx = jax.random.choice(
                jax.random.fold_in(k, 1), n * m, (nnz,), replace=False
            ).astype(jnp.int32)
            stack = info.stack_dims
            reps[info.name] = {
                "b": jax.random.normal(k, (*stack, n, r)) / np.sqrt(r),
                "a": jax.random.normal(jax.random.fold_in(k, 2), (*stack, r, m)) / np.sqrt(m),
                "s_values": jnp.zeros((*stack, nnz)),
            }
            supports[info.name] = jnp.broadcast_to(idx, (*stack, nnz))
            # base leaf replaced at materialize; drop it to zeros to save memory
            new_params = _set_leaf(new_params, info.path, jnp.zeros(info.shape, jnp.float32) * 0)
        return {"base": new_params, "reps": reps, "_blocks": blocks, "_support": supports}

    def materialize(self, t):
        params = t["base"]
        for info in t["_blocks"]:
            rep = t["reps"][info.name]
            n, m = info.n, info.m
            low = rep["b"] @ rep["a"]

            def scatter(vals, idx):
                return jnp.zeros((n * m,), vals.dtype).at[idx].add(vals).reshape(n, m)

            fn = scatter
            for _ in info.stack_dims:
                fn = jax.vmap(fn)
            sparse_part = fn(rep["s_values"], t["_support"][info.name])
            w = (low + sparse_part).astype(_get_leaf(params, info.path).dtype)
            params = _set_leaf(params, info.path, w)
        return params

    def param_count(self, t):
        total = 0
        covered = {b.name for b in t["_blocks"]}
        for info in t["_blocks"]:
            rep = t["reps"][info.name]
            total += rep["b"].size + rep["a"].size + 2 * rep["s_values"].size  # values + idx
        for path, leaf in jax.tree_util.tree_leaves_with_path(t["base"]):
            from ..core.selection import path_str

            if path_str(path) not in covered:
                total += int(np.prod(leaf.shape))
        return int(total)


@dataclass
class GaLoreAdam:
    """Gradient low-rank projection (Zhao et al. 2024 style) around Adam."""

    rank: int = 16
    refresh_every: int = 50
    selection: SelectionConfig = None
    adam: AdamConfig = None
    name = "galore"

    def init_state(self, params, key):
        sel = self.selection or SelectionConfig(min_dim=16)
        self.blocks = select_blocks(params, sel)
        projectors = {}
        moments = {}
        for i, info in enumerate(self.blocks):
            r = min(self.rank, info.n, info.m)
            k = jax.random.fold_in(key, i)
            q, _ = jnp.linalg.qr(jax.random.normal(k, (info.n, r)))
            projectors[info.name] = jnp.broadcast_to(q, (*info.stack_dims, info.n, r))
            moments[info.name] = {
                "mu": jnp.zeros((*info.stack_dims, r, info.m)),
                "nu": jnp.zeros((*info.stack_dims, r, info.m)),
            }
        dense = init_adam(params)  # for non-selected leaves
        return {"proj": projectors, "mom": moments, "dense": dense, "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, step: int):
        cfg = self.adam or AdamConfig()
        count = state["count"] + 1
        b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
        new_params = params
        new_proj = dict(state["proj"])
        new_mom = dict(state["mom"])
        sel_names = {b.name for b in self.blocks}
        for info in self.blocks:
            g = _get_leaf(grads, info.path).astype(jnp.float32)
            p = state["proj"][info.name]
            if step and step % self.refresh_every == 0:
                # refresh projector from the current gradient's top subspace
                def topq(gm, key):
                    u, s, vt = randomized_svd(gm, key, p.shape[-1])
                    return u

                fn = topq
                keys = jax.random.PRNGKey(step)
                if info.stack_dims:
                    nb = int(np.prod(info.stack_dims))
                    fn = jax.vmap(topq)
                    p = fn(
                        g.reshape(nb, info.n, info.m), jax.random.split(keys, nb)
                    ).reshape(*info.stack_dims, info.n, p.shape[-1])
                else:
                    p = topq(g, keys)
                new_proj[info.name] = p
            # project, adam in low-rank space, project back
            gp = jnp.swapaxes(p, -1, -2) @ g            # (r, m)
            mom = state["mom"][info.name]
            mu = cfg.b1 * mom["mu"] + (1 - cfg.b1) * gp
            nu = cfg.b2 * mom["nu"] + (1 - cfg.b2) * gp * gp
            step_lr = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
            upd = p @ step_lr                            # back to (n, m)
            w = _get_leaf(params, info.path)
            new_params = _set_leaf(
                new_params, info.path, (w.astype(jnp.float32) - cfg.lr * upd).astype(w.dtype)
            )
            new_mom[info.name] = {"mu": mu, "nu": nu}
        # dense Adam for everything else
        from ..core.selection import path_str

        def mask_grad(path, gleaf):
            return jnp.zeros_like(gleaf) if path_str(path) in sel_names else gleaf

        masked = jax.tree_util.tree_map_with_path(mask_grad, grads)
        dense_params, dense_state = adam_update(masked, state["dense"], new_params, cfg)
        return dense_params, {
            "proj": new_proj, "mom": new_mom, "dense": dense_state, "count": count
        }


def train_baseline(
    method,
    arch_cfg,
    data,
    steps: int,
    key,
    adam_cfg: AdamConfig = AdamConfig(lr=1e-3, grad_clip=1.0),
    eval_batches: int = 4,
):
    """Train a baseline and return (final_eval_loss, param_count, losses)."""
    from ..models import model as model_lib

    params = model_lib.init_params(arch_cfg, key)

    if isinstance(method, GaLoreAdam):
        state = method.init_state(params, key)
        losses = []

        def loss_fn(p, batch):
            return model_lib.loss_fn(p, batch, arch_cfg)[0]

        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        for s in range(steps):
            batch = data.batch(s)
            loss, grads = grad_fn(params, batch)
            params, state = method.update(grads, state, params, s)
            losses.append(float(loss))
        eval_loss = float(
            np.mean([float(loss_fn(params, data.batch(50_000 + i))) for i in range(eval_batches)])
        )
        n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        return eval_loss, n_params, losses

    t = method.init(params, key)
    static_blocks = t.pop("_blocks", None)
    static_support = t.pop("_support", None)

    def loss_fn(tp, batch):
        full = t_materialize(tp)
        return model_lib.loss_fn(full, batch, arch_cfg)[0]

    def t_materialize(tp):
        tp2 = dict(tp)
        if static_blocks is not None:
            tp2["_blocks"] = static_blocks
        if static_support is not None:
            tp2["_support"] = jax.tree.map(jax.lax.stop_gradient, static_support)
        return method.materialize(tp2)

    opt = init_adam(t)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    jadam = jax.jit(lambda g, o, p: adam_update(g, o, p, adam_cfg))
    losses = []
    for s in range(steps):
        batch = data.batch(s)
        loss, grads = grad_fn(t, batch)
        t, opt = jadam(grads, opt, t)
        losses.append(float(loss))
    eval_loss = float(
        np.mean([float(loss_fn(t, data.batch(50_000 + i))) for i in range(eval_batches)])
    )
    if static_blocks is not None:
        t["_blocks"] = static_blocks
    if static_support is not None:
        t["_support"] = static_support
    return eval_loss, method.param_count(t), losses
