"""Baselines the paper compares against (Table 1), as weight-space
reparameterizations + optimizer transforms over the SAME model code."""
from .reparam import (  # noqa: F401
    FullRank,
    GaLoreAdam,
    LoRAReparam,
    SLTrainReparam,
    train_baseline,
)
