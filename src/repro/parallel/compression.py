"""Gradient compression for the data-parallel all-reduce.

int8 quantized all-reduce with per-tensor scales, shard_map-based: each data
shard quantizes its local gradient, all-reduces the int32-accumulated values
(psum of int8 payloads upcast to int32 — exact), and dequantizes with the
psum'd max-scale. Wire bytes drop 4x (f32) / 2x (bf16) on the slowest link
(cross-pod DCN), at a quantization error bounded by scale/127 per element.

Off by default; ``make_compressed_grad_fn`` wraps a per-example loss into a
grad function with the compressed DP reduction, and the error-feedback
variant keeps a residual so the bias does not accumulate across steps
(Seide et al. 2014; tested for convergence-neutrality in
tests/test_runtime.py::TestGradCompression).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads: Any, axis_name: str, residuals: Any = None):
    """int8-quantized psum over ``axis_name`` (call inside shard_map).

    With ``residuals`` (same pytree as grads), applies error feedback: each
    worker adds its previous quantization error before quantizing and carries
    the new error forward, so compression bias does not accumulate.
    Returns (mean_grads, new_residuals) when residuals is not None.
    """

    def one(g, r=None):
        g32 = g.astype(jnp.float32)
        if r is not None:
            g32 = g32 + r
        q, scale = quantize_int8(g32)
        # exact integer accumulation; scales reduced with max (conservative)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_max = jax.lax.pmax(scale, axis_name)
        n = jax.lax.psum(jnp.ones(()), axis_name)
        out = (acc.astype(jnp.float32) * scale_max / n).astype(g.dtype)
        new_r = g32 - dequantize_int8(q, scale) if r is not None else None
        return out, new_r

    if residuals is None:
        return jax.tree.map(lambda g: one(g)[0], grads)
    pairs = jax.tree.map(one, grads, residuals)
    is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
    return (
        jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair),
        jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair),
    )


def make_compressed_grad_fn(loss_fn, mesh: Mesh, data_axis: str = "data"):
    """grads(params, batch) with an int8 DP all-reduce via shard_map.

    ``loss_fn(params, local_batch) -> scalar`` is evaluated per data shard on
    its batch slice; local grads are quantize-psum'd across the data axis.
    """

    def local_grads(params, batch):
        g = jax.grad(loss_fn)(params, batch)
        return compressed_psum_tree(g, data_axis)

    def grad_fn(params, batch):
        from jax.experimental.shard_map import shard_map

        batch_specs = jax.tree.map(lambda _: P(data_axis), batch)
        param_specs = jax.tree.map(lambda _: P(), params)
        fn = shard_map(
            local_grads,
            mesh=mesh,
            in_specs=(param_specs, batch_specs),
            out_specs=param_specs,
            check_rep=False,
        )
        return fn(params, batch)

    return grad_fn
