"""Sharding rules: logical-axis constraints + parameter partition specs.

The mesh has axes ("data", "model") single-pod or ("pod", "data", "model")
multi-pod. Batch-like logical axes map to ("pod", "data") jointly so the pod
axis folds into data parallelism (cross-pod traffic = gradient all-reduce
only, which is DCN-friendly); "model" carries TP/EP.

``constrain`` is a no-op outside a mesh context, so every model runs
unmodified on a single CPU device (tests) and sharded under the production
mesh (dry-run/training) with the same code.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "constrain",
    "param_spec",
    "param_sharding_tree",
    "logical_to_mesh",
    "ServingMesh",
    "parse_mesh_spec",
    "serving_param_spec",
    "serving_shardings",
    "kv_cache_shardings",
    "current_mesh",
    "dp_axes",
    "dp_size",
    "batch_shardings",
]


def _current_mesh() -> Mesh | None:
    mesh = jax.sharding.get_abstract_mesh() if hasattr(jax.sharding, "get_abstract_mesh") else None
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m and not m.empty:
            return m
    except Exception:
        pass
    return None


def current_mesh() -> Mesh | None:
    """The active physical mesh (``with mesh:`` context), or None."""
    return _current_mesh()


def dp_axes(mesh: Mesh):
    """The batch-carrying mesh axes present in ``mesh`` (pod folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names]))


def batch_shardings(specs: dict, mesh: Mesh) -> dict:
    """Leading-dim (batch) shardings for a dict of abstract batch arrays."""
    dp = dp_axes(mesh)
    dpn = dp_size(mesh)
    out = {}
    for k, v in specs.items():
        ax0 = dp if v.shape[0] % dpn == 0 else None
        rest = (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(ax0, *rest))
    return out


def logical_to_mesh(axis: str | None, mesh: Mesh) -> Any:
    """Map a logical axis name to mesh axes present in ``mesh``."""
    if axis is None:
        return None
    names = mesh.axis_names
    if axis == "data":
        got = tuple(a for a in ("pod", "data") if a in names)
        return got if len(got) > 1 else (got[0] if got else None)
    if axis in names:
        return axis
    return None


def constrain(x: jax.Array, logical: tuple[str | None, ...]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = P(*(logical_to_mesh(a, mesh) for a in logical))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------- parameter rules --

# (path regex, ndim) -> logical spec for the trailing dims; leading stacked
# dims are unsharded (None). First match wins.
_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    # MoE experts (E, d_in, d_out): expert-parallel over model + FSDP over
    # data on the first matrix dim. The shard_map EP path declares
    # P('model', None, None), so entering it all-gathers the 'data' shards —
    # exactly the FSDP weight gather, one layer at a time under the scan.
    (r"experts/(gate|up|w1|w3)$", ("model", "data", None)),
    (r"experts/(down|w2)$", ("model", "data", None)),
    (r"router$", (None, None)),
    # attention: column-parallel QKV, row-parallel O (+ FSDP on the other dim)
    (r"(^|/)(q|k|v)$", ("data", "model")),
    (r"(^|/)o$", ("model", "data")),
    # MLP: column-parallel up/gate, row-parallel down
    (r"(gate|up)$", ("data", "model")),
    (r"down$", ("model", "data")),
    # SSM projections
    (r"in_proj$", ("data", "model")),
    (r"out_proj$", ("model", "data")),
    (r"(x_proj|dt_proj)$", ("data", "model")),
    # embeddings / output head: d_model over model, vocab REPLICATED.
    # Sharding vocab over 'data' collides with the batch axis of the logits
    # (both want 'data') and forces GSPMD to materialize full-vocab logits
    # (13 GB/device measured on olmo_1b); a replicated vocab slice costs
    # <=131 MB/device (internvl2) and keeps logits sharded (data, :, model).
    (r"embedding$", (None, "model")),
    (r"lm_head/w$", (None, "model")),
    # generic fallbacks for any other 2-D matrix
    (r".*", ("data", "model")),
]


def _rule_tail(name: str) -> tuple[str | None, ...]:
    for pat, logical in _RULES:
        if re.search(pat, name):
            return logical
    return ("data", "model")


def _resolve_tail(
    shape: tuple[int, ...],
    tail: tuple[str | None, ...],
    mesh: Mesh,
    *,
    drop_data: bool = False,
) -> P:
    """Resolve a logical tail spec against ``mesh``: leading stacked dims are
    unsharded, axes that don't divide the dim evenly are dropped (replicate),
    and ``drop_data`` removes the FSDP-style 'data' weight sharding (serving
    keeps weights replicated across data replicas; batch rides 'data')."""
    n_stack = len(shape) - len(tail)
    full = (None,) * n_stack + tuple(tail)
    resolved = []
    for dim, ax in zip(shape, full):
        if drop_data and ax == "data":
            ax = None
        mesh_ax = logical_to_mesh(ax, mesh)
        if mesh_ax is None:
            resolved.append(None)
            continue
        size = (
            int(np.prod([mesh.shape[a] for a in mesh_ax]))
            if isinstance(mesh_ax, tuple)
            else mesh.shape[mesh_ax]
        )
        resolved.append(mesh_ax if dim % size == 0 else None)
    return P(*resolved)


def param_spec(name: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for a parameter leaf, by path + shape."""
    if len(shape) < 2:
        return P()  # vectors replicated
    return _resolve_tail(shape, _rule_tail(name), mesh)


def param_sharding_tree(params: Any, mesh: Mesh) -> Any:
    """NamedSharding tree matching ``params`` (works on ShapeDtypeStructs)."""
    from repro.core.selection import path_str

    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path_str(path), tuple(leaf.shape), mesh))

    return jax.tree_util.tree_map_with_path(one, params)


# ------------------------------------------------------- serving shardings --

# SLRLinear pytree fields holding index-addressed sparse tables. Their row /
# column ids are global, so a partition by array position is meaningless —
# the tables replicate, and GSPMD reshards their dense scatter on use.
_SLR_TABLE_FIELDS = frozenset({"s_coo", "s_bsr", "s_stack"})


def serving_param_spec(name: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Serving-time PartitionSpec for one param leaf (dense or inside an
    ``SLRLinear``).

    Differences from the training rules in :func:`param_spec`:

    * weights shard over 'model' only — 'data' carries the request batch, and
      FSDP-style weight sharding would all-gather weights every decode tick;
    * ``SLRLinear`` factors follow the dense weight they replace: ``p`` takes
      the row (contraction) axis — sharded over 'model' at row-parallel sites
      (o/down) so x@p partial-sums exactly like x@W — and ``vt`` takes the
      column axis — 'model' at column-parallel sites (q/k/v/gate/up); the
      rank dim is never sharded;
    * sparse tables (COO / block-CSR / BsrStack) replicate.
    """
    parts = name.split("/")
    last = parts[-1]
    if last in ("p", "vt") and len(parts) > 1:
        if len(shape) < 2:
            return P()
        row, col = _rule_tail("/".join(parts[:-1]))[-2:]
        sub = (row, None) if last == "p" else (None, col)
        return _resolve_tail(shape, sub, mesh, drop_data=True)
    if any(f in parts for f in _SLR_TABLE_FIELDS):
        return P()
    if len(shape) < 2:
        return P()
    return _resolve_tail(shape, _rule_tail(name), mesh, drop_data=True)


def serving_shardings(params: Any, mesh: Mesh) -> Any:
    """NamedSharding tree matching a DeployedModel param tree (descends into
    SLRLinear / CooMatrix / BsrMatrix pytrees)."""
    from repro.core.selection import path_str

    def one(path, leaf):
        return NamedSharding(
            mesh, serving_param_spec(path_str(path), tuple(leaf.shape), mesh)
        )

    return jax.tree_util.tree_map_with_path(one, params)


def kv_cache_shardings(cache: Any, mesh: Mesh) -> Any:
    """NamedSharding tree for an LMCache / PagedKVCache.

    Payload pools — (L, B, H, S, D) slot caches, (L, pages, H, bs, D) paged
    pools, and their (L, pages, H, bs, 1) int8 scales — shard the KV-head
    axis (dim 2) over 'model'. Everything else (block tables, lengths) is
    host bookkeeping: replicated, so the BlockAllocator / prefix cache / CoW
    logic never sees the mesh.
    """
    model_n = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1

    def one(leaf):
        s = tuple(leaf.shape)
        if len(s) == 5 and model_n > 1 and s[2] % model_n == 0:
            return NamedSharding(mesh, P(None, None, "model", None, None))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, cache)


# --------------------------------------------------------------- ServingMesh --


def parse_mesh_spec(spec: str) -> dict[str, int]:
    """Parse ``"model=N,data=M"`` → axis sizes (missing axes default to 1).

    Pure string validation — never touches jax device state, so
    ``EngineConfig.__post_init__`` can call it eagerly.
    """
    sizes = {"data": 1, "model": 1}
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        key, eq, val = tok.partition("=")
        key = key.strip()
        if not eq or key not in sizes:
            raise ValueError(
                f"mesh={spec!r} must be comma-separated axis=N terms with axis "
                f"in ('data', 'model'); got {tok!r}"
            )
        try:
            n = int(val)
        except ValueError:
            n = 0
        if n < 1:
            raise ValueError(f"mesh={spec!r}: size for {key!r} must be a positive int")
        sizes[key] = n
    return sizes


class ServingMesh:
    """The ("data", "model") device mesh for serving, plus its sharding rules.

    ONE axis-naming authority: ``launch.mesh`` and the serving engines build
    meshes only through here. 'model' carries tensor parallelism (head / ffn
    splits); 'data' (optionally preceded by 'pod') carries the batch. Used as
    a context manager it activates the mesh so :func:`constrain` and the
    shard_map-wrapped kernels see it at trace time.
    """

    AXES = ("data", "model")

    def __init__(self, mesh: Mesh):
        extra = [a for a in mesh.axis_names if a not in ("pod",) + self.AXES]
        if extra:
            raise ValueError(
                f"mesh axis names {tuple(mesh.axis_names)} must be drawn from "
                f"('pod', 'data', 'model'); got unknown {extra}"
            )
        self.mesh = mesh

    @classmethod
    def create(cls, *, data: int = 1, model: int = 1, devices=None) -> "ServingMesh":
        if devices is None:
            devices = jax.devices()
        need = data * model
        if need > len(devices):
            raise ValueError(
                f"mesh data*model={need} exceeds the {len(devices)} available "
                f"device(s)"
            )
        grid = np.asarray(devices[:need]).reshape(data, model)
        return cls(Mesh(grid, cls.AXES))

    @classmethod
    def from_spec(cls, spec: str, devices=None) -> "ServingMesh":
        sizes = parse_mesh_spec(spec)
        return cls.create(data=sizes["data"], model=sizes["model"], devices=devices)

    # ------------------------------------------------------------ topology --

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape["model"]) if "model" in self.mesh.axis_names else 1

    @property
    def data_size(self) -> int:
        return dp_size(self.mesh)

    @property
    def num_devices(self) -> int:
        return int(self.mesh.size)

    def describe(self) -> dict:
        """JSON-safe topology record (for ``engine_provenance`` / BENCH_*.json)."""
        return {
            "axis_names": list(self.mesh.axis_names),
            "shape": {a: int(self.mesh.shape[a]) for a in self.mesh.axis_names},
            "num_devices": int(self.mesh.size),
        }

    # ------------------------------------------------------------ shardings --

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def params_shardings(self, params: Any) -> Any:
        return serving_shardings(params, self.mesh)

    def cache_shardings(self, cache: Any) -> Any:
        return kv_cache_shardings(cache, self.mesh)

    # -------------------------------------------------------------- context --

    def __enter__(self) -> "ServingMesh":
        self.mesh.__enter__()
        return self

    def __exit__(self, *exc):
        return self.mesh.__exit__(*exc)
