"""Sharding rules: logical-axis constraints + parameter partition specs.

The mesh has axes ("data", "model") single-pod or ("pod", "data", "model")
multi-pod. Batch-like logical axes map to ("pod", "data") jointly so the pod
axis folds into data parallelism (cross-pod traffic = gradient all-reduce
only, which is DCN-friendly); "model" carries TP/EP.

``constrain`` is a no-op outside a mesh context, so every model runs
unmodified on a single CPU device (tests) and sharded under the production
mesh (dry-run/training) with the same code.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["constrain", "param_spec", "param_sharding_tree", "logical_to_mesh"]


def _current_mesh() -> Mesh | None:
    mesh = jax.sharding.get_abstract_mesh() if hasattr(jax.sharding, "get_abstract_mesh") else None
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m and not m.empty:
            return m
    except Exception:
        pass
    return None


def logical_to_mesh(axis: str | None, mesh: Mesh) -> Any:
    """Map a logical axis name to mesh axes present in ``mesh``."""
    if axis is None:
        return None
    names = mesh.axis_names
    if axis == "data":
        got = tuple(a for a in ("pod", "data") if a in names)
        return got if len(got) > 1 else (got[0] if got else None)
    if axis in names:
        return axis
    return None


def constrain(x: jax.Array, logical: tuple[str | None, ...]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = P(*(logical_to_mesh(a, mesh) for a in logical))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------- parameter rules --

# (path regex, ndim) -> logical spec for the trailing dims; leading stacked
# dims are unsharded (None). First match wins.
_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    # MoE experts (E, d_in, d_out): expert-parallel over model + FSDP over
    # data on the first matrix dim. The shard_map EP path declares
    # P('model', None, None), so entering it all-gathers the 'data' shards —
    # exactly the FSDP weight gather, one layer at a time under the scan.
    (r"experts/(gate|up|w1|w3)$", ("model", "data", None)),
    (r"experts/(down|w2)$", ("model", "data", None)),
    (r"router$", (None, None)),
    # attention: column-parallel QKV, row-parallel O (+ FSDP on the other dim)
    (r"(^|/)(q|k|v)$", ("data", "model")),
    (r"(^|/)o$", ("model", "data")),
    # MLP: column-parallel up/gate, row-parallel down
    (r"(gate|up)$", ("data", "model")),
    (r"down$", ("model", "data")),
    # SSM projections
    (r"in_proj$", ("data", "model")),
    (r"out_proj$", ("model", "data")),
    (r"(x_proj|dt_proj)$", ("data", "model")),
    # embeddings / output head: d_model over model, vocab REPLICATED.
    # Sharding vocab over 'data' collides with the batch axis of the logits
    # (both want 'data') and forces GSPMD to materialize full-vocab logits
    # (13 GB/device measured on olmo_1b); a replicated vocab slice costs
    # <=131 MB/device (internvl2) and keeps logits sharded (data, :, model).
    (r"embedding$", (None, "model")),
    (r"lm_head/w$", (None, "model")),
    # generic fallbacks for any other 2-D matrix
    (r".*", ("data", "model")),
]


def param_spec(name: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for a parameter leaf, by path + shape."""
    if len(shape) < 2:
        return P()  # vectors replicated
    for pat, logical in _RULES:
        if re.search(pat, name):
            tail = logical
            break
    n_stack = len(shape) - len(tail)
    full = (None,) * n_stack + tail
    # drop axes that don't divide the dim evenly -> replicate that dim
    resolved = []
    for dim, ax in zip(shape, full):
        mesh_ax = logical_to_mesh(ax, mesh)
        if mesh_ax is None:
            resolved.append(None)
            continue
        size = (
            int(np.prod([mesh.shape[a] for a in mesh_ax]))
            if isinstance(mesh_ax, tuple)
            else mesh.shape[mesh_ax]
        )
        resolved.append(mesh_ax if dim % size == 0 else None)
    return P(*resolved)


def param_sharding_tree(params: Any, mesh: Mesh) -> Any:
    """NamedSharding tree matching ``params`` (works on ShapeDtypeStructs)."""
    from repro.core.selection import path_str

    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path_str(path), tuple(leaf.shape), mesh))

    return jax.tree_util.tree_map_with_path(one, params)
