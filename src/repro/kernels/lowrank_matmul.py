"""Pallas TPU kernel: fused low-rank matmul  y = x @ (P @ Vt).

This is the SLR serving hot path: after SALAAD+HPA a weight is deployed as
``P (K, r)`` and ``Vt (r, M)`` with r << min(K, M). Computing ``x @ P @ Vt``
as two XLA matmuls materializes the intermediate ``t = x @ P`` (T, r) in HBM
and reads it back. This kernel keeps ``t`` in a VMEM scratch accumulator per
row-tile and streams it straight into the second matmul — one HBM round-trip
saved, both matmuls on the MXU.

Phase-based grid: for each row tile ``i`` the minor grid axis runs
``K_tiles`` accumulate phases (t += x_blk @ p_blk) followed by ``M_tiles``
emit phases (y_blk = t @ vt_blk). Index maps clamp so each operand stays
resident when unused; the output block for column j is only mapped (and
written) during its emit phase, so every y block is written exactly once.

VMEM budget per step (f32, defaults bm=bk=bn=128, r<=1024):
  x (128,128) + p (128,r) + vt (r,128) + y (128,128) + t (128,r)  < 1.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(x_ref, p_ref, vt_ref, y_ref, t_ref, *, k_tiles: int):
    phase = pl.program_id(1)

    @pl.when(phase < k_tiles)
    def accumulate():
        @pl.when(phase == 0)
        def init():
            t_ref[...] = jnp.zeros_like(t_ref)

        t_ref[...] += jnp.dot(
            x_ref[...].astype(jnp.float32),
            p_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(phase >= k_tiles)
    def emit():
        y_ref[...] = jnp.dot(
            t_ref[...], vt_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def lowrank_matmul_pallas(
    x: jax.Array,    # (T, K)
    p: jax.Array,    # (K, r)
    vt: jax.Array,   # (r, M)
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    t_dim, k_dim = x.shape
    r = p.shape[1]
    m_dim = vt.shape[1]
    assert p.shape[0] == k_dim and vt.shape[0] == r

    bm = min(bm, t_dim)
    bk = min(bk, k_dim)
    bn = min(bn, m_dim)

    # Zero-pad every dim to a tile multiple: out-of-bounds block padding is
    # undefined (NaN in interpret mode), and zeros are accumulation-neutral.
    def pad_to(a, mults):
        pads = [(0, -d % mult) for d, mult in zip(a.shape, mults)]
        return jnp.pad(a, pads) if any(p[1] for p in pads) else a

    x = pad_to(x, (bm, bk))
    p = pad_to(p, (bk, 1))
    t_pad, k_pad = x.shape
    vt = pad_to(vt, (1, bn))
    m_pad = vt.shape[1]

    k_tiles = k_pad // bk
    m_tiles = m_pad // bn
    grid = (t_pad // bm, k_tiles + m_tiles)

    kernel = functools.partial(_kernel, k_tiles=k_tiles)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # x: row tile i, K tile = phase while accumulating (clamped after)
            pl.BlockSpec((bm, bk), lambda i, ph: (i, jnp.minimum(ph, k_tiles - 1))),
            # p: K tile while accumulating; full r is resident
            pl.BlockSpec((bk, r), lambda i, ph: (jnp.minimum(ph, k_tiles - 1), 0)),
            # vt: column tile while emitting
            pl.BlockSpec(
                (r, bn), lambda i, ph: (0, jnp.clip(ph - k_tiles, 0, m_tiles - 1))
            ),
        ],
        out_specs=pl.BlockSpec(
            (bm, bn), lambda i, ph: (i, jnp.clip(ph - k_tiles, 0, m_tiles - 1))
        ),
        out_shape=jax.ShapeDtypeStruct((t_pad, m_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(x, p, vt)[:t_dim, :m_dim]
