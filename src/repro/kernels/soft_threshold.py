"""Pallas TPU kernel: element-wise soft thresholding (l1 prox).

The shrinkage op runs over every selected block every ADMM phase — on dense
residuals the size of the weight matrix — so it is bandwidth-bound. One VMEM
tile in, one out, fully vectorized on the VPU: the roofline is HBM bandwidth
and this kernel hits it by construction (1 load + 1 store per element).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)


def _kernel(x_ref, tau_ref, o_ref):
    x = x_ref[...]
    tau = tau_ref[0]
    o_ref[...] = jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0.0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def soft_threshold_pallas(
    x: jax.Array,
    tau: jax.Array | float,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Shrinkage of a 2-D array, tiled (block[0], block[1]) in VMEM."""
    n, m = x.shape
    bn = min(block[0], n)
    bm = min(block[1], m)
    tau_arr = jnp.asarray(tau, x.dtype).reshape(1)
    grid = (pl.cdiv(n, bn), pl.cdiv(m, bm))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),  # replicated scalar threshold
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=interpret,
    )(x, tau_arr)
