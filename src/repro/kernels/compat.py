"""jax version compatibility for the Pallas TPU kernels.

``pltpu.CompilerParams`` was named ``TPUCompilerParams`` before jax 0.5.x;
resolve whichever this container ships so the kernels import everywhere.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
