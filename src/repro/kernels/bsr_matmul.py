"""Pallas TPU kernel: block-CSR sparse matmul  y = x @ S.

TPU adaptation of SALAAD's unstructured sparse component (DESIGN.md §3): the
MXU consumes 128x128 tiles, so unstructured S is re-tiled at deployment into
block-CSR — only tiles containing surviving entries are stored. HPA's
magnitude truncation concentrates the support, so occupancy is measured (and
reported by ``kernels.ops.bsr_occupancy``), not assumed.

Layout (column-major over output blocks, padded to a fixed per-column count):
    counts  (JB,)              int32 — live blocks feeding output column jb
    rows    (JB, MAXB)         int32 — input row-block index of each block
    vals    (JB, MAXB, bs, bs) float — the tile data (zero-padded)

Kernel: grid (row_tiles_of_x, JB, MAXB); the scalar-prefetched ``rows`` table
drives the x BlockSpec index map, so the correct (bt, bs) slice of x is
DMA'd for each stored tile — the gather happens in the DMA engine, not the
VPU. Accumulation stays in a VMEM scratch; y is written once per (i, jb).
Padded slots multiply by zero tiles (cheap relative to DMA savings, and the
x index map clamps to a valid block so no out-of-bounds DMA occurs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["BsrMatrix", "bsr_from_dense", "bsr_to_dense", "bsr_matmul_pallas"]


class BsrMatrix:
    """Static-shape block-CSC container (named Bsr for familiarity).

    ``shape`` is the ORIGINAL dense (n, m); dims that do not divide
    ``block_size`` are zero-padded at conversion time, so the block tables
    cover ``ceil(n/bs) x ceil(m/bs)`` tiles and matmul callers slice the
    padded output columns back off. ``empty`` is STATIC deploy-time metadata
    (no live blocks at all) so jitted callers can skip the sparse phase
    entirely instead of burning one DMA+matmul per column block on the
    MAXB >= 1 padding slot.
    """

    def __init__(self, counts, rows, vals, shape, block_size, empty=False):
        self.counts = counts          # (JB,) int32
        self.rows = rows              # (JB, MAXB) int32
        self.vals = vals              # (JB, MAXB, bs, bs)
        self.shape = shape            # original dense (n, m), pre-padding
        self.block_size = block_size
        self.empty = empty            # static: no live blocks anywhere

    def tree_flatten(self):
        return (self.counts, self.rows, self.vals), (
            self.shape, self.block_size, self.empty
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def padded_shape(self) -> tuple[int, int]:
        """Block-aligned dims the tables actually cover."""
        bs = self.block_size
        n, m = self.shape
        return (-(-n // bs) * bs, -(-m // bs) * bs)

    @property
    def occupancy(self) -> float:
        """Fraction of (padded) dense tiles actually stored."""
        n_pad, m_pad = self.padded_shape
        bs = self.block_size
        total = (n_pad // bs) * (m_pad // bs)
        return float(np.sum(np.asarray(self.counts))) / max(total, 1)


jax.tree_util.register_pytree_node(
    BsrMatrix, BsrMatrix.tree_flatten, BsrMatrix.tree_unflatten
)


def bsr_from_dense(s: np.ndarray, block_size: int = 128, maxb: int | None = None) -> BsrMatrix:
    """Eager (deploy-time) conversion of a dense sparse matrix to block-CSC.

    Dims that do not divide ``block_size`` are zero-padded to the next block
    boundary (the padding tiles are all-zero, so they are never stored) —
    odd hidden sizes deploy as ``bsr``/``fused`` instead of asserting.
    ``maxb`` forces the per-column slot count (>= the live maximum) so
    several matrices can share one stacked table layout.
    """
    s = np.asarray(s)
    n, m = s.shape
    bs = block_size
    if n % bs or m % bs:
        s = np.pad(s, ((0, -n % bs), (0, -m % bs)))
    ib, jb = s.shape[0] // bs, s.shape[1] // bs
    tiles = s.reshape(ib, bs, jb, bs).transpose(0, 2, 1, 3)  # (ib, jb, bs, bs)
    live = np.abs(tiles).max(axis=(2, 3)) > 0                # (ib, jb)
    counts = live.sum(axis=0).astype(np.int32)               # per column block
    live_max = int(counts.max()) if counts.size else 0
    if maxb is None:
        maxb = max(live_max, 1)
    elif maxb < max(live_max, 1):
        raise ValueError(f"maxb={maxb} < live maximum {live_max}")
    rows = np.zeros((jb, maxb), np.int32)
    vals = np.zeros((jb, maxb, bs, bs), s.dtype)
    for j in range(jb):
        live_rows = np.nonzero(live[:, j])[0]
        rows[j, : len(live_rows)] = live_rows
        vals[j, : len(live_rows)] = tiles[live_rows, j]
    return BsrMatrix(
        jnp.asarray(counts), jnp.asarray(rows), jnp.asarray(vals), (n, m), bs,
        empty=live_max == 0,
    )


def bsr_to_dense(bsr: BsrMatrix) -> jax.Array:
    n, m = bsr.shape
    n_pad, _ = bsr.padded_shape
    bs = bsr.block_size
    jb, maxb = bsr.rows.shape
    dense = jnp.zeros((n_pad // bs, jb, bs, bs), bsr.vals.dtype)
    slot = jnp.arange(maxb)[None, :] < bsr.counts[:, None]  # (jb, maxb)
    vals = jnp.where(slot[:, :, None, None], bsr.vals, 0)
    for t in range(maxb):
        dense = dense.at[bsr.rows[:, t], jnp.arange(jb)].add(vals[:, t])
    return dense.transpose(0, 2, 1, 3).reshape(n_pad, jb * bs)[:n, :m]


def _kernel(scalars_ref, x_ref, vals_ref, y_ref, acc_ref, *, maxb: int):
    # scalar buffer layout: [counts (JB,), rows (JB*MAXB,)]
    t = pl.program_id(2)
    jb = pl.program_id(1)

    @pl.when(t == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Padded slots hold zero tiles, so skipping them is a pure MXU saving.
    @pl.when(t < scalars_ref[jb])
    def accumulate():
        acc_ref[...] += jnp.dot(
            x_ref[...].astype(jnp.float32),
            vals_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(t == maxb - 1)
    def emit():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def bsr_matmul_pallas(
    x: jax.Array, bsr: BsrMatrix, bt: int = 128, interpret: bool = True
) -> jax.Array:
    """y = x @ S for block-CSC S. x: (T, n) -> y: (T, m)."""
    t_dim, n = x.shape
    n_s, m = bsr.shape
    assert n == n_s, (x.shape, bsr.shape)
    n_pad, m_pad = bsr.padded_shape
    bs = bsr.block_size
    jb, maxb = bsr.rows.shape
    bt = min(bt, t_dim)
    pad_t, pad_n = -t_dim % bt, n_pad - n
    if pad_t or pad_n:
        x = jnp.pad(x, ((0, pad_t), (0, pad_n)))
    t_pad = x.shape[0]

    # scalar prefetch buffer: counts then flattened rows
    scalars = jnp.concatenate([bsr.counts, bsr.rows.reshape(-1)]).astype(jnp.int32)

    grid = (t_pad // bt, jb, maxb)
    kernel = functools.partial(_kernel, maxb=maxb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # x row-tile i, column block chosen by the rows table (clamped by
            # the slot-live predicate inside the kernel; padded slots reuse
            # slot 0's row which is always a valid block index)
            pl.BlockSpec(
                (bt, bs),
                lambda i, j, t, rows: (i, rows[jb + j * maxb + t]),
            ),
            pl.BlockSpec((1, 1, bs, bs), lambda i, j, t, rows: (j, t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bs), lambda i, j, t, rows: (i, j)),
        scratch_shapes=[pltpu.VMEM((bt, bs), jnp.float32)],
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad, m_pad), x.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
    )(scalars, x, bsr.vals)
    return y[:t_dim, :m]
