"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def soft_threshold_ref(x: jax.Array, tau) -> jax.Array:
    tau = jnp.asarray(tau, x.dtype)
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0)


def lowrank_matmul_ref(x: jax.Array, p: jax.Array, vt: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ p.astype(jnp.float32) @ vt.astype(jnp.float32)).astype(x.dtype)


def bsr_matmul_ref(x: jax.Array, bsr) -> jax.Array:
    """Scatter the blocks to dense, then dense matmul."""
    from .bsr_matmul import bsr_to_dense

    dense = bsr_to_dense(bsr)
    return (x.astype(jnp.float32) @ dense.astype(jnp.float32)).astype(x.dtype)


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True, scale=None
) -> jax.Array:
    """Dense softmax attention with GQA broadcast — O(T*S) memory."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, s), bool), k=s - t)
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", w, v.astype(jnp.float32)).astype(q.dtype)
