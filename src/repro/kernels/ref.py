"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def soft_threshold_ref(x: jax.Array, tau) -> jax.Array:
    tau = jnp.asarray(tau, x.dtype)
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0)


def lowrank_matmul_ref(x: jax.Array, p: jax.Array, vt: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ p.astype(jnp.float32) @ vt.astype(jnp.float32)).astype(x.dtype)


def bsr_matmul_ref(x: jax.Array, bsr) -> jax.Array:
    """Scatter the blocks to dense, then dense matmul."""
    from .bsr_matmul import bsr_to_dense

    dense = bsr_to_dense(bsr)
    return (x.astype(jnp.float32) @ dense.astype(jnp.float32)).astype(x.dtype)


def slr_matmul_ref(x: jax.Array, p, vt, bsr=None) -> jax.Array:
    """y = x @ P @ Vt + x @ S, matching the fused kernel's numerics: both
    contributions accumulate in one f32 accumulator and cast to x.dtype once
    (NOT lowrank_ref + bsr_ref, which would round each partial separately)."""
    from .bsr_matmul import bsr_to_dense

    xf = x.astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], vt.shape[-1] if vt is not None else bsr.shape[1]),
                    jnp.float32)
    if p is not None and p.shape[-1] > 0:
        acc = acc + xf @ p.astype(jnp.float32) @ vt.astype(jnp.float32)
    if bsr is not None and not getattr(bsr, "empty", False):
        acc = acc + xf @ bsr_to_dense(bsr).astype(jnp.float32)
    return acc.astype(x.dtype)


def slr_matmul_stacked_ref(x: jax.Array, p, vt, stack, layer) -> jax.Array:
    """Per-layer oracle for the stacked kernel: dynamic-slice layer ``layer``
    out of every table and defer to ``slr_matmul_ref``."""
    idx = lambda a: jax.lax.dynamic_index_in_dim(a, layer, keepdims=False)
    bsr = None
    if stack is not None and not getattr(stack, "empty", False):
        from .bsr_matmul import BsrMatrix

        bsr = BsrMatrix(
            idx(stack.counts), idx(stack.rows), idx(stack.vals),
            stack.shape, stack.block_size, empty=stack.empty,
        )
    return slr_matmul_ref(
        x, None if p is None else idx(p), None if vt is None else idx(vt), bsr
    )


def slr_matmul_multi_ref(x: jax.Array, p, vt, stack, ids) -> jax.Array:
    """Per-slot oracle for the multi-adapter kernel: slot ``b`` runs the
    stacked oracle with adapter ``ids[b]``'s tables."""
    ids = jnp.asarray(ids, jnp.int32)
    return jax.vmap(
        lambda xb, i: slr_matmul_stacked_ref(xb, p, vt, stack, i)
    )(x, ids)


def paged_attention_ref(
    q: jax.Array,            # (B, Hq, D) single decode query per slot
    k_pages: jax.Array,      # (num_pages, Hkv, bs, D) page pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (B, pages_per_slot) int32; >= num_pages unmapped
    lengths: jax.Array,      # (B,) pre-insert valid length per slot
) -> jax.Array:
    """Gather pages via the block table, then masked decode softmax.

    The query at slot b sits at position ``lengths[b]`` (its KV is already in
    the pool), so keys at positions <= lengths[b] are visible.
    """
    n, hkv, bs, d = k_pages.shape
    b, hq, _ = q.shape
    group = hq // hkv
    bt = jnp.minimum(block_table, n - 1)     # clamp unmapped; mask hides it
    nb = bt.shape[1]

    def gather(pages):
        g = pages[bt]                        # (B, nb, Hkv, bs, D)
        return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nb * bs, d)

    k, v = gather(k_pages), gather(v_pages)
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32) * scale
    sc = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(nb * bs)[None, :] <= lengths[:, None]      # (B, S)
    sc = jnp.where(mask[:, None, None], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def paged_attention_kquery_ref(
    q: jax.Array,            # (B, Hq, kq, D) — kq decode queries per slot
    k_pages: jax.Array,      # (num_pages, Hkv, bs, D) page pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (B, pages_per_slot) int32; >= num_pages unmapped
    lengths: jax.Array,      # (B,) pre-insert valid length per slot
) -> jax.Array:
    """k-query paged attention oracle (speculative-verify window AND
    chunked-prefill chunks — the kernel's query tiling must be invisible, so
    this oracle is deliberately tiling-free).

    Query i of slot b sits at position ``lengths[b] + i`` (the KV of all kq
    window tokens is already in the pool), so it sees keys at positions
    <= lengths[b] + i.
    """
    n, hkv, bs, d = k_pages.shape
    b, hq, kq, _ = q.shape
    group = hq // hkv
    bt = jnp.minimum(block_table, n - 1)     # clamp unmapped; mask hides it
    nb = bt.shape[1]

    def gather(pages):
        g = pages[bt]                        # (B, nb, Hkv, bs, D)
        return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nb * bs, d)

    k, v = gather(k_pages), gather(v_pages)
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, kq, d).astype(jnp.float32) * scale
    sc = jnp.einsum("bhgqd,bhsd->bhgqs", qg, k.astype(jnp.float32))
    q_pos = lengths[:, None] + jnp.arange(kq)[None, :]           # (B, kq)
    mask = jnp.arange(nb * bs)[None, None, :] <= q_pos[:, :, None]  # (B, kq, S)
    sc = jnp.where(mask[:, None, None], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgqs,bhsd->bhgqd", w, v.astype(jnp.float32))
    return out.reshape(b, hq, kq, d).astype(q.dtype)


def page_copy_ref(
    pool: jax.Array,   # (L, num_pages, H, bs, D) — payload or scale pool
    src: jax.Array,    # (n,) int32 source page ids
    dst: jax.Array,    # (n,) int32 destination page ids
) -> jax.Array:
    """Batched whole-page copy: ``out[:, dst[i]] = pool[:, src[i]]`` with every
    other page untouched (the copy-on-write primitive of prefix sharing).
    Duplicate destinations are only ever the (0, 0) identity padding pairs, so
    scatter order cannot matter."""
    return pool.at[:, dst].set(pool[:, src])


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True, scale=None
) -> jax.Array:
    """Dense softmax attention with GQA broadcast — O(T*S) memory."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, s), bool), k=s - t)
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", w, v.astype(jnp.float32)).astype(q.dtype)
