"""Pallas TPU kernel: batched whole-page copy over a paged KV pool.

The device half of copy-on-write prefix sharing (``serving/prefix_cache.py``):
when a slot's first divergent write would land in a page it shares read-only
with the radix prompt index, the engine allocates a private page and copies the
shared payload into it before the write. Copies are batched — one call moves
every CoW pair of an admission tick across all layers — and the pool operand is
ALIASED to the output (``input_output_aliases``), so the untouched pages are
never rewritten: the kernel only DMAs the ``n`` copied pages.

The same kernel serves every pool layout the paged cache carries: float
payloads, int8 payloads, and the f32 scale pools (trailing dim 1) — a page is
copied bit-for-bit whatever it stores. Grid is ``(n, L)`` with the src/dst page
ids scalar-prefetched, mirroring the block-table prefetch in
``paged_attention.py``: the gather/scatter happens in the DMA engine.

Pairs may be padded with (0, 0) identity entries (page 0 onto itself) so the
engine compiles only power-of-two batch widths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _copy_kernel(ids_ref, src_ref, o_ref):
    del ids_ref  # consumed by the index maps
    o_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_copy_pallas(
    pool: jax.Array,   # (L, num_pages, H, bs, D) — payload or scale pool
    src: jax.Array,    # (n,) int32 source page ids
    dst: jax.Array,    # (n,) int32 destination page ids
    interpret: bool = True,
) -> jax.Array:
    """``out[:, dst[i]] = pool[:, src[i]]``; every other page unchanged."""
    l, _, h, bs, d = pool.shape
    n = src.shape[0]
    ids = jnp.concatenate([src, dst]).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, l),
        in_specs=[
            pl.BlockSpec((1, 1, h, bs, d), lambda i, li, t: (li, t[i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, h, bs, d), lambda i, li, t: (li, t[n + i], 0, 0, 0)
        ),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # alias the pool into the output: only the n destination pages are
        # written, everything else stays in place (no full-pool roundtrip)
        input_output_aliases={1: 0},
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(ids, pool)
