"""Pallas TPU kernels: paged decode attention (single-query and k-query).

The serving engine stores KV in a fixed pool of ``(num_pages, Hkv, bs, D)``
pages per layer; each slot's logical sequence is scattered across pages named
by its block-table row. Gathering those pages with ``jnp`` materializes a
``(B, Hkv, pages_per_slot * bs, D)`` copy per layer per step — this kernel
instead scalar-prefetches the block table so each page is DMA'd straight from
its pool position (the gather happens in the DMA engine, like the BSR rows
table in ``bsr_matmul.py``).

Grid: (B * Hkv, pages_per_slot) — page minor, classic online softmax with
running (max, denom, acc) VMEM scratch carried across a slot's pages. GQA is
handled by blocking q as (B * Hkv, group, D). Pages at or beyond the slot's
valid length are skipped via ``pl.when``; unmapped table entries are clamped
to a valid pool index host-side and hidden by the positional length mask.

Interpret mode (the CPU default via ``kernels.ops``) is the validation and
container fallback path; on TPU hardware prefer ``block_size`` a multiple of
128 so page tiles align with the MXU.

``paged_attention_kquery_pallas`` is the multi-query variant: each slot
carries ``kq`` queries at consecutive positions ``length .. length + kq - 1``
— the just-inserted speculative-verify window (kq = draft k) or a chunked-
prefill chunk (kq = prefill_chunk, which can span many pages). Same
online-softmax structure with a per-row position mask; the query axis is
TILED (grid ``(B * Hkv, kq / q_tile, pages_per_slot)``) so chunk-width
windows never need a ``(kq * group, bs)`` score tile in VMEM — each query
tile carries its own running (max, denom, acc) scratch across the slot's
pages, and ``kq`` pads up to the tile multiple (padded rows compute junk that
is sliced off host-side; their positions sit past the valid window so they
only ever widen the page-skip bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(
    tables_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, bs, nb, n_kv, table_len,
):
    # tables layout: [block_table (B * nb,), lengths (B,)]
    bh = pl.program_id(0)
    i = pl.program_id(1)
    b = bh // n_kv

    @pl.when(i == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = tables_ref[table_len + b]

    # the decode query sits at position ``length`` (its KV was just inserted),
    # so page i holds visible keys iff i * bs <= length
    @pl.when(i * bs <= length)
    def page():
        q = q_ref[0].astype(jnp.float32) * scale        # (group, D)
        k = k_ref[0, 0].astype(jnp.float32)             # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (group, bs)
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(pos <= length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(
    q: jax.Array,            # (B, Hq, D) single decode query per slot
    k_pages: jax.Array,      # (num_pages, Hkv, bs, D) page pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (B, pages_per_slot) int32
    lengths: jax.Array,      # (B,) int32 pre-insert valid length per slot
    interpret: bool = True,
) -> jax.Array:
    b, hq, d = q.shape
    n, hkv, bs, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    nb = block_table.shape[1]
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * hkv, group, d)
    # unmapped entries (>= n) clamp to a real page; the length mask hides it
    tables = jnp.concatenate(
        [jnp.minimum(block_table, n - 1).reshape(-1), lengths]
    ).astype(jnp.int32)

    kernel = functools.partial(
        _kernel, scale=scale, bs=bs, nb=nb, n_kv=hkv, table_len=b * nb,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, nb),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda bh, i, t: (bh, 0, 0)),
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda bh, i, t: (t[(bh // hkv) * nb + i], bh % hkv, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda bh, i, t: (t[(bh // hkv) * nb + i], bh % hkv, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda bh, i, t: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, d), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(tables, qf, k_pages, v_pages)
    return out.reshape(b, hq, d)


# ------------------------------------------------------- k-query (verify) ---


def _kquery_kernel(
    tables_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, bs, nb, n_kv, q_tile, group, table_len,
):
    # tables layout: [block_table (B * nb,), lengths (B,)]
    bh = pl.program_id(0)
    qt = pl.program_id(1)
    i = pl.program_id(2)
    b = bh // n_kv
    rows = q_tile * group

    @pl.when(i == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = tables_ref[table_len + b]
    q0 = qt * q_tile     # first query index of this tile

    # query row r = qi * group + g sits at position length + q0 + qi; the page
    # holds visible keys for SOME row of the tile iff
    # i * bs <= length + q0 + q_tile - 1
    @pl.when(i * bs <= length + q0 + q_tile - 1)
    def page():
        q = q_ref[0].astype(jnp.float32) * scale        # (rows, D)
        k = k_ref[0, 0].astype(jnp.float32)             # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (rows, bs)
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (rows, bs), 1)
        qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (rows, bs), 0) // group
        s = jnp.where(pos <= length + qi, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


# per-tile query rows beyond which the query axis splits into grid tiles:
# bounds the (rows, bs) score block and the running-softmax scratch in VMEM
# however wide the chunked-prefill window grows
_MAX_Q_ROWS = 128


@functools.partial(jax.jit, static_argnames=("interpret", "q_tile"))
def paged_attention_kquery_pallas(
    q: jax.Array,            # (B, Hq, kq, D) — kq queries per slot, positions
    #                          length .. length + kq - 1 (speculative-verify
    #                          window or chunked-prefill chunk)
    k_pages: jax.Array,      # (num_pages, Hkv, bs, D) page pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (B, pages_per_slot) int32
    lengths: jax.Array,      # (B,) int32 pre-insert valid length per slot
    interpret: bool = True,
    q_tile: int | None = None,  # queries per grid tile; None = auto (whole
    #                             window while kq * group <= _MAX_Q_ROWS)
) -> jax.Array:
    b, hq, kq, d = q.shape
    n, hkv, bs, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    nb = block_table.shape[1]
    scale = 1.0 / (d ** 0.5)

    if q_tile is None:
        q_tile = kq if kq * group <= _MAX_Q_ROWS else max(_MAX_Q_ROWS // group, 1)
    q_tile = max(min(q_tile, kq), 1)
    kq_pad = -(-kq // q_tile) * q_tile
    if kq_pad != kq:
        # padded queries sit at positions length + kq .. length + kq_pad - 1:
        # past the valid window, so they only widen the page-skip bound of the
        # last tile; their junk output rows are sliced off below
        q = jnp.pad(q, ((0, 0), (0, 0), (0, kq_pad - kq), (0, 0)))
    nq = kq_pad // q_tile

    # rows ordered query-major: row = qi * group + g
    qf = q.reshape(b, hkv, group, kq_pad, d).transpose(0, 1, 3, 2, 4)
    qf = qf.reshape(b * hkv, kq_pad * group, d)
    tables = jnp.concatenate(
        [jnp.minimum(block_table, n - 1).reshape(-1), lengths]
    ).astype(jnp.int32)

    kernel = functools.partial(
        _kquery_kernel, scale=scale, bs=bs, nb=nb, n_kv=hkv, q_tile=q_tile,
        group=group, table_len=b * nb,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, nq, nb),
        in_specs=[
            pl.BlockSpec(
                (1, q_tile * group, d), lambda bh, qt, i, t: (bh, qt, 0)
            ),
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda bh, qt, i, t: (t[(bh // hkv) * nb + i], bh % hkv, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, bs, d),
                lambda bh, qt, i, t: (t[(bh // hkv) * nb + i], bh % hkv, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, q_tile * group, d), lambda bh, qt, i, t: (bh, qt, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((q_tile * group, 1), jnp.float32),
            pltpu.VMEM((q_tile * group, 1), jnp.float32),
            pltpu.VMEM((q_tile * group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, kq_pad * group, d), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(tables, qf, k_pages, v_pages)
    out = out.reshape(b, hkv, kq_pad, group, d).transpose(0, 1, 3, 2, 4)
    return out.reshape(b, hq, kq_pad, d)[:, :, :kq]
