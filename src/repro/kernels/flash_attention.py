"""Pallas TPU kernel: flash (blockwise-softmax) attention forward.

Needed by every prefill_32k cell: materializing 32k x 32k score matrices per
head is ~2 GB each — chunked online softmax is mandatory. The framework's
default under pjit is the pure-JAX blockwise path (models/attention.py) which
GSPMD shards; this kernel is the single-core TPU hot path (selectable via
``kernel_impl='pallas'``) with explicit VMEM tiling for the MXU, and is
validated against the jnp oracle in interpret mode.

Grid: (batch*q_heads, q_blocks, kv_blocks) — kv minor, classic online
softmax with running (max, denom, acc) scratch carried across kv blocks.
Causal masking is positional; fully-masked kv blocks are skipped via
``pl.when`` (upper triangle costs nothing). GQA is handled in the BlockSpec
index map (q head h reads kv head h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, kv_blocks, bq, bk):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def block():
        q = q_ref[0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip blocks entirely above the diagonal
        pl.when(kb * bk <= qb * bq + bq - 1)(block)
    else:
        block()

    @pl.when(kb == kv_blocks - 1)
    def emit():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "scale")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    causal: bool = True,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(bq, t)
    bk = min(bk, s)
    assert t % bq == 0 and s % bk == 0, "pad seq lens to block multiples"

    qf = q.reshape(b * hq, t, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)
    kv_blocks = s // bk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, kv_blocks=kv_blocks, bq=bq, bk=bk
    )

    def kv_head(bh):
        # flat q index -> flat kv index (GQA)
        return (bh // hq) * hkv + (bh % hq) // group

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, t // bq, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qb, kb: (kv_head(bh), kb, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qb, kb: (kv_head(bh), kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(qf, kf, vf)
    return out.reshape(b, hq, t, d)
