"""Pallas TPU kernel: fused SLR matmul  y = x @ P @ Vt + x @ S_bsr.

The SALAAD serving hot path evaluates a weight deployed as ``W ~= P @ Vt + S``
(low-rank + sparse) at every linear site of every decode tick. Running
``lowrank_matmul`` and ``bsr_matmul`` as two Pallas calls streams ``x`` from
HBM twice, writes two partial ``y``s back, and re-adds them in XLA — three
extra HBM round-trips per site. This kernel does both phases in ONE pass over
activation row-tiles: ``x`` is read once, ``y`` written once per tile.

Per row tile ``i`` the minor grid axis runs three phase groups over
``k_tiles + JB * MAXB`` steps:

  ph < k_tiles          accumulate  t_ref += x_blk @ p_blk       (VMEM (bt, r))
  ph >= k_tiles, slot 0 low-rank emit  acc = t_ref @ vt_j        (VMEM (bt,bs))
  ph >= k_tiles, live   sparse epilogue  acc += x[rows[j,t]] @ vals[j,t]
  ph >= k_tiles, last   y[i, j] = acc                            (one write)

The sparse epilogue reuses the scalar-prefetched block-CSC gather of
``bsr_matmul``: the ``rows`` table drives the x BlockSpec index map, so the
gather happens in the DMA engine. The BSR block size doubles as both the
K tile and the output column tile (bk == bn == bs), which is what lets the
low-rank emit and the sparse accumulate share one output accumulator.

The stacked variant adds a leading layer axis to every table
(counts ``(L, JB)``, rows ``(L, JB, MAXB)``, vals ``(L, JB, MAXB, bs, bs)``,
p ``(L, K, r)``, vt ``(L, r, M)``) and prefetches the layer id as scalar 0,
so the layer slice happens in the kernel's DMA index maps — no XLA gather of
the weight stack — and `bsr`/`fused` deployments become ``lax.scan``-able
over the transformer layer stack instead of unrolling it.

The multi-adapter variant generalizes the layer axis into an *adapter* axis
selected per batch slot: tables carry a leading adapter axis ``N`` and the
scalar buffer leads with a ``(B,)`` slot->adapter map, so one decode tick
serves B slots each running a DIFFERENT (P, Vt, S) adapter — the adapter
gather again lives entirely in the DMA index maps, one compiled program for
any slot->adapter assignment.

Callers pick decode-width row tiles (``bt`` rounded to the sublane tile, not
padded to 128) so a 4-row decode batch doesn't burn 32x padding FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bsr_matmul import BsrMatrix
from .compat import CompilerParams

__all__ = [
    "BsrStack",
    "stack_bsr",
    "slr_matmul_pallas",
    "slr_matmul_stacked_pallas",
    "slr_matmul_multi_pallas",
    "row_tile",
]


class BsrStack:
    """Layer-stacked block-CSC: L per-layer tables padded to a common MAXB.

    Same layout contract as ``BsrMatrix`` with a leading layer axis:
        counts  (L, JB)                int32
        rows    (L, JB, MAXB)          int32
        vals    (L, JB, MAXB, bs, bs)  float
    ``shape`` is the per-layer ORIGINAL dense (n, m) and ``empty`` is static
    deploy-time metadata meaning no layer holds any live block.
    """

    def __init__(self, counts, rows, vals, shape, block_size, empty=False):
        self.counts = counts
        self.rows = rows
        self.vals = vals
        self.shape = shape
        self.block_size = block_size
        self.empty = empty

    def tree_flatten(self):
        return (self.counts, self.rows, self.vals), (
            self.shape, self.block_size, self.empty
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_layers(self) -> int:
        return self.counts.shape[0]

    @property
    def padded_shape(self) -> tuple[int, int]:
        bs = self.block_size
        n, m = self.shape
        return (-(-n // bs) * bs, -(-m // bs) * bs)

    def at_layer(self, layer: int) -> BsrMatrix:
        """Eager per-layer view (testing/debug; kernels take the stack)."""
        return BsrMatrix(
            self.counts[layer], self.rows[layer], self.vals[layer],
            self.shape, self.block_size, empty=self.empty,
        )


jax.tree_util.register_pytree_node(
    BsrStack, BsrStack.tree_flatten, BsrStack.tree_unflatten
)


def stack_bsr(mats: list[BsrMatrix]) -> BsrStack:
    """Stack per-layer BsrMatrix tables, padding every layer to the max MAXB.

    Padding slots hold row 0 / zero tiles — the same always-dead convention
    as within one matrix, so the kernels' ``t < counts[j]`` predicate covers
    them for free.
    """
    assert mats, "stack_bsr needs at least one layer"
    shape, bs = mats[0].shape, mats[0].block_size
    assert all(m.shape == shape and m.block_size == bs for m in mats), (
        [m.shape for m in mats]
    )
    maxb = max(m.rows.shape[1] for m in mats)

    def pad_slots(a):
        pad = maxb - a.shape[1]
        if not pad:
            return a
        widths = [(0, 0)] * a.ndim
        widths[1] = (0, pad)
        return jnp.pad(a, widths)

    return BsrStack(
        jnp.stack([m.counts for m in mats]),
        jnp.stack([pad_slots(m.rows) for m in mats]),
        jnp.stack([pad_slots(m.vals) for m in mats]),
        shape, bs, empty=all(m.empty for m in mats),
    )


def row_tile(t_dim: int, dtype, cap: int = 128) -> int:
    """Decode-width row tile: round T up to the dtype's sublane tile, cap at
    ``cap``. A 4-row decode batch runs at bt=8 instead of padding to 128."""
    sub = {4: 8, 2: 16, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)
    return min(cap, -(-t_dim // sub) * sub)


def _kernel(scalars_ref, x_ref, p_ref, vt_ref, vals_ref, y_ref,
            t_ref, acc_ref, *, k_tiles: int, maxb: int):
    # scalar buffer layout: [counts (JB,), rows (JB*MAXB,)]
    ph = pl.program_id(1)

    @pl.when(ph < k_tiles)
    def lowrank_accumulate():
        @pl.when(ph == 0)
        def init():
            t_ref[...] = jnp.zeros_like(t_ref)

        t_ref[...] += jnp.dot(
            x_ref[...].astype(jnp.float32),
            p_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    e = jnp.maximum(ph - k_tiles, 0)
    j, t = e // maxb, e % maxb

    @pl.when(ph >= k_tiles)
    def epilogue():
        # Slot 0 of each column window seeds the accumulator with the
        # low-rank emit; live sparse slots add on top; the last slot writes.
        @pl.when(t == 0)
        def lowrank_emit():
            acc_ref[...] = jnp.dot(
                t_ref[...], vt_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )

        @pl.when(t < scalars_ref[j])
        def sparse_accumulate():
            acc_ref[...] += jnp.dot(
                x_ref[...].astype(jnp.float32),
                vals_ref[0, 0].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )

        @pl.when(t == maxb - 1)
        def emit():
            y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def slr_matmul_pallas(
    x: jax.Array,      # (T, K)
    p: jax.Array,      # (K, r)
    vt: jax.Array,     # (r, M)
    bsr: BsrMatrix,    # block-CSC S, shape (K, M)
    bt: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """y = x @ P @ Vt + x @ S in one Pallas pass. x: (T, K) -> y: (T, M)."""
    t_dim, k_dim = x.shape
    n_s, m = bsr.shape
    r = p.shape[1]
    assert k_dim == n_s and p.shape[0] == k_dim and vt.shape == (r, m), (
        x.shape, p.shape, vt.shape, bsr.shape
    )
    assert r > 0, "dispatch r == 0 to bsr_matmul (ops.slr_matmul does)"
    bs = bsr.block_size
    n_pad, m_pad = bsr.padded_shape
    jb, maxb = bsr.rows.shape
    bt = row_tile(t_dim, x.dtype, cap=bt)

    x = jnp.pad(x, ((0, -t_dim % bt), (0, n_pad - k_dim)))
    p = jnp.pad(p, ((0, n_pad - k_dim), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, m_pad - m)))
    t_pad = x.shape[0]

    k_tiles = n_pad // bs
    grid = (t_pad // bt, k_tiles + jb * maxb)
    scalars = jnp.concatenate([bsr.counts, bsr.rows.reshape(-1)]).astype(jnp.int32)

    def sparse_jt(ph):
        e = jnp.maximum(ph - k_tiles, 0)
        return e // maxb, e % maxb

    def x_map(i, ph, sc):
        j, t = sparse_jt(ph)
        kb = jnp.where(ph < k_tiles, ph, sc[jb + j * maxb + t])
        return (i, kb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # x column block: K tile while accumulating, rows-table gather
            # during the sparse epilogue (padded slots reuse row 0 — always a
            # valid block; the kernel predicate skips their matmul)
            pl.BlockSpec((bt, bs), x_map),
            pl.BlockSpec(
                (bs, r), lambda i, ph, sc: (jnp.minimum(ph, k_tiles - 1), 0)
            ),
            pl.BlockSpec((r, bs), lambda i, ph, sc: (0, sparse_jt(ph)[0])),
            pl.BlockSpec(
                (1, 1, bs, bs),
                lambda i, ph, sc: (*sparse_jt(ph), 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (bt, bs), lambda i, ph, sc: (i, sparse_jt(ph)[0])
        ),
        scratch_shapes=[
            pltpu.VMEM((bt, r), jnp.float32),
            pltpu.VMEM((bt, bs), jnp.float32),
        ],
    )
    y = pl.pallas_call(
        functools.partial(_kernel, k_tiles=k_tiles, maxb=maxb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad, m_pad), x.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(scalars, x, p, vt, bsr.vals)
    return y[:t_dim, :m]


def _stacked_kernel(scalars_ref, x_ref, p_ref, vt_ref, vals_ref, y_ref,
                    t_ref, acc_ref, *, k_tiles: int, jb: int, maxb: int):
    # scalar buffer layout: [layer, counts (L*JB,), rows (L*JB*MAXB,)]
    ph = pl.program_id(1)
    layer = scalars_ref[0]

    @pl.when(ph < k_tiles)
    def lowrank_accumulate():
        @pl.when(ph == 0)
        def init():
            t_ref[...] = jnp.zeros_like(t_ref)

        t_ref[...] += jnp.dot(
            x_ref[...].astype(jnp.float32),
            p_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    e = jnp.maximum(ph - k_tiles, 0)
    j, t = e // maxb, e % maxb

    @pl.when(ph >= k_tiles)
    def epilogue():
        @pl.when(t == 0)
        def lowrank_emit():
            acc_ref[...] = jnp.dot(
                t_ref[...], vt_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )

        @pl.when(t < scalars_ref[1 + layer * jb + j])
        def sparse_accumulate():
            acc_ref[...] += jnp.dot(
                x_ref[...].astype(jnp.float32),
                vals_ref[0, 0, 0].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )

        @pl.when(t == maxb - 1)
        def emit():
            y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def slr_matmul_stacked_pallas(
    x: jax.Array,      # (T, K)
    p: jax.Array,      # (L, K, r)
    vt: jax.Array,     # (L, r, M)
    stack: BsrStack,   # per-layer block-CSC S, shape (K, M)
    layer: jax.Array,  # () int32 — which layer's tables to use
    bt: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Layer-``lax.scan``-able fused SLR matmul.

    The layer id rides in slot 0 of the scalar-prefetch buffer and selects
    the (p, vt, vals) blocks inside the DMA index maps — only layer
    ``layer``'s tiles ever leave HBM, with no XLA gather of the stack.
    """
    t_dim, k_dim = x.shape
    n_s, m = stack.shape
    num_l, _, r = p.shape
    assert k_dim == n_s and p.shape[1] == k_dim and vt.shape == (num_l, r, m), (
        x.shape, p.shape, vt.shape, stack.shape
    )
    assert r > 0, "dispatch r == 0 to the sparse-only path (ops.slr_matmul)"
    bs = stack.block_size
    n_pad, m_pad = stack.padded_shape
    _, jb, maxb = stack.rows.shape
    bt = row_tile(t_dim, x.dtype, cap=bt)

    x = jnp.pad(x, ((0, -t_dim % bt), (0, n_pad - k_dim)))
    p = jnp.pad(p, ((0, 0), (0, n_pad - k_dim), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, m_pad - m)))
    t_pad = x.shape[0]

    k_tiles = n_pad // bs
    grid = (t_pad // bt, k_tiles + jb * maxb)
    scalars = jnp.concatenate([
        jnp.asarray(layer, jnp.int32).reshape(1),
        stack.counts.reshape(-1).astype(jnp.int32),
        stack.rows.reshape(-1).astype(jnp.int32),
    ])
    rows_base = 1 + num_l * jb  # rows table offset in the scalar buffer

    def sparse_jt(ph):
        e = jnp.maximum(ph - k_tiles, 0)
        return e // maxb, e % maxb

    def x_map(i, ph, sc):
        j, t = sparse_jt(ph)
        row = sc[rows_base + (sc[0] * jb + j) * maxb + t]
        return (i, jnp.where(ph < k_tiles, ph, row))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bs), x_map),
            pl.BlockSpec(
                (1, bs, r),
                lambda i, ph, sc: (sc[0], jnp.minimum(ph, k_tiles - 1), 0),
            ),
            pl.BlockSpec(
                (1, r, bs), lambda i, ph, sc: (sc[0], 0, sparse_jt(ph)[0])
            ),
            pl.BlockSpec(
                (1, 1, 1, bs, bs),
                lambda i, ph, sc: (sc[0], *sparse_jt(ph), 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (bt, bs), lambda i, ph, sc: (i, sparse_jt(ph)[0])
        ),
        scratch_shapes=[
            pltpu.VMEM((bt, r), jnp.float32),
            pltpu.VMEM((bt, bs), jnp.float32),
        ],
    )
    y = pl.pallas_call(
        functools.partial(_stacked_kernel, k_tiles=k_tiles, jb=jb, maxb=maxb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad, m_pad), x.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(scalars, x, p, vt, stack.vals)
    return y[:t_dim, :m]


def _multi_kernel(scalars_ref, x_ref, p_ref, vt_ref, vals_ref, y_ref,
                  t_ref, acc_ref, *, k_tiles: int, jb: int, maxb: int,
                  tiles: int, counts_base: int):
    # scalar buffer layout: [ids (B,), counts (N*JB,), rows (N*JB*MAXB,)]
    ph = pl.program_id(1)
    aid = scalars_ref[pl.program_id(0) // tiles]

    @pl.when(ph < k_tiles)
    def lowrank_accumulate():
        @pl.when(ph == 0)
        def init():
            t_ref[...] = jnp.zeros_like(t_ref)

        t_ref[...] += jnp.dot(
            x_ref[...].astype(jnp.float32),
            p_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    e = jnp.maximum(ph - k_tiles, 0)
    j, t = e // maxb, e % maxb

    @pl.when(ph >= k_tiles)
    def epilogue():
        @pl.when(t == 0)
        def lowrank_emit():
            acc_ref[...] = jnp.dot(
                t_ref[...], vt_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )

        @pl.when(t < scalars_ref[counts_base + aid * jb + j])
        def sparse_accumulate():
            acc_ref[...] += jnp.dot(
                x_ref[...].astype(jnp.float32),
                vals_ref[0, 0, 0].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )

        @pl.when(t == maxb - 1)
        def emit():
            y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def slr_matmul_multi_pallas(
    x: jax.Array,      # (B, T, K) — B batch slots
    p: jax.Array,      # (N, K, r) — N resident adapters
    vt: jax.Array,     # (N, r, M)
    stack: BsrStack,   # per-adapter block-CSC S, shape (K, M)
    ids: jax.Array,    # (B,) int32 — slot -> adapter row
    bt: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Batched heterogeneous-adapter fused SLR matmul: y[b] uses adapter
    ``ids[b]``'s (P, Vt, S) tables.

    The slot->adapter map rides at the head of the scalar-prefetch buffer:
    the major grid axis walks ``B * tiles`` row tiles and every DMA index map
    looks up ``ids[i // tiles]`` to pick the adapter slice — one compiled
    program serves any assignment of adapters to slots. Row padding is per
    slot (each slot's T rounds up to ``bt`` independently), so no row tile
    ever spans two slots.
    """
    b_dim, t_dim, k_dim = x.shape
    n_s, m = stack.shape
    num_n, _, r = p.shape
    assert k_dim == n_s and p.shape[1] == k_dim and vt.shape == (num_n, r, m), (
        x.shape, p.shape, vt.shape, stack.shape
    )
    assert ids.shape == (b_dim,), (ids.shape, b_dim)
    assert r > 0, "dispatch r == 0 through a zero-rank dummy (ops does)"
    bs = stack.block_size
    n_pad, m_pad = stack.padded_shape
    _, jb, maxb = stack.rows.shape
    bt = row_tile(t_dim, x.dtype, cap=bt)
    t_pad = -(-t_dim // bt) * bt

    x = jnp.pad(x, ((0, 0), (0, t_pad - t_dim), (0, n_pad - k_dim)))
    x = x.reshape(b_dim * t_pad, n_pad)
    p = jnp.pad(p, ((0, 0), (0, n_pad - k_dim), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, m_pad - m)))

    k_tiles = n_pad // bs
    tiles = t_pad // bt  # row tiles per slot
    grid = (b_dim * tiles, k_tiles + jb * maxb)
    scalars = jnp.concatenate([
        jnp.asarray(ids, jnp.int32).reshape(-1),
        stack.counts.reshape(-1).astype(jnp.int32),
        stack.rows.reshape(-1).astype(jnp.int32),
    ])
    counts_base = b_dim
    rows_base = b_dim + num_n * jb

    def sparse_jt(ph):
        e = jnp.maximum(ph - k_tiles, 0)
        return e // maxb, e % maxb

    def x_map(i, ph, sc):
        j, t = sparse_jt(ph)
        row = sc[rows_base + (sc[i // tiles] * jb + j) * maxb + t]
        return (i, jnp.where(ph < k_tiles, ph, row))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bs), x_map),
            pl.BlockSpec(
                (1, bs, r),
                lambda i, ph, sc: (
                    sc[i // tiles], jnp.minimum(ph, k_tiles - 1), 0
                ),
            ),
            pl.BlockSpec(
                (1, r, bs),
                lambda i, ph, sc: (sc[i // tiles], 0, sparse_jt(ph)[0]),
            ),
            pl.BlockSpec(
                (1, 1, 1, bs, bs),
                lambda i, ph, sc: (sc[i // tiles], *sparse_jt(ph), 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (bt, bs), lambda i, ph, sc: (i, sparse_jt(ph)[0])
        ),
        scratch_shapes=[
            pltpu.VMEM((bt, r), jnp.float32),
            pltpu.VMEM((bt, bs), jnp.float32),
        ],
    )
    y = pl.pallas_call(
        functools.partial(
            _multi_kernel, k_tiles=k_tiles, jb=jb, maxb=maxb,
            tiles=tiles, counts_base=counts_base,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_dim * t_pad, m_pad), x.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(scalars, x, p, vt, stack.vals)
    return y.reshape(b_dim, t_pad, m_pad)[:, :t_dim, :m]
