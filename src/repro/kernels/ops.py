"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to auto: Pallas interpret mode when no TPU is attached
(this container), compiled Mosaic on real TPU. Models and the serving engine
call these through the ``kernel_impl`` config switch; everything falls back to
the pure-jnp reference implementations under ``kernel_impl='xla'`` so pjit /
GSPMD sharding is never blocked by a kernel.
"""
from __future__ import annotations

import functools

import jax

from . import ref
from .bsr_matmul import BsrMatrix, bsr_from_dense, bsr_matmul_pallas, bsr_to_dense
from .flash_attention import flash_attention_pallas
from .lowrank_matmul import lowrank_matmul_pallas
from .page_copy import page_copy_pallas
from .paged_attention import paged_attention_kquery_pallas, paged_attention_pallas
from .soft_threshold import soft_threshold_pallas

__all__ = [
    "BsrMatrix",
    "bsr_from_dense",
    "bsr_to_dense",
    "soft_threshold",
    "lowrank_matmul",
    "bsr_matmul",
    "flash_attention",
    "paged_attention",
    "paged_attention_kquery",
    "page_copy",
    "bsr_occupancy",
]


@functools.cache
def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def soft_threshold(x, tau, interpret: bool | None = None):
    return soft_threshold_pallas(
        x, tau, interpret=_auto_interpret() if interpret is None else interpret
    )


def lowrank_matmul(x, p, vt, interpret: bool | None = None, **kw):
    return lowrank_matmul_pallas(
        x, p, vt, interpret=_auto_interpret() if interpret is None else interpret, **kw
    )


def bsr_matmul(x, bsr: BsrMatrix, interpret: bool | None = None, **kw):
    return bsr_matmul_pallas(
        x, bsr, interpret=_auto_interpret() if interpret is None else interpret, **kw
    )


def flash_attention(q, k, v, causal=True, interpret: bool | None = None, **kw):
    return flash_attention_pallas(
        q, k, v, causal=causal,
        interpret=_auto_interpret() if interpret is None else interpret, **kw
    )


def paged_attention(q, k_pages, v_pages, block_table, lengths,
                    interpret: bool | None = None):
    return paged_attention_pallas(
        q, k_pages, v_pages, block_table, lengths,
        interpret=_auto_interpret() if interpret is None else interpret,
    )


def paged_attention_kquery(q, k_pages, v_pages, block_table, lengths,
                           interpret: bool | None = None,
                           q_tile: int | None = None):
    """Multi-query paged attention: the speculative-verify window (kq = draft
    k) and chunked-prefill chunks (kq = prefill_chunk) share this kernel —
    wide windows tile the query axis across the grid (``q_tile``)."""
    return paged_attention_kquery_pallas(
        q, k_pages, v_pages, block_table, lengths,
        interpret=_auto_interpret() if interpret is None else interpret,
        q_tile=q_tile,
    )


def page_copy(pool, src, dst, interpret: bool | None = None):
    """Batched whole-page copy ``out[:, dst[i]] = pool[:, src[i]]`` — the
    device half of copy-on-write prefix sharing. One kernel serves float
    payload, int8 payload, and f32 scale pools alike."""
    return page_copy_pallas(
        pool, src, dst,
        interpret=_auto_interpret() if interpret is None else interpret,
    )


def bsr_occupancy(bsr: BsrMatrix) -> float:
    return bsr.occupancy
