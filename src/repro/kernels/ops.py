"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to auto: Pallas interpret mode when no TPU is attached
(this container), compiled Mosaic on real TPU. Models and the serving engine
call these through the ``kernel_impl`` config switch; everything falls back to
the pure-jnp reference implementations under ``kernel_impl='xla'`` so pjit /
GSPMD sharding is never blocked by a kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .bsr_matmul import BsrMatrix, bsr_from_dense, bsr_matmul_pallas, bsr_to_dense
from .flash_attention import flash_attention_pallas
from .lowrank_matmul import lowrank_matmul_pallas
from .page_copy import page_copy_pallas
from .paged_attention import paged_attention_kquery_pallas, paged_attention_pallas
from .slr_matmul import (
    BsrStack,
    slr_matmul_multi_pallas,
    slr_matmul_pallas,
    slr_matmul_stacked_pallas,
    stack_bsr,
)
from .soft_threshold import soft_threshold_pallas

__all__ = [
    "BsrMatrix",
    "BsrStack",
    "bsr_from_dense",
    "bsr_to_dense",
    "stack_bsr",
    "soft_threshold",
    "lowrank_matmul",
    "bsr_matmul",
    "slr_matmul",
    "slr_matmul_stacked",
    "slr_matmul_multi",
    "flash_attention",
    "paged_attention",
    "paged_attention_kquery",
    "page_copy",
    "bsr_occupancy",
]


@functools.cache
def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def soft_threshold(x, tau, interpret: bool | None = None):
    return soft_threshold_pallas(
        x, tau, interpret=_auto_interpret() if interpret is None else interpret
    )


def lowrank_matmul(x, p, vt, interpret: bool | None = None, **kw):
    return lowrank_matmul_pallas(
        x, p, vt, interpret=_auto_interpret() if interpret is None else interpret, **kw
    )


def bsr_matmul(x, bsr: BsrMatrix, interpret: bool | None = None, **kw):
    # Empty-S fast path: `empty` is static deploy-time metadata, so jitted
    # callers skip the kernel (MAXB is padded to >= 1 even for an all-zero
    # matrix — one dead DMA+matmul per column block per call otherwise).
    if getattr(bsr, "empty", False):
        return jnp.zeros((x.shape[0], bsr.shape[1]), x.dtype)
    return bsr_matmul_pallas(
        x, bsr, interpret=_auto_interpret() if interpret is None else interpret, **kw
    )


def slr_matmul(x, p, vt, bsr: BsrMatrix | None, interpret: bool | None = None, **kw):
    """Fused y = x @ P @ Vt + x @ S in one Pallas pass over x row-tiles.

    Degenerate corners dispatch to the cheaper single-phase kernels: empty S
    (static ``bsr.empty``) skips the sparse epilogue via ``lowrank_matmul``,
    r == 0 / missing factors skip the low-rank phases via ``bsr_matmul``.
    """
    interp = _auto_interpret() if interpret is None else interpret
    r = 0 if p is None else p.shape[-1]
    empty_s = bsr is None or getattr(bsr, "empty", False)
    if empty_s and r == 0:
        m = vt.shape[-1] if vt is not None else bsr.shape[1]
        return jnp.zeros((x.shape[0], m), x.dtype)
    if empty_s:
        from .slr_matmul import row_tile

        bm = row_tile(x.shape[0], x.dtype, cap=kw.pop("bt", 128))
        return lowrank_matmul_pallas(x, p, vt, bm=bm, interpret=interp)
    if r == 0:
        return bsr_matmul(x, bsr, interpret=interp, **kw)
    return slr_matmul_pallas(x, p, vt, bsr, interpret=interp, **kw)


def slr_matmul_stacked(x, p, vt, stack: BsrStack | None, layer,
                       interpret: bool | None = None, **kw):
    """Layer-scannable fused SLR matmul: per-layer tables selected inside the
    kernel's DMA index maps via the scalar-prefetched ``layer`` id.

    Same degenerate-corner dispatch as ``slr_matmul``; the r == 0 /
    non-empty-S corner (rare: a site that kept sparse support but no live
    rank) rides the fused kernel with dummy rank-1 zero factors rather than
    growing a third stacked kernel.
    """
    interp = _auto_interpret() if interpret is None else interpret
    r = 0 if p is None else p.shape[-1]
    empty_s = stack is None or getattr(stack, "empty", False)
    if empty_s and r == 0:
        m = vt.shape[-1] if vt is not None else stack.shape[1]
        return jnp.zeros((x.shape[0], m), x.dtype)
    if empty_s:
        from .slr_matmul import row_tile

        p_l = jax.lax.dynamic_index_in_dim(p, layer, keepdims=False)
        vt_l = jax.lax.dynamic_index_in_dim(vt, layer, keepdims=False)
        bm = row_tile(x.shape[0], x.dtype, cap=kw.pop("bt", 128))
        return lowrank_matmul_pallas(x, p_l, vt_l, bm=bm, interpret=interp)
    if r == 0:
        num_l = stack.counts.shape[0]
        p = jnp.zeros((num_l, x.shape[1], 1), x.dtype)
        vt = jnp.zeros((num_l, 1, stack.shape[1]), x.dtype)
    return slr_matmul_stacked_pallas(x, p, vt, stack, layer, interpret=interp, **kw)


def slr_matmul_multi(x, p, vt, stack: BsrStack | None, ids,
                     interpret: bool | None = None, **kw):
    """Batched heterogeneous-adapter fused SLR matmul: slot ``b`` of the
    (B, T, K) activation batch runs adapter ``ids[b]``'s (P, Vt, S) tables,
    selected per slot inside the kernel's DMA index maps.

    Degenerate corners mirror ``slr_matmul_stacked`` OP FOR OP — the serving
    parity guarantee (AdapterBank vs ModelBank bitwise-identical streams)
    depends on each corner running the exact same kernel per slot, so the
    empty-S corner maps ``lowrank_matmul_pallas`` over slots rather than
    batching into a differently-tiled einsum.
    """
    interp = _auto_interpret() if interpret is None else interpret
    r = 0 if p is None else p.shape[-1]
    empty_s = stack is None or getattr(stack, "empty", False)
    if empty_s and r == 0:
        m = vt.shape[-1] if vt is not None else stack.shape[1]
        return jnp.zeros((*x.shape[:2], m), x.dtype)
    if empty_s:
        from .slr_matmul import row_tile

        bm = row_tile(x.shape[1], x.dtype, cap=kw.pop("bt", 128))
        ids = jnp.asarray(ids, jnp.int32)

        def one_slot(args):
            xb, i = args
            p_i = jax.lax.dynamic_index_in_dim(p, i, keepdims=False)
            vt_i = jax.lax.dynamic_index_in_dim(vt, i, keepdims=False)
            return lowrank_matmul_pallas(xb, p_i, vt_i, bm=bm, interpret=interp)

        return jax.lax.map(one_slot, (x, ids))
    if r == 0:
        num_n = stack.counts.shape[0]
        p = jnp.zeros((num_n, x.shape[2], 1), x.dtype)
        vt = jnp.zeros((num_n, 1, stack.shape[1]), x.dtype)
    if interpret is None and interp:
        # Off-TPU the grid emulation is pathological for THIS op: every
        # pallas_call charges for the full (A*L, ...) pooled operands and
        # the N*JB*MAXB scalar table, where on hardware the DMA index maps
        # move only the B slots' blocks — cost grows with pool capacity,
        # not with the batch. The jnp oracle performs the same per-slot
        # gather + matmul in one vectorized pass, so it IS the correct
        # non-TPU lowering; pass ``interpret=True`` explicitly to exercise
        # the emulated kernel itself (kernel tests do). The degenerate
        # corners above stay on the single-tenant kernels in either case —
        # the bitwise-parity guarantee needs each corner to run the exact
        # per-slot op the plain tier path runs.
        return ref.slr_matmul_multi_ref(x, p, vt, stack, ids)
    return slr_matmul_multi_pallas(x, p, vt, stack, ids, interpret=interp, **kw)


def flash_attention(q, k, v, causal=True, interpret: bool | None = None, **kw):
    return flash_attention_pallas(
        q, k, v, causal=causal,
        interpret=_auto_interpret() if interpret is None else interpret, **kw
    )


def _head_shard_mesh(num_q_heads: int, num_kv_heads: int):
    """The active mesh, iff paged attention should shard_map over heads.

    The Pallas paged kernels use scalar-prefetched DMA index maps, which GSPMD
    cannot partition — so under an active mesh with model > 1 the dispatchers
    below wrap them in ``shard_map`` over the KV-head axis. Per-(batch, head)
    attention is independent, and GQA groups stay co-located (hq/m q heads +
    hkv/m kv heads per rank), so the body needs NO collective; the psum
    happens later at the row-parallel o-projection, exactly as for dense TP.
    """
    from ..parallel.sharding import current_mesh

    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    m = int(mesh.shape["model"])
    if m <= 1 or num_q_heads % m or num_kv_heads % m:
        return None
    return mesh


def paged_attention(q, k_pages, v_pages, block_table, lengths,
                    interpret: bool | None = None):
    interp = _auto_interpret() if interpret is None else interpret
    mesh = _head_shard_mesh(q.shape[1], k_pages.shape[1])
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        fn = shard_map(
            functools.partial(paged_attention_pallas, interpret=interp),
            mesh=mesh,
            in_specs=(
                P(None, "model", None),        # q: (B, Hq, D) heads sharded
                P(None, "model", None, None),  # k pool: (pages, Hkv, bs, D)
                P(None, "model", None, None),  # v pool
                P(),                           # block table: host bookkeeping
                P(),                           # lengths
            ),
            out_specs=P(None, "model", None),
            check_rep=False,
        )
        return fn(q, k_pages, v_pages, block_table, lengths)
    return paged_attention_pallas(
        q, k_pages, v_pages, block_table, lengths, interpret=interp,
    )


def paged_attention_kquery(q, k_pages, v_pages, block_table, lengths,
                           interpret: bool | None = None,
                           q_tile: int | None = None):
    """Multi-query paged attention: the speculative-verify window (kq = draft
    k) and chunked-prefill chunks (kq = prefill_chunk) share this kernel —
    wide windows tile the query axis across the grid (``q_tile``)."""
    interp = _auto_interpret() if interpret is None else interpret
    mesh = _head_shard_mesh(q.shape[1], k_pages.shape[1])
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        fn = shard_map(
            functools.partial(
                paged_attention_kquery_pallas, interpret=interp, q_tile=q_tile
            ),
            mesh=mesh,
            in_specs=(
                P(None, "model", None, None),  # q: (B, Hq, kq, D)
                P(None, "model", None, None),
                P(None, "model", None, None),
                P(),
                P(),
            ),
            out_specs=P(None, "model", None, None),
            check_rep=False,
        )
        return fn(q, k_pages, v_pages, block_table, lengths)
    return paged_attention_kquery_pallas(
        q, k_pages, v_pages, block_table, lengths, interpret=interp, q_tile=q_tile,
    )


def page_copy(pool, src, dst, interpret: bool | None = None):
    """Batched whole-page copy ``out[:, dst[i]] = pool[:, src[i]]`` — the
    device half of copy-on-write prefix sharing. One kernel serves float
    payload, int8 payload, and f32 scale pools alike."""
    return page_copy_pallas(
        pool, src, dst,
        interpret=_auto_interpret() if interpret is None else interpret,
    )


def bsr_occupancy(bsr: BsrMatrix) -> float:
    return bsr.occupancy
