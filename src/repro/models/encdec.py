"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, frames, d_model) straight into the encoder.
Positions are absolute sinusoidal (rope_theta=None archs). Decoder layers:
causal self-attn + cross-attn over encoder output + GELU MLP.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import constrain
from .attention import KVCache, attention_block, init_qkv
from .layers import (
    apply_mlp, apply_norm, apply_weight, embed, init_embedding, init_mlp,
    init_norm, sinusoidal_positions,
)


class EncDecCache(NamedTuple):
    k: jax.Array         # (L, B, H, S, D) decoder self-attn cache
    v: jax.Array
    cross_k: jax.Array   # (L, B, H, F, D) precomputed from encoder output
    cross_v: jax.Array
    length: jax.Array


def _init_block(key, cfg, cross: bool) -> dict:
    ka, kc, km, kn = jax.random.split(key, 4)
    p = {"self": init_qkv(ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.param_dtype)}
    p["pre_self"] = init_norm(kn, cfg.d_model, cfg.norm_type, cfg.param_dtype)
    if cross:
        p["cross"] = init_qkv(kc, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.param_dtype)
        p["pre_cross"] = init_norm(jax.random.fold_in(kn, 1), cfg.d_model, cfg.norm_type, cfg.param_dtype)
    p["pre_mlp"] = init_norm(jax.random.fold_in(kn, 2), cfg.d_model, cfg.norm_type, cfg.param_dtype)
    p.update(init_mlp(km, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.param_dtype))
    return p


def init_encdec(cfg, key) -> dict:
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "encoder": jax.vmap(lambda k: _init_block(k, cfg, cross=False))(enc_keys),
        "decoder": jax.vmap(lambda k: _init_block(k, cfg, cross=True))(dec_keys),
        "enc_norm": init_norm(jax.random.fold_in(ke, 1), cfg.d_model, cfg.norm_type, cfg.param_dtype),
        "final_norm": init_norm(jax.random.fold_in(ke, 2), cfg.d_model, cfg.norm_type, cfg.param_dtype),
        "lm_head": {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)).astype(cfg.param_dtype)
        },
    }


def encode(params, frames: jax.Array, cfg) -> jax.Array:
    """frames: (B, F, d_model) stub embeddings -> encoder hidden states."""
    b, f, d = frames.shape
    x = frames + sinusoidal_positions(f, d).astype(frames.dtype)[None]
    x = constrain(x, ("data", None, None))

    def body(x, lp):
        h = apply_norm(x, lp.get("pre_self"), cfg.norm_type)
        out, _ = attention_block(
            lp["self"], h,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
            positions=None, rope_theta=None, causal=False,
            kernel_impl=cfg.kernel_impl,
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
        )
        x = x + out
        h = apply_norm(x, lp.get("pre_mlp"), cfg.norm_type)
        x = x + apply_mlp(lp, h, cfg.mlp_type)
        return constrain(x, ("data", None, None)), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"], unroll=cfg.scan_unroll)
    return apply_norm(x, params.get("enc_norm"), cfg.norm_type)


def _cross_kv(lp_cross, enc_out, cfg):
    b, f, _ = enc_out.shape
    k = apply_weight(enc_out, lp_cross["k"]).reshape(b, f, cfg.num_kv_heads, cfg.head_dim)
    v = apply_weight(enc_out, lp_cross["v"]).reshape(b, f, cfg.num_kv_heads, cfg.head_dim)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def decode_stack(params, tokens, enc_out, cfg, cache: EncDecCache | None = None, position_offset=0, collect_kv=False):
    """Decoder forward. Returns (logits, new_cache_or_kvs)."""
    b, t = tokens.shape
    x = embed(params["embed"], tokens)
    positions = position_offset + jnp.arange(t)[None, :]
    # dynamic sinusoidal embedding (position_offset may be traced at decode)
    d = cfg.d_model
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-np.log(10000.0) / d))
    ang = positions[..., None].astype(jnp.float32) * div
    pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    x = x + pos_emb.astype(x.dtype)
    x = constrain(x, ("data", None, None))

    if cache is None:
        def body(x, lp):
            h = apply_norm(x, lp.get("pre_self"), cfg.norm_type)
            out, kv = attention_block(
                lp["self"], h,
                n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
                positions=positions, rope_theta=None, causal=True,
                kernel_impl=cfg.kernel_impl,
                q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
            )
            x = x + out
            h = apply_norm(x, lp.get("pre_cross"), cfg.norm_type)
            ck, cv = _cross_kv(lp["cross"], enc_out, cfg)
            out, _ = attention_block(
                lp["cross"], h,
                n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
                positions=None, rope_theta=None, causal=False,
                kv_override=(ck, cv), kernel_impl=cfg.kernel_impl,
                q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
            )
            x = x + out
            h = apply_norm(x, lp.get("pre_mlp"), cfg.norm_type)
            x = x + apply_mlp(lp, h, cfg.mlp_type)
            return constrain(x, ("data", None, None)), (kv if collect_kv else None)

        fn = jax.checkpoint(body) if cfg.remat else body
        x, kvs = jax.lax.scan(fn, x, params["decoder"], unroll=cfg.scan_unroll)
        new_cache = kvs
    else:
        def body(carry, inp):
            x = carry
            lp, k_l, v_l, ck_l, cv_l = inp
            h = apply_norm(x, lp.get("pre_self"), cfg.norm_type)
            out, kv = attention_block(
                lp["self"], h,
                n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
                positions=positions, rope_theta=None, causal=True,
                cache=KVCache(k_l, v_l, cache.length), kernel_impl=cfg.kernel_impl,
            )
            x = x + out
            h = apply_norm(x, lp.get("pre_cross"), cfg.norm_type)
            out, _ = attention_block(
                lp["cross"], h,
                n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
                positions=None, rope_theta=None, causal=False,
                kv_override=(ck_l, cv_l), kernel_impl=cfg.kernel_impl,
            )
            x = x + out
            h = apply_norm(x, lp.get("pre_mlp"), cfg.norm_type)
            x = x + apply_mlp(lp, h, cfg.mlp_type)
            return x, (kv.k, kv.v)

        x, (k_n, v_n) = jax.lax.scan(
            body, x, (params["decoder"], cache.k, cache.v, cache.cross_k, cache.cross_v), unroll=cfg.scan_unroll
        )
        new_cache = EncDecCache(k_n, v_n, cache.cross_k, cache.cross_v, cache.length + t)

    x = apply_norm(x, params.get("final_norm"), cfg.norm_type)
    logits = apply_weight(x, params["lm_head"]["w"])
    return constrain(logits, ("data", None, "model")), new_cache


def forward(params, tokens, cfg, *, frames=None, cache=None, position_offset=0):
    """Unified entry. Train/prefill: frames given. Decode: cache given
    (cross-KV precomputed in the cache)."""
    if cache is None:
        enc_out = encode(params, frames, cfg)
        logits, kvs = decode_stack(params, tokens, enc_out, cfg, position_offset=position_offset)
        return logits, (kvs, enc_out), jnp.zeros((), jnp.float32)
    logits, new_cache = decode_stack(
        params, tokens, None, cfg, cache=cache, position_offset=position_offset
    )
    return logits, new_cache, jnp.zeros((), jnp.float32)


def init_encdec_cache(params, cfg, batch: int, max_len: int, enc_out=None, dtype=jnp.bfloat16):
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    if enc_out is None:
        f = cfg.encoder_seq
        ck = jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads, f, cfg.head_dim), dtype)
        cv = ck
    else:
        def per_layer(lp):
            return _cross_kv(lp["cross"], enc_out, cfg)

        ck, cv = jax.vmap(per_layer)(params["decoder"])
        ck, cv = ck.astype(dtype), cv.astype(dtype)
    return EncDecCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        cross_k=ck, cross_v=cv, length=jnp.zeros((), jnp.int32),
    )
