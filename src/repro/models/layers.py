"""Shared neural net layers (pure-function style: params are nested dicts).

Conventions:
  * every linear weight is stored (d_in, d_out) so ``x @ w`` applies it;
  * scan-stacked layer parameters carry a leading (num_layers,) axis;
  * compute dtype follows the activation dtype; norms/softmax run in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------- pluggable linear ---


def apply_weight(x: jax.Array, w) -> jax.Array:
    """y = x @ w for a dense array OR any deployed-format weight object.

    Every matmul against a model weight goes through here so serving can swap
    dense matrices for structured ones without touching model code:
    ``serving.slr_params.SLRLinear`` (factored / block-CSR / fused one-pass
    Pallas) and its per-layer ``SLRLayerView`` (stacked fused weights inside
    an index-driven layer scan) all expose ``apply(x)``; plain arrays take
    the ordinary einsum path. Fused weights pick decode-width row tiles from
    the flattened activation, so small-batch decode never pads to 128.
    """
    if hasattr(w, "apply"):
        return w.apply(x)
    return x @ w


# ----------------------------------------------------------------- norms ---


def rmsnorm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def nonparam_layernorm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale, no bias)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(x: jax.Array, params: dict | None, norm_type: str) -> jax.Array:
    if norm_type == "rmsnorm":
        return rmsnorm(x, params["norm_scale"] if params else None)
    if norm_type == "nonparam_ln":
        return nonparam_layernorm(x)
    if norm_type == "layernorm":
        x32 = x.astype(jnp.float32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * params["norm_scale"].astype(jnp.float32) + params["norm_bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    raise ValueError(norm_type)


def init_norm(key, d: int, norm_type: str, dtype) -> dict:
    if norm_type == "rmsnorm":
        return {"norm_scale": jnp.zeros((d,), dtype)}
    if norm_type == "nonparam_ln":
        return {}
    if norm_type == "layernorm":
        return {"norm_scale": jnp.ones((d,), dtype), "norm_bias": jnp.zeros((d,), dtype)}
    raise ValueError(norm_type)


# ------------------------------------------------------------------ RoPE ---


def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., seq, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, offset: int = 0) -> jax.Array:
    pos = np.arange(offset, offset + seq)[:, None]
    div = np.exp(np.arange(0, d, 2) * -(np.log(10000.0) / d))
    pe = np.zeros((seq, d), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe)


# ------------------------------------------------------------------- MLP ---


def init_mlp(key, d: int, d_ff: int, mlp_type: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(d_ff)
    if mlp_type in ("swiglu", "geglu"):
        return {
            "gate": (jax.random.normal(k1, (d, d_ff)) * scale_in).astype(dtype),
            "up": (jax.random.normal(k2, (d, d_ff)) * scale_in).astype(dtype),
            "down": (jax.random.normal(k3, (d_ff, d)) * scale_out).astype(dtype),
        }
    return {  # plain gelu MLP (whisper)
        "up": (jax.random.normal(k1, (d, d_ff)) * scale_in).astype(dtype),
        "up_bias": jnp.zeros((d_ff,), dtype),
        "down": (jax.random.normal(k2, (d_ff, d)) * scale_out).astype(dtype),
        "down_bias": jnp.zeros((d,), dtype),
    }


def apply_mlp(params: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type == "swiglu":
        h = jax.nn.silu(apply_weight(x, params["gate"])) * apply_weight(x, params["up"])
        return apply_weight(h, params["down"])
    if mlp_type == "geglu":
        h = jax.nn.gelu(apply_weight(x, params["gate"]), approximate=True) * apply_weight(x, params["up"])
        return apply_weight(h, params["down"])
    if mlp_type == "gelu":
        h = jax.nn.gelu(apply_weight(x, params["up"]) + params["up_bias"], approximate=True)
        return apply_weight(h, params["down"]) + params["down_bias"]
    raise ValueError(mlp_type)


# ------------------------------------------------------------- embedding ---


def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"embedding": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return params["embedding"][tokens]


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    w = (jax.random.normal(key, (d_in, d_out)) / np.sqrt(d_in)).astype(dtype)
    out = {"w": w}
    if bias:
        out["b"] = jnp.zeros((d_out,), dtype)
    return out


def apply_linear(params: dict, x: jax.Array) -> jax.Array:
    y = apply_weight(x, params["w"])
    if "b" in params:
        y = y + params["b"]
    return y
