"""Mamba2 (SSD — state-space duality) blocks: chunked train/prefill + O(1) decode.

Chunked SSD (Dao & Gu 2024): the sequence is split into chunks of Q tokens;
within a chunk the recurrence is expanded into a (Q, Q) lower-triangular
"attention" form (quadratic in Q only — MXU-friendly), while chunk-to-chunk
state is carried by a lax.scan — sub-quadratic in sequence length, which is
what qualifies the ssm/hybrid archs for the long_500k cells.

Decode is the pure recurrent form: state (B, H, P, N) updated per token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SSMCache(NamedTuple):
    state: jax.Array     # (B, H, P, N)
    conv: jax.Array      # (B, K-1, conv_dim) rolling conv window
    length: jax.Array    # ()


def ssm_dims(d_model: int, expand: int, head_dim: int, d_state: int, ngroups: int = 1):
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    conv_dim = d_inner + 2 * ngroups * d_state
    return d_inner, nheads, conv_dim


def init_ssm_layer(key, d_model, expand, head_dim, d_state, dtype, ngroups=1):
    d_inner, nheads, conv_dim = ssm_dims(d_model, expand, head_dim, d_state, ngroups)
    k1, k2, k3 = jax.random.split(key, 3)
    d_in_proj = 2 * d_inner + 2 * ngroups * d_state + nheads
    return {
        "in_proj": (jax.random.normal(k1, (d_model, d_in_proj)) / np.sqrt(d_model)).astype(dtype),
        "conv_w": (jax.random.normal(k2, (4, conv_dim)) * 0.2).astype(dtype),
        "a_log": jnp.zeros((nheads,), jnp.float32),          # A = -exp(a_log) = -1
        "dt_bias": jnp.full((nheads,), -2.0, jnp.float32),   # softplus ~ 0.12
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": (jax.random.normal(k3, (d_inner, d_model)) / np.sqrt(d_inner)).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, window: jax.Array | None = None):
    """Depthwise causal conv, k=4. x: (B, L, C); w: (4, C).

    ``window`` (B, 3, C): trailing context from a cache (decode); else zeros.
    Returns (y, new_window)."""
    b, l, c = x.shape
    k = w.shape[0]
    if window is None:
        window = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([window, x], axis=1)  # (B, L+3, C)
    y = sum(xp[:, i : i + l] * w[i][None, None] for i in range(k))
    return jax.nn.silu(y), xp[:, -(k - 1) :]


def _segsum(dA: jax.Array) -> jax.Array:
    """Lower-triangular pairwise sums: out[..., i, j] = sum_{j<t<=i} dA[..., t].

    dA: (..., q); returns (..., q, q) with -inf above the diagonal."""
    q = dA.shape[-1]
    csum = jnp.cumsum(dA, axis=-1)
    # sum_{j < t <= i} = csum[i] - csum[j]
    diff = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,    # (B, L, H, P)
    dt: jax.Array,   # (B, L, H)   (already softplus'd, >0)
    a: jax.Array,    # (H,)        (negative)
    b_in: jax.Array, # (B, L, N)   ngroups=1
    c_in: jax.Array, # (B, L, N)
    chunk: int = 128,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, f"seq {l} must divide chunk {q}"
    nc = l // q

    # Mixed precision: the decay/cumsum math stays f32 (exp of sums — small
    # (B,nc,q,h) tensors), but the LARGE intra-chunk tensors (xc, the (q,q)
    # decay matrix, the weighted scores) run in the activation dtype. In f32
    # they alone held ~9 GB/device/layer on zamba2 train_4k (measured:
    # 93 GB peak); bf16 halves that. Tests feed f32 and keep exactness.
    f32 = jnp.float32
    cdt = x.dtype  # compute dtype for the big tensors
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    dA = dtc * a[None, None, None, :]  # (B, nc, q, h)

    # fold dt into x once: (dt_j x_j) appears in both intra and state terms
    xdt = (x.reshape(bsz, nc, q, h, p).astype(f32) * dtc[..., None]).astype(cdt)
    bc = b_in.reshape(bsz, nc, q, n).astype(cdt)
    cc = c_in.reshape(bsz, nc, q, n).astype(cdt)

    # intra-chunk (quadratic in q): Y_intra = (CB^T * L) (dt x)
    lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2))).astype(cdt)  # (B,nc,h,q,q)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)                 # (B,nc,q,q)
    w = (scores[:, :, None] * lmat).astype(cdt)                    # (B,nc,h,q,q)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w, xdt)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j
    cum = jnp.cumsum(dA, axis=2)                                # (B,nc,q,h)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum).astype(cdt)  # (B,nc,q,h)
    states = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchpn", decay_to_end, bc, xdt
    ).astype(f32)                                               # (B,nc,h,p,n)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))                  # (B,nc,h)
    s0 = (
        init_state.astype(f32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), f32)
    )

    def step(s, inp):
        st, dec = inp  # (B,h,p,n), (B,h)
        s_prev = s
        s_new = s * dec[:, :, None, None] + st
        return s_new, s_prev

    (s_final, s_prevs) = jax.lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                  # (B,nc,h,p,n)

    # inter-chunk contribution: Y_inter_i = exp(cum_i) * C_i . S_prev
    in_decay = jnp.exp(cum)                                     # (B,nc,q,h)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, s_prevs, in_decay)

    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    return y.astype(x.dtype), s_final


def ssm_block(
    params: dict,
    u: jax.Array,  # (B, L, d_model)
    *,
    expand: int,
    head_dim: int,
    d_state: int,
    chunk: int = 128,
    cache: SSMCache | None = None,
) -> tuple[jax.Array, SSMCache | None]:
    """Full Mamba2 block: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    from .layers import rmsnorm

    bsz, l, d_model = u.shape
    d_inner, nheads, conv_dim = ssm_dims(d_model, expand, head_dim, d_state)
    n = d_state

    zxbcdt = u @ params["in_proj"]
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    window = cache.conv if cache is not None else None
    xbc, new_window = _causal_conv(xbc, params["conv_w"], window)
    x, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xh = x.reshape(bsz, l, nheads, head_dim)

    if cache is not None and l == 1:
        # recurrent decode: state' = state * exp(dt A) + dt * x B^T
        st = cache.state.astype(jnp.float32)  # (B,H,P,N)
        dt1 = dt[:, 0]                        # (B,H)
        da = jnp.exp(dt1 * a[None, :])        # (B,H)
        upd = jnp.einsum(
            "bh,bhp,bn->bhpn", dt1, xh[:, 0].astype(jnp.float32), b_in[:, 0].astype(jnp.float32)
        )
        st_new = st * da[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", st_new, c_in[:, 0].astype(jnp.float32))
        y = y[:, None]  # (B,1,H,P)
        new_cache = SSMCache(st_new.astype(cache.state.dtype), new_window, cache.length + 1)
    else:
        init_state = cache.state if cache is not None else None
        y, s_final = ssd_chunked(xh, dt, a, b_in, c_in, chunk=chunk, init_state=init_state)
        new_cache = (
            SSMCache(s_final.astype(u.dtype), new_window, (cache.length if cache is not None else 0) + l)
            if cache is not None
            else SSMCache(s_final.astype(u.dtype), new_window, jnp.asarray(l, jnp.int32))
        )

    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, l, d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"])
    return y @ params["out_proj"], new_cache


def init_ssm_cache(bsz, d_model, expand, head_dim, d_state, dtype):
    d_inner, nheads, conv_dim = ssm_dims(d_model, expand, head_dim, d_state)
    return SSMCache(
        state=jnp.zeros((bsz, nheads, head_dim, d_state), dtype),
        conv=jnp.zeros((bsz, 3, conv_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )
