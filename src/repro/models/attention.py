"""Attention: GQA projections + blockwise (memory-efficient) softmax.

Three execution paths, one set of weights:
  * ``blockwise_attention`` — pure-JAX flash algorithm (double lax.scan over
    q/kv chunks). This is the pjit/GSPMD default: it never materializes the
    (T, S) score matrix, which is what lets the prefill_32k cells fit HBM.
  * ``repro.kernels.flash_attention`` — Pallas TPU kernel (kernel_impl='pallas').
  * ``dense path`` — plain softmax for tiny smoke shapes (kernel_impl='dense').

Decode: single-token query against a preallocated KV cache, O(S) einsum.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, apply_weight

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, Hkv, S, D)
    v: jax.Array  # (B, Hkv, S, D)
    length: jax.Array  # () int32 valid prefix — or (B,) for per-slot lengths


class PagedLayerCache(NamedTuple):
    """One layer's view of a block-paged KV cache (serving decode path).

    Token position j of slot b lives in page ``block_table[b, j // bs]`` at
    offset ``j % bs``. Entries ``>= num_pages`` mean "unmapped" — writes to
    them drop, gathers clamp (and the length mask hides whatever they read).
    When ``k_scale``/``v_scale`` are present the payload pools are int8 and
    dequantize per-(position, head) — serving/kv_quant.py layout.
    """

    k: jax.Array            # (num_pages, Hkv, block_size, D) page pool
    v: jax.Array
    block_table: jax.Array  # (B, pages_per_slot) int32
    length: jax.Array       # (B,) int32 valid tokens per slot
    k_scale: jax.Array | None = None  # (num_pages, Hkv, block_size, 1) f32
    v_scale: jax.Array | None = None


def paged_insert(cache: PagedLayerCache, kh: jax.Array, vh: jax.Array) -> PagedLayerCache:
    """Insert t decode tokens (B, Hkv, t, D) at positions length..length+t-1.

    t == 1 is the classic decode insert; t == k is the speculative verify
    insert (the k draft positions land in one scatter); t == chunk is the
    chunked-prefill insert (the chunk scatters in at the slot's current
    length). Unmapped pages (freed slots) and positions beyond the slot's
    table capacity map to the out-of-range sentinel, so those writes drop;
    per-slot page sets are disjoint by allocator invariant, so the scatter
    has no collisions.

    With prefix caching, a slot's table may reference SHARED pages (allocator
    refcount > 1) attached read-only from the radix index. The engine
    maintains the invariant that inserts never land in a shared page: shared
    pages are always full (attached at page granularity) and the slot's
    length starts past them, except for the one partially-resumed page that
    admission copy-on-writes (kernels/page_copy.py) and remaps BEFORE the
    first insert. This function therefore stays collision-free unchanged.
    """
    n, _, bs, _ = cache.k.shape
    nb = cache.block_table.shape[1]
    t = kh.shape[2]
    pos = cache.length[:, None] + jnp.arange(t)[None, :]           # (B, t)
    blk = jnp.clip(pos // bs, 0, nb - 1)
    page = jnp.take_along_axis(cache.block_table, blk, axis=1)     # (B, t)
    # positions past the table's capacity must not clamp into a REAL page
    # (that would corrupt another slot's block) — send them out of bounds
    page = jnp.where(pos < nb * bs, page, n)
    off = pos % bs
    k_tok = kh.transpose(0, 2, 1, 3)              # (B, t, Hkv, D)
    v_tok = vh.transpose(0, 2, 1, 3)
    if cache.k_scale is not None:
        from ..serving.kv_quant import quantize_kv

        k_q, k_s = quantize_kv(k_tok)
        v_q, v_s = quantize_kv(v_tok)
        return cache._replace(
            k=cache.k.at[page, :, off, :].set(k_q, mode="drop"),
            v=cache.v.at[page, :, off, :].set(v_q, mode="drop"),
            k_scale=cache.k_scale.at[page, :, off, :].set(k_s, mode="drop"),
            v_scale=cache.v_scale.at[page, :, off, :].set(v_s, mode="drop"),
            length=cache.length + t,
        )
    return cache._replace(
        k=cache.k.at[page, :, off, :].set(k_tok.astype(cache.k.dtype), mode="drop"),
        v=cache.v.at[page, :, off, :].set(v_tok.astype(cache.v.dtype), mode="drop"),
        length=cache.length + t,
    )


def paged_gather(cache: PagedLayerCache) -> tuple[jax.Array, jax.Array]:
    """Materialize each slot's logical KV sequence from its pages.

    Returns (k, v) of shape (B, Hkv, pages_per_slot * block_size, D) laid out
    so logical position j of the contiguous cache and position j here hold
    identical values — the decode einsum then matches the unpaged path.
    """
    n = cache.k.shape[0]
    bt = jnp.minimum(cache.block_table, n - 1)    # clamp unmapped; mask hides it

    def gather(pages, scale):
        g = pages[bt]                             # (B, nb, Hkv, bs, D)
        if scale is not None:
            g = g.astype(jnp.float32) * scale[bt]
        b, nb, h, bs, d = g.shape
        return g.transpose(0, 2, 1, 3, 4).reshape(b, h, nb * bs, d)

    return gather(cache.k, cache.k_scale), gather(cache.v, cache.v_scale)


def init_qkv(key, d_model, n_heads, n_kv, head_dim, dtype, bias=False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d_model)
    so = 1.0 / np.sqrt(n_heads * head_dim)
    p = {
        "q": (jax.random.normal(kq, (d_model, n_heads * head_dim)) * s).astype(dtype),
        "k": (jax.random.normal(kk, (d_model, n_kv * head_dim)) * s).astype(dtype),
        "v": (jax.random.normal(kv, (d_model, n_kv * head_dim)) * s).astype(dtype),
        "o": (jax.random.normal(ko, (n_heads * head_dim, d_model)) * so).astype(dtype),
    }
    if bias:
        p["q_bias"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["k_bias"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["v_bias"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _proj(x, w, b=None):
    y = apply_weight(x, w)
    if b is not None:
        y = y + b
    return y


def blockwise_attention(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
    causal_offset: jax.Array | int = 0,
) -> jax.Array:
    """Flash-style attention in pure jnp; O(T*D) memory, scores never stored.

    ``causal_offset``: query position i attends to keys <= i + offset (used
    when T < S, e.g. chunked prefill against a longer cache). A scalar applies
    one offset to every row; a ``(B,)`` vector gives each batch row its own
    offset — chunked prefill over a batch of slots at ragged lengths.
    """
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)
    bq = min(q_block, t)
    bk = min(kv_block, s)
    # pad to block multiples
    t_pad, s_pad = -t % bq, -s % bk
    if t_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    if s_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    tq, sk = q.shape[2], k.shape[2]
    nq, nk = tq // bq, sk // bk

    qb = q.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32) * scale
    kb = k.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    vb = v.reshape(b, hkv, nk, bk, d).astype(jnp.float32)

    q_pos = jnp.arange(tq).reshape(nq, bq)
    k_pos = jnp.arange(sk).reshape(nk, bk)
    valid_k = (k_pos < s)  # padding mask (nk, bk)
    offset = jnp.asarray(causal_offset)

    def q_step(_, qi):
        q_i = qb[:, :, :, qi]          # (b, hkv, group, bq, d)
        qp = q_pos[qi]                  # (bq,)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_i = kb[:, :, ki]          # (b, hkv, bk, d)
            v_i = vb[:, :, ki]
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_i)
            mask = valid_k[ki][None, None, None, None, :]
            if causal:
                if offset.ndim:         # per-slot offsets: (b, bq, bk) mask
                    cm = (qp[None, :, None] + offset[:, None, None]) \
                        >= k_pos[ki][None, None, :]
                    cm = cm[:, None, None]
                else:
                    cm = ((qp[:, None] + offset) >= k_pos[ki][None, :])[
                        None, None, None
                    ]
                mask = jnp.logical_and(mask, cm)
            sc = jnp.where(mask, sc, NEG_INF)
            m_cur = jnp.max(sc, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(sc - m_new)
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = corr * acc + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_i)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, group, bq, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, group, bq, 1), jnp.float32),
            jnp.zeros((b, hkv, group, bq, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        return None, acc / jnp.maximum(l, 1e-30)

    _, out = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq, b, hkv, g, bq, d)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, tq, d)
    return out[:, :, :t].astype(q.dtype)


def dense_attention(q, k, v, causal=True):
    """Small-shape oracle path."""
    from ..kernels.ref import attention_ref

    return attention_ref(q, k, v, causal=causal)


def attention_block(
    params: dict,
    x: jax.Array,                 # (B, T, d_model)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    positions: jax.Array | None = None,
    rope_theta: float | None = 1e4,
    causal: bool = True,
    cache: KVCache | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attention
    kernel_impl: str = "blockwise",
    q_block: int = 512,
    kv_block: int = 1024,
    causal_scheme: str = "full",
) -> tuple[jax.Array, KVCache | None]:
    """Full attention sub-block: projections + rope + attention + output.

    * training/prefill: cache is None (or preallocated for prefill fill-in)
    * decode: cache holds S past positions; x is (B, 1, d)
    * cross-attention: kv_override supplies precomputed (k, v) heads
    """
    b, t, _ = x.shape
    q = _proj(x, params["q"], params.get("q_bias"))
    q = q.reshape(b, t, n_heads, head_dim)

    if kv_override is not None:
        kh, vh = kv_override  # (B, Hkv, S, D)
        new_cache = cache
    else:
        k = _proj(x, params["k"], params.get("k_bias")).reshape(b, t, n_kv, head_dim)
        v = _proj(x, params["v"], params.get("v_bias")).reshape(b, t, n_kv, head_dim)
        if positions is None:
            positions = jnp.arange(t)[None, :]
        if rope_theta is not None:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
        kh = k.transpose(0, 2, 1, 3)  # (B, Hkv, T, D)
        vh = v.transpose(0, 2, 1, 3)
        if isinstance(cache, PagedLayerCache):
            # t == 1: classic paged decode; t == k: speculative verify;
            # t == chunk: chunked prefill — the t positions insert in one
            # scatter and attend through the same block-table gather
            # (query i sees keys <= length + i)
            new_cache = paged_insert(cache, kh, vh)
            kh, vh = paged_gather(new_cache)
        elif cache is not None:
            # insert at cache.length (decode: t == 1; chunked prefill: t == chunk)
            if jnp.ndim(cache.length) == 0:
                kc = jax.lax.dynamic_update_slice(
                    cache.k, kh.astype(cache.k.dtype), (0, 0, cache.length, 0)
                )
                vc = jax.lax.dynamic_update_slice(
                    cache.v, vh.astype(cache.v.dtype), (0, 0, cache.length, 0)
                )
            else:
                # per-slot lengths (batched serving): each sequence inserts at
                # its own write position — vmapped scatter with mode='drop' so
                # rows past the buffer end are DROPPED per-position (a
                # dynamic_update_slice would clamp the whole write start back
                # into the valid region when length + t > max_len, silently
                # shifting a ragged chunk's tail over valid history)
                ins = jax.vmap(
                    lambda ck, kn, pos: ck.at[
                        :, pos + jnp.arange(kn.shape[1]), :
                    ].set(kn, mode="drop")
                )
                kc = ins(cache.k, kh.astype(cache.k.dtype), cache.length)
                vc = ins(cache.v, vh.astype(cache.v.dtype), cache.length)
            new_cache = KVCache(kc, vc, cache.length + t)
            kh, vh = kc, vc
        else:
            new_cache = None

    qh = q.transpose(0, 2, 1, 3)  # (B, Hq, T, D)

    if cache is not None and kv_override is None:
        if (
            isinstance(cache, PagedLayerCache)
            and kernel_impl == "pallas"
            and cache.k_scale is None
        ):
            # Pallas paged-decode kernels: the page gather happens in the DMA
            # engine via the scalar-prefetched block table, not a jnp gather.
            # t == 1 is the single-query decode kernel; t > 1 the multi-query
            # variant (query i attends keys <= length + i) serving both the
            # k-wide speculative verify and chunk-wide chunked prefill.
            if t == 1:
                from ..kernels.ops import paged_attention

                out = paged_attention(
                    qh[:, :, 0], new_cache.k, new_cache.v,
                    new_cache.block_table, cache.length,
                )[:, :, None, :]
            else:
                from ..kernels.ops import paged_attention_kquery

                out = paged_attention_kquery(
                    qh, new_cache.k, new_cache.v,
                    new_cache.block_table, cache.length,
                )
        elif t > 1 and not isinstance(cache, PagedLayerCache):
            # chunked prefill into a cache: the dense masked-score path would
            # materialize (T, S) scores (34 GB/device measured on zamba2
            # prefill_32k) — use the flash path with a causal offset so query
            # i attends keys <= cache.length + i.
            if jnp.ndim(cache.length) != 0:
                # per-slot lengths (batched serving): each slot's chunk sits
                # at its own offset — blockwise path with a (B,) causal
                # offset; forward-only here, so the custom-VJP wrapper is
                # unnecessary
                out = blockwise_attention(
                    qh, kh, vh, causal=True, q_block=q_block,
                    kv_block=kv_block, causal_offset=cache.length,
                )
            else:
                from .flash_vjp import flash_attention_jax

                out = flash_attention_jax(
                    qh, kh, vh, True, q_block, kv_block, cache.length, "full"
                )
        else:
            # single-token decode (and k-token paged verify): O(t*S) masked
            # einsum — query i of slot b attends keys <= length[b] + i
            s = kh.shape[2]
            scale = 1.0 / np.sqrt(head_dim)
            group = n_heads // n_kv
            qg = qh.reshape(b, n_kv, group, t, head_dim).astype(jnp.float32) * scale
            sc = jnp.einsum("bhgtd,bhsd->bhgts", qg, kh.astype(jnp.float32))
            k_idx = jnp.arange(s)
            if jnp.ndim(cache.length) == 0:
                q_idx = cache.length + jnp.arange(t)[:, None]
                mask = k_idx[None, :] <= q_idx          # (t, s) causal prefix
                sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            else:
                # per-slot valid prefixes: query of slot b sits at length[b]
                q_idx = cache.length[:, None] + jnp.arange(t)[None, :]
                mask = k_idx[None, None, :] <= q_idx[..., None]  # (B, t, s)
                sc = jnp.where(mask[:, None, None], sc, NEG_INF)
            w = jax.nn.softmax(sc, axis=-1)
            out = jnp.einsum("bhgts,bhsd->bhgtd", w, vh.astype(jnp.float32))
            out = out.reshape(b, n_heads, t, head_dim).astype(x.dtype)
    elif kernel_impl == "pallas":
        from ..kernels.ops import flash_attention

        out = flash_attention(qh, kh, vh, causal=causal)
    elif kernel_impl == "dense":
        out = dense_attention(qh, kh, vh, causal=causal)
    else:
        # custom-VJP flash path: O(T) residuals (naive autodiff through the
        # blockwise scan would save the full O(T^2) probability tensors)
        from .flash_vjp import flash_attention_jax

        out = flash_attention_jax(
            qh, kh, vh, causal, q_block, kv_block, 0, causal_scheme
        )

    out = out.transpose(0, 2, 1, 3).reshape(b, t, n_heads * head_dim)
    if cache is None and kv_override is None:
        # expose the projected/rotated KV heads so prefill can build a cache
        # without re-running the projections (or a dense-score path)
        new_cache = (kh, vh)
    return apply_weight(out, params["o"]), new_cache
