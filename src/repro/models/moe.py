"""Mixture-of-Experts FFN with explicit expert parallelism.

Two execution paths:

* **shard_map EP** (under a mesh with a 'model' axis): activations at the MoE
  boundary are replicated across 'model' (they're P(data, None, None) — the
  same layout the dense TP blocks use), so each model-rank owns E/TP experts
  and simply processes ALL of its data-shard's tokens for ITS experts; the
  partial outputs are psum'd over 'model' — the exact collective pattern of a
  row-parallel dense FFN. No all_to_all, no GSPMD guesswork. Leaving dispatch
  to GSPMD instead was measured to all-gather ~1 TB/device/step on
  dbrx-132b train_4k (see EXPERIMENTS.md §Perf iteration 1).

* **single-device** path (tests, CPU): same math, one "rank" owning all
  experts.

Dispatch inside a rank is scatter-based (argsort + capacity), NOT one-hot
einsum: a (tokens, E, capacity) one-hot for 1M tokens x 128 experts costs
40-80 GB and ~1e17 counted multiply-by-zero FLOPs which would falsify the
roofline's compute term (DESIGN.md §4).

Capacity semantics: capacity is per (data-shard, expert): C = ceil(T_local *
top_k / E * capacity_factor); overflow tokens are dropped in router-score
order (Switch/GShard convention).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..parallel.sharding import _current_mesh, logical_to_mesh


def init_moe(key, d: int, d_ff: int, num_experts: int, dtype) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    si, so = 1.0 / np.sqrt(d), 1.0 / np.sqrt(d_ff)
    return {
        "router": (jax.random.normal(kr, (d, num_experts)) * si).astype(jnp.float32),
        "experts": {
            "gate": (jax.random.normal(k1, (num_experts, d, d_ff)) * si).astype(dtype),
            "up": (jax.random.normal(k2, (num_experts, d, d_ff)) * si).astype(dtype),
            "down": (jax.random.normal(k3, (num_experts, d_ff, d)) * so).astype(dtype),
        },
    }


def _expert_compute(buf, w):
    """buf: (E_loc, C, d); w: experts dict with (E_loc, d, f) leaves."""
    h = jnp.einsum("ecd,edf->ecf", buf, w["gate"].astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w["up"].astype(buf.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w["down"].astype(buf.dtype))


def _rank_moe(
    x_flat,        # (T_loc, d) tokens this rank must serve
    gate_vals,     # (T_loc, k) normalized router weights
    expert_ids,    # (T_loc, k) global expert ids
    experts,       # dict of (E_loc, d, f) local expert weights
    e_offset,      # global id of this rank's first expert
    num_local: int,
    cap: int,
):
    """Dispatch/compute/combine for the experts owned by this rank."""
    t_loc, d = x_flat.shape
    k = expert_ids.shape[-1]
    flat_e = expert_ids.reshape(-1) - e_offset          # local expert ids
    flat_g = gate_vals.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(t_loc), k)
    mine = (flat_e >= 0) & (flat_e < num_local)
    e_for_sort = jnp.where(mine, flat_e, num_local)     # park foreign slots at E
    order = jnp.argsort(e_for_sort)                     # stable by expert
    e_sorted = e_for_sort[order]
    counts = jnp.bincount(e_sorted, length=num_local + 1)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(t_loc * k) - starts[e_sorted]
    keep = (pos_sorted < cap) & (e_sorted < num_local)

    buf = jnp.zeros((num_local, cap, d), x_flat.dtype)
    src = x_flat[tok_of[order]]
    buf = buf.at[
        jnp.where(keep, e_sorted, 0), jnp.where(keep, pos_sorted, 0)
    ].add(jnp.where(keep[:, None], src, 0), mode="drop")

    y_buf = _expert_compute(buf, experts)

    vals = y_buf[jnp.where(keep, e_sorted, 0), jnp.where(keep, pos_sorted, 0)]
    vals = jnp.where(keep[:, None], vals, 0)
    out = jnp.zeros((t_loc, d), y_buf.dtype)
    out = out.at[tok_of[order]].add(vals * flat_g[order][:, None])
    return out


def _route(x_flat, router_w, num_experts, top_k):
    logits = x_flat.astype(jnp.float32) @ router_w      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e fraction_routed_e * mean_prob_e
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], num_experts), axis=0)
    aux = num_experts * jnp.sum(fe * me)
    return gate_vals, expert_ids, aux


def moe_ffn(
    params: dict,
    x: jax.Array,              # (B, T, d)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    num_groups: int | None = None,  # kept for config compat; unused
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B, T, d), aux_loss ())."""
    b, t, d = x.shape
    mesh = _current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1

    if mesh is not None and tp > 1 and num_experts % tp == 0:
        dp_ax = logical_to_mesh("data", mesh)
        dp = int(np.prod([mesh.shape[a] for a in (dp_ax if isinstance(dp_ax, tuple) else (dp_ax,)) if a]))
        e_loc = num_experts // tp
        t_loc = (b // max(dp, 1)) * t if b % max(dp, 1) == 0 else b * t
        cap = max(int(np.ceil(t_loc * top_k / num_experts * capacity_factor)), top_k)

        def local_fn(xl, router_w, experts):
            # xl: (b_loc, t, d) — replicated over 'model', sharded over data
            bl = xl.shape[0]
            x_flat = xl.reshape(bl * t, d)
            gate_vals, expert_ids, aux = _route(x_flat, router_w, num_experts, top_k)
            m_idx = jax.lax.axis_index("model")
            out = _rank_moe(
                x_flat, gate_vals, expert_ids, experts,
                e_offset=m_idx * e_loc, num_local=e_loc, cap=cap,
            )
            out = jax.lax.psum(out, "model")            # sum expert contributions
            aux = jax.lax.pmean(aux, dp_ax) if dp_ax else aux
            return out.reshape(bl, t, d), aux[None]

        from jax.experimental.shard_map import shard_map

        batch_ax = dp_ax if b % max(dp, 1) == 0 else None
        fn = shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(
                P(batch_ax, None, None),
                P(None, None),           # router replicated (tiny)
                jax.tree.map(lambda _: P("model", None, None), params["experts"]),
            ),
            out_specs=(P(batch_ax, None, None), P(None)),
            check_rep=False,
        )
        out, aux = fn(x, params["router"], params["experts"])
        return out.astype(x.dtype), jnp.mean(aux)

    # ---------------- single-rank fallback (tests / CPU / no model axis) ----
    x_flat = x.reshape(b * t, d)
    cap = max(int(np.ceil(b * t * top_k / num_experts * capacity_factor)), top_k)
    gate_vals, expert_ids, aux = _route(x_flat, params["router"], num_experts, top_k)
    out = _rank_moe(
        x_flat, gate_vals, expert_ids, params["experts"],
        e_offset=0, num_local=num_experts, cap=cap,
    )
    return out.reshape(b, t, d).astype(x.dtype), aux
