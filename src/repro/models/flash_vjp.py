"""Pure-JAX flash attention with a custom VJP (O(T) residuals).

Differentiating naively through the blockwise-softmax scan makes autodiff
save the per-chunk probability tensors — the full O(T^2) score matrix per
layer (~17 GB/device/layer at 4k seq on olmo_1b; measured via the dry-run
buffer dump). The standard flash-attention backward fixes this: save only
(out, lse) per row and RECOMPUTE the probabilities blockwise in the VJP.

Forward:  online softmax over kv chunks (same math as models/attention.py).
Backward: D = rowsum(dO * O); per (q-chunk, kv-chunk): P = exp(S - lse),
          dV += P^T dO;  dS = P * (dO V^T - D) * scale;  dQ += dS K;
          dK += dS^T Q.  GQA folds the group axis into the dK/dV sums.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _pad_axis(x, mult, axis):
    pad = -x.shape[axis] % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash_core(q, k, v, causal_offset, causal, q_block, kv_block, scheme):
    # causal_offset is a (traced) int array argument — chunked prefill passes
    # the runtime cache length; nondiff_argnums cannot hold tracers.
    out, _ = _fwd_impl(q, k, v, causal, q_block, kv_block, causal_offset, scheme)
    return out


def flash_attention_jax(
    q, k, v, causal=True, q_block=512, kv_block=1024, causal_offset=0, scheme="full"
):
    return _flash_core(
        q, k, v, jnp.asarray(causal_offset, jnp.int32), causal, q_block, kv_block, scheme
    )


def _fwd_impl(q, k, v, causal, q_block, kv_block, causal_offset, scheme="full"):
    if (
        scheme == "balanced"
        and causal
        and q.shape[2] == k.shape[2]
        and q.shape[2] % min(q_block, q.shape[2]) == 0
    ):
        # triangle-only scheme: ~2x fewer score FLOPs (see flash_balanced.py)
        from .flash_balanced import balanced_causal_fwd

        return balanced_causal_fwd(q, k, v, q_block, causal_offset)
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)
    bq, bk = min(q_block, t), min(kv_block, s)
    qp = _pad_axis(q, bq, 2)
    kp = _pad_axis(k, bk, 2)
    vp = _pad_axis(v, bk, 2)
    tq, sk = qp.shape[2], kp.shape[2]
    nq, nk = tq // bq, sk // bk

    qb = qp.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32) * scale
    kb = kp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    vb = vp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    q_pos = jnp.arange(tq).reshape(nq, bq)
    k_pos = jnp.arange(sk).reshape(nk, bk)
    valid_k = k_pos < s

    def q_step(_, qi):
        q_i = qb[:, :, :, qi]

        def kv_step(carry, ki):
            m, l, acc = carry
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, kb[:, :, ki])
            mask = valid_k[ki][None, None, None, None, :]
            if causal:
                cm = (q_pos[qi][:, None] + causal_offset) >= k_pos[ki][None, :]
                mask = jnp.logical_and(mask, cm[None, None, None])
            sc = jnp.where(mask, sc, NEG_INF)
            m_cur = jnp.max(sc, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(sc - m_new)
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = corr * acc + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb[:, :, ki])
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, group, bq, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, group, bq, 1), jnp.float32),
            jnp.zeros((b, hkv, group, bq, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        lse = m[..., 0] + jnp.log(jnp.maximum(l[..., 0], 1e-30))
        return None, (acc / jnp.maximum(l, 1e-30), lse)

    _, (ob, lse) = jax.lax.scan(q_step, None, jnp.arange(nq))
    # ob: (nq, b, hkv, g, bq, d); lse: (nq, b, hkv, g, bq)
    out = ob.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, tq, d)[:, :, :t]
    return out.astype(q.dtype), lse


def _fwd(q, k, v, causal_offset, causal, q_block, kv_block, scheme):
    out, lse = _fwd_impl(q, k, v, causal, q_block, kv_block, causal_offset, scheme)
    return out, (q, k, v, out, lse, causal_offset)


def _bwd(causal, q_block, kv_block, scheme, res, dout):
    # backward reuses the full scheme regardless of the forward scheme: the
    # residuals (q, k, v, out, lse) are scheme-independent.
    q, k, v, out, lse, causal_offset = res
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)
    bq, bk = min(q_block, t), min(kv_block, s)
    qp = _pad_axis(q, bq, 2)
    kp = _pad_axis(k, bk, 2)
    vp = _pad_axis(v, bk, 2)
    dop = _pad_axis(dout, bq, 2)
    op = _pad_axis(out, bq, 2)
    tq, sk = qp.shape[2], kp.shape[2]
    nq, nk = tq // bq, sk // bk

    qb = qp.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32) * scale
    kb = kp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    vb = vp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    dob = dop.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32)
    ob = op.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32)
    ddelta = jnp.sum(dob * ob, axis=-1)  # (b,hkv,g,nq,bq)
    q_pos = jnp.arange(tq).reshape(nq, bq)
    k_pos = jnp.arange(sk).reshape(nk, bk)
    valid_k = k_pos < s

    def q_step(carry, qi):
        dk_acc, dv_acc = carry  # (nk, b, hkv, bk, d) each
        q_i = qb[:, :, :, qi]
        do_i = dob[:, :, :, qi]
        lse_i = lse[qi][..., None]       # (b,hkv,g,bq,1)
        dd_i = ddelta[:, :, :, qi][..., None]

        def kv_step(carry2, ki):
            dq_i, dk_acc, dv_acc = carry2
            k_j, v_j = kb[:, :, ki], vb[:, :, ki]
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_j)
            mask = valid_k[ki][None, None, None, None, :]
            if causal:
                cm = (q_pos[qi][:, None] + causal_offset) >= k_pos[ki][None, :]
                mask = jnp.logical_and(mask, cm[None, None, None])
            sc = jnp.where(mask, sc, NEG_INF)
            p = jnp.exp(sc - lse_i)      # recomputed probs (bhgqk)
            dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, do_i)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_i, v_j)
            ds = p * (dp - dd_i)
            dq_i = dq_i + jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_j)
            dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds, q_i)
            dk_acc = dk_acc.at[ki].add(dk_j)
            dv_acc = dv_acc.at[ki].add(dv_j)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, hkv, group, bq, d), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk)
        )
        return (dk_acc, dv_acc), dq_i * scale

    dk0 = jnp.zeros((nk, b, hkv, bk, d), jnp.float32)
    dv0 = jnp.zeros((nk, b, hkv, bk, d), jnp.float32)
    (dk_acc, dv_acc), dq_chunks = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))

    dq = dq_chunks.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, tq, d)[:, :, :t]
    # no extra scale on dk: qb already carries the 1/sqrt(d) factor
    dk = dk_acc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk, d)[:, :, :s]
    dv = dv_acc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk, d)[:, :, :s]
    d_off = np.zeros(causal_offset.shape, jax.dtypes.float0)  # int arg: no grad
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), d_off


_flash_core.defvjp(_fwd, _bwd)
