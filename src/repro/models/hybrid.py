"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention+MLP block
applied after every ``attn_every`` SSM layers (weight sharing across depth).

Structure: outer scan over G = num_layers/attn_every groups; inside a group,
inner scan over the group's SSM layers, then the shared attention block
(params NOT scanned — broadcast into the body, so sharing is structural and
SALAAD counts the shared block once, matching the real architecture).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import constrain
from .attention import KVCache, attention_block, init_qkv
from .layers import apply_mlp, apply_norm, apply_weight, embed, init_embedding, init_mlp, init_norm
from .ssm import SSMCache, init_ssm_cache, init_ssm_layer, ssm_block, ssm_dims


class HybridCache(NamedTuple):
    ssm_state: jax.Array   # (L, B, H, P, N)
    conv: jax.Array        # (L, B, 3, conv_dim)
    k: jax.Array           # (G, B, Hkv, S, D) shared-attn cache per application
    v: jax.Array
    length: jax.Array      # ()


def init_hybrid(cfg, key) -> dict:
    assert cfg.num_layers % cfg.attn_every == 0
    g = cfg.num_layers // cfg.attn_every
    ke, kl, ks, kh = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.num_layers).reshape(g, cfg.attn_every, 2)

    def one(k):
        return init_ssm_layer(
            k, cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state, cfg.param_dtype
        )

    ssm_layers = jax.vmap(jax.vmap(lambda k: one(k)))(layer_keys)  # (G, E, ...)
    shared = {}
    shared.update(
        init_qkv(ks, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.param_dtype)
    )
    shared["pre_attn"] = init_norm(jax.random.fold_in(ks, 1), cfg.d_model, cfg.norm_type, cfg.param_dtype)
    shared["pre_mlp"] = init_norm(jax.random.fold_in(ks, 2), cfg.d_model, cfg.norm_type, cfg.param_dtype)
    shared.update(init_mlp(jax.random.fold_in(ks, 3), cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.param_dtype))
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "ssm_layers": ssm_layers,
        "shared_attn": shared,
        "final_norm": init_norm(jax.random.fold_in(ke, 1), cfg.d_model, cfg.norm_type, cfg.param_dtype),
        "lm_head": {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)).astype(cfg.param_dtype)
        },
    }


def forward(params, tokens, cfg, *, cache: HybridCache | None = None, position_offset=0):
    """Returns (logits, new_cache_or_None, aux=0)."""
    g = cfg.num_layers // cfg.attn_every
    x = embed(params["embed"], tokens)
    b, t, _ = x.shape
    x = constrain(x, ("data", None, None))
    positions = position_offset + jnp.arange(t)[None, :]
    shared = params["shared_attn"]

    def group_body(carry, inp):
        x = carry
        if cache is None:
            glp = inp

            def inner(x, lp):
                h = apply_norm(x, None, "rmsnorm")
                out, _ = ssm_block(
                    lp, h, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                    d_state=cfg.ssm_state, chunk=cfg.ssm_chunk, cache=None,
                )
                return x + out, None

            # nested remat: the group-level checkpoint alone re-materializes
            # ALL attn_every layers' SSD internals during the group backward
            # (~30 GB on zamba2 train_4k); per-layer checkpointing inside
            # bounds it to one layer at a time.
            fn = jax.checkpoint(inner) if cfg.remat else inner
            x, _ = jax.lax.scan(fn, x, glp, unroll=cfg.scan_unroll)
            att_cache = None
        else:
            glp, st, cv, k_g, v_g = inp

            def inner(x, lps):
                lp, st_l, cv_l = lps
                h = apply_norm(x, None, "rmsnorm")
                c = SSMCache(st_l, cv_l, cache.length)
                out, nc = ssm_block(
                    lp, h, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                    d_state=cfg.ssm_state, chunk=cfg.ssm_chunk, cache=c,
                )
                return x + out, (nc.state, nc.conv)

            x, (st_new, cv_new) = jax.lax.scan(inner, x, (glp, st, cv), unroll=cfg.scan_unroll)
            att_cache = KVCache(k_g, v_g, cache.length)

        # shared attention + MLP block
        h = apply_norm(x, shared.get("pre_attn"), cfg.norm_type)
        attn_out, kv = attention_block(
            shared, h,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
            positions=positions, rope_theta=cfg.rope_theta, causal=True,
            cache=att_cache, kernel_impl=cfg.kernel_impl,
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
            causal_scheme=cfg.causal_scheme,
        )
        x = x + attn_out
        h = apply_norm(x, shared.get("pre_mlp"), cfg.norm_type)
        x = x + apply_mlp(shared, h, cfg.mlp_type)
        x = constrain(x, ("data", None, None))
        if cache is None:
            return x, None
        return x, (st_new, cv_new, kv.k, kv.v)

    if cache is None:
        # remat the FULL group (SSM layers + shared attn/MLP): leaving the
        # shared block un-rematted keeps ~40 GB of its residuals live across
        # all 9 applications (measured on zamba2 train_4k)
        body = jax.checkpoint(group_body) if cfg.remat else group_body
        x, _ = jax.lax.scan(body, x, params["ssm_layers"], unroll=cfg.scan_unroll)
        new_cache = None
    else:
        st = cache.ssm_state.reshape(g, cfg.attn_every, *cache.ssm_state.shape[1:])
        cv = cache.conv.reshape(g, cfg.attn_every, *cache.conv.shape[1:])
        x, (st_n, cv_n, k_n, v_n) = jax.lax.scan(
            group_body, x, (params["ssm_layers"], st, cv, cache.k, cache.v), unroll=cfg.scan_unroll
        )
        new_cache = HybridCache(
            ssm_state=st_n.reshape(cfg.num_layers, *st_n.shape[2:]),
            conv=cv_n.reshape(cfg.num_layers, *cv_n.shape[2:]),
            k=k_n, v=v_n, length=cache.length + t,
        )

    x = apply_norm(x, params.get("final_norm"), cfg.norm_type)
    logits = apply_weight(x, params["lm_head"]["w"])
    logits = constrain(logits, ("data", None, "model"))
    return logits, new_cache, jnp.zeros((), jnp.float32)


def init_hybrid_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> HybridCache:
    g = cfg.num_layers // cfg.attn_every
    d_inner, nheads, conv_dim = ssm_dims(
        cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state
    )
    return HybridCache(
        ssm_state=jnp.zeros((cfg.num_layers, batch, nheads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        conv=jnp.zeros((cfg.num_layers, batch, 3, conv_dim), dtype),
        k=jnp.zeros((g, batch, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
        v=jnp.zeros((g, batch, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )
