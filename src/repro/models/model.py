"""Unified model API over all families.

    init_params(cfg, key)                      -> params pytree
    abstract_params(cfg)                       -> ShapeDtypeStruct pytree
    train_step_fn(cfg)                         -> loss(params, batch)
    prefill_fn(cfg, max_len)                   -> (params, batch) -> (logits, cache)
    decode_fn(cfg)                             -> (params, token, cache) -> (logits, cache)
    input_specs(cfg, shape, max_len)           -> ShapeDtypeStruct batch stand-ins
    init_cache(cfg, batch, max_len)            -> family-appropriate cache

Modality frontends (audio frames / vision patches) are STUBS per the
assignment: ``input_specs`` provides the precomputed embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec, hybrid, ssm_lm, transformer


def init_params(cfg: ArchConfig, key) -> Any:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_lm(cfg, key)
    if cfg.family == "ssm":
        return ssm_lm.init_ssm_lm(cfg, key)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid(cfg, key)
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key)
    raise ValueError(cfg.family)


def abstract_params(cfg: ArchConfig) -> Any:
    """Shape/dtype tree without allocating anything (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def _forward(params, batch: dict, cfg: ArchConfig, cache=None, position_offset=0, collect_kv=False):
    tokens = batch["tokens"]
    if cfg.family in ("dense", "moe"):
        return transformer.forward(
            params, tokens, cfg, cache=cache, position_offset=position_offset,
            collect_kv=collect_kv,
        )
    if cfg.family == "vlm":
        return transformer.forward(
            params, tokens, cfg, prefix_embeds=batch.get("patches"),
            cache=cache, position_offset=position_offset, collect_kv=collect_kv,
        )
    if cfg.family == "ssm":
        return ssm_lm.forward(params, tokens, cfg, cache=cache, position_offset=position_offset)
    if cfg.family == "hybrid":
        return hybrid.forward(params, tokens, cfg, cache=cache, position_offset=position_offset)
    if cfg.family == "encdec":
        return encdec.forward(
            params, tokens, cfg, frames=batch.get("frames"),
            cache=cache, position_offset=position_offset,
        )
    raise ValueError(cfg.family)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """Shard-friendly CE: the gold logit is extracted with a masked reduction
    instead of take_along_axis — a vocab-dim gather would force GSPMD to
    all-gather the full vocab axis (13 GB/device at OLMo scale; measured).
    Max/sum reductions over the sharded vocab axis lower to cheap psums."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    gold_mask = vocab_iota == labels[..., None]
    gold = jnp.sum(jnp.where(gold_mask, shifted, 0.0), axis=-1) + m[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(params, batch: dict, cfg: ArchConfig, aux_weight: float = 0.01):
    """Next-token CE + MoE load-balance aux. VLM: loss on text tail only."""
    logits, _, aux = _forward(params, batch, cfg)
    if cfg.family == "vlm" and "patches" in batch:
        p = batch["patches"].shape[1]
        logits = logits[:, p:]
    labels = batch["labels"]
    loss = cross_entropy(logits, labels, batch.get("loss_mask"))
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# --------------------------------------------------------------- serving ---


def prefill(params, batch: dict, cfg: ArchConfig, max_len: int, cache_dtype=jnp.bfloat16):
    """Full-sequence forward building a decode cache. Returns (logits, cache)."""
    if cfg.family in ("dense", "moe", "vlm"):
        logits, kvs, _ = _forward(params, batch, cfg, collect_kv=True)
        cache = transformer.cache_from_prefill(cfg, kvs, max_len, dtype=cache_dtype)
        return logits, cache
    if cfg.family == "ssm":
        cache = ssm_lm.init_ssm_lm_cache(cfg, batch["tokens"].shape[0])
        logits, new_cache, _ = ssm_lm.forward(params, batch["tokens"], cfg, cache=cache)
        return logits, new_cache
    if cfg.family == "hybrid":
        cache = hybrid.init_hybrid_cache(cfg, batch["tokens"].shape[0], max_len)
        logits, new_cache, _ = hybrid.forward(params, batch["tokens"], cfg, cache=cache)
        return logits, new_cache
    if cfg.family == "encdec":
        enc_out = encdec.encode(params, batch["frames"], cfg)
        cache = encdec.init_encdec_cache(
            params, cfg, batch["tokens"].shape[0], max_len, enc_out=enc_out
        )
        logits, new_cache = encdec.decode_stack(params, batch["tokens"], None, cfg, cache=cache)
        return logits, new_cache
    raise ValueError(cfg.family)


def decode_step(params, token: jax.Array, cache, cfg: ArchConfig):
    """One autoregressive step. token: (B, 1). Returns (logits, new_cache)."""
    offset = cache.length
    logits, new_cache, _ = _forward(params, {"tokens": token}, cfg, cache=cache, position_offset=offset)
    return logits, new_cache


def chunk_prefill_step(params, tokens: jax.Array, counts: jax.Array, cache,
                       cfg: ArchConfig):
    """One chunked-prefill step: process a ``(B, C)`` token chunk against an
    existing cache at each slot's current length.

    Per slot b the chunk's KV lands at positions ``length[b] ..
    length[b] + C - 1`` (paged: scattered into pages through the block table;
    contiguous: vmapped slice insert) and query i attends keys ``<= length[b]
    + i`` — history plus the causal prefix of the chunk itself. Rows may be
    RAGGED: only ``counts[b]`` leading tokens are valid, and lengths advance
    by ``counts`` (not C), so the padded tail wrote junk KV past the valid
    prefix — never attended (length-masked) and overwritten by the next real
    insert at the same positions. ``counts[b] == 0`` rows are pure padding.

    Returns ``(logits (B, C, vocab), new_cache)``; the last VALID position's
    logits (``logits[b, counts[b] - 1]``) continue the sequence. Chaining
    chunks over an empty cache reproduces one-shot prefill exactly
    (tests/test_chunked_prefill.py asserts bitwise equality).
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"chunked prefill needs a KV-cache family, got {cfg.family!r}"
        )
    n0 = cache.length
    logits, new_cache, _ = _forward(
        params, {"tokens": tokens}, cfg, cache=cache, position_offset=n0
    )
    return logits, new_cache._replace(length=n0 + counts)


def init_paged_cache(
    cfg: ArchConfig,
    max_slots: int,
    num_pages: int,
    block_size: int,
    pages_per_slot: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
):
    """Block-paged serving cache (KV-cache families only)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"paged KV cache needs a KV-cache family, got {cfg.family!r}"
        )
    return transformer.init_paged_cache(
        cfg, max_slots, num_pages, block_size, pages_per_slot,
        dtype=dtype, quantized=quantized,
    )


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "ssm":
        return ssm_lm.init_ssm_lm_cache(cfg, batch, dtype)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid_cache(cfg, batch, max_len, dtype)
    if cfg.family == "encdec":
        return encdec.init_encdec_cache(None, cfg, batch, max_len, enc_out=None, dtype=dtype)
    raise ValueError(cfg.family)


# ------------------------------------------------------------ input specs --


def input_specs(cfg: ArchConfig, shape: ShapeConfig, per_host: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a given cell.

    train/prefill: {tokens, labels [, frames | patches]}
    decode: {token} (cache specs come from init_cache via eval_shape).
    """
    b = per_host or shape.global_batch
    t = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        return specs
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, t), i32),
        "labels": jax.ShapeDtypeStruct((b, t), i32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.param_dtype
        )
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), cfg.param_dtype
        )
    return specs
