"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones."""
from . import attention, encdec, hybrid, layers, model, moe, ssm, ssm_lm, transformer  # noqa: F401
