"""Balanced-causal flash attention forward: compute ONLY the lower triangle.

The straightforward causal blockwise scan visits all nq x nk chunk pairs and
masks the upper triangle — half the score FLOPs are multiply-by-minus-inf.
This variant pairs q-chunk i with q-chunk (nq-1-i): together they need
(i+1) + (nq-i) = nq+1 kv-chunk visits — CONSTANT per pair — so the total is
ceil(nq/2) * (nq+1) ~= nq^2/2 chunk visits with fully static shapes (no cond,
no dynamic trip counts). Each inner step computes ONE score block for
whichever of the two q-chunks needs it (a where-select on the small q/row
state, not on the matmul).

This is the '§Perf causal_scheme=balanced' iteration: same math (validated
against the dense oracle), ~2x fewer attention-score FLOPs in the compiled
HLO for causal prefill/train. Forward only — the backward reuses the full
scheme (its analytic-correction accounting is separate; see dryrun.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def balanced_causal_fwd(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, T, D)
    v: jax.Array,
    q_block: int = 512,
    causal_offset: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (B,Hq,T,D), lse (nq,B,Hkv,G,bq)). Requires T == S and
    T % q_block == 0 (the serving/dry-run shapes satisfy this; the generic
    path handles ragged cases)."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert t == s and t % min(q_block, t) == 0
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)
    bq = min(q_block, t)
    nq = t // bq

    qb = q.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32) * scale
    kb = k.reshape(b, hkv, nq, bq, d).astype(jnp.float32)
    vb = v.reshape(b, hkv, nq, bq, d).astype(jnp.float32)
    pos = jnp.arange(t).reshape(nq, bq)

    npairs = (nq + 1) // 2

    def pair_step(_, pi):
        i = pi
        j = nq - 1 - pi
        q_i = qb[:, :, :, i]
        q_j = qb[:, :, :, j]
        pos_i, pos_j = pos[i], pos[j]
        j_valid = j != i  # odd nq: middle chunk served once as i

        def kv_step(carry, tstep):
            (mi, li, acci), (mj, lj, accj) = carry
            serve_i = tstep <= i
            kv_idx = jnp.where(serve_i, tstep, tstep - i - 1)
            q_blk = jnp.where(serve_i, q_i, q_j)
            qpos = jnp.where(serve_i, pos_i, pos_j)

            sc = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, kb[:, :, kv_idx])
            cm = (qpos[:, None] + causal_offset) >= pos[kv_idx][None, :]
            sc = jnp.where(cm[None, None, None], sc, NEG_INF)

            def online(mx, lx, accx):
                m_cur = jnp.max(sc, axis=-1, keepdims=True)
                m_new = jnp.maximum(mx, m_cur)
                p = jnp.exp(sc - m_new)
                corr = jnp.exp(mx - m_new)
                l_new = corr * lx + jnp.sum(p, axis=-1, keepdims=True)
                acc_new = corr * accx + jnp.einsum(
                    "bhgqk,bhkd->bhgqd", p, vb[:, :, kv_idx]
                )
                return m_new, l_new, acc_new

            mi2, li2, acci2 = online(mi, li, acci)
            mj2, lj2, accj2 = online(mj, lj, accj)
            upd_j = jnp.logical_and(~serve_i, j_valid)
            sel = lambda c, a, bb: jnp.where(c, a, bb)  # noqa: E731
            new_i = (sel(serve_i, mi2, mi), sel(serve_i, li2, li), sel(serve_i, acci2, acci))
            new_j = (sel(upd_j, mj2, mj), sel(upd_j, lj2, lj), sel(upd_j, accj2, accj))
            return (new_i, new_j), None

        init = lambda: (  # noqa: E731
            jnp.full((b, hkv, group, bq, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, group, bq, 1), jnp.float32),
            jnp.zeros((b, hkv, group, bq, d), jnp.float32),
        )
        ((mi, li, acci), (mj, lj, accj)), _ = jax.lax.scan(
            kv_step, (init(), init()), jnp.arange(nq + 1)
        )
        out_i = acci / jnp.maximum(li, 1e-30)
        out_j = accj / jnp.maximum(lj, 1e-30)
        lse_i = mi[..., 0] + jnp.log(jnp.maximum(li[..., 0], 1e-30))
        lse_j = mj[..., 0] + jnp.log(jnp.maximum(lj[..., 0], 1e-30))
        return None, (out_i, out_j, lse_i, lse_j)

    _, (oi, oj, lse_i, lse_j) = jax.lax.scan(pair_step, None, jnp.arange(npairs))
    # oi[p] is q-chunk p; oj[p] is q-chunk nq-1-p. Assemble in chunk order.
    order = np.zeros(nq, np.int32)
    src_is_j = np.zeros(nq, bool)
    for p in range(npairs):
        order[p] = p
        if nq - 1 - p != p:
            order[nq - 1 - p] = p
            src_is_j[nq - 1 - p] = True
    o_chunks = jnp.where(
        jnp.asarray(src_is_j)[:, None, None, None, None, None],
        oj[jnp.asarray(order)],
        oi[jnp.asarray(order)],
    )  # (nq, b, hkv, g, bq, d)
    lse = jnp.where(
        jnp.asarray(src_is_j)[:, None, None, None, None],
        lse_j[jnp.asarray(order)],
        lse_i[jnp.asarray(order)],
    )
    out = o_chunks.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, t, d)
    return out.astype(q.dtype), lse
