"""Mamba2 decoder-only LM (attention-free, SSD blocks)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import constrain
from .layers import apply_norm, apply_weight, embed, init_embedding, init_norm
from .ssm import SSMCache, init_ssm_layer, ssm_block, ssm_dims


class SSMLMCache(NamedTuple):
    state: jax.Array   # (L, B, H, P, N)
    conv: jax.Array    # (L, B, 3, conv_dim)
    length: jax.Array


def init_ssm_lm(cfg, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(
        lambda k: init_ssm_layer(
            k, cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state, cfg.param_dtype
        )
    )(layer_keys)
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "final_norm": init_norm(jax.random.fold_in(ke, 1), cfg.d_model, cfg.norm_type, cfg.param_dtype),
        "lm_head": {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)).astype(cfg.param_dtype)
        },
    }


def forward(params, tokens, cfg, *, cache: SSMLMCache | None = None, position_offset=0):
    x = embed(params["embed"], tokens)
    b, t, _ = x.shape
    x = constrain(x, ("data", None, None))

    if cache is None:
        def body(x, lp):
            h = apply_norm(x, None, "rmsnorm")
            out, _ = ssm_block(
                lp, h, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                d_state=cfg.ssm_state, chunk=cfg.ssm_chunk, cache=None,
            )
            return constrain(x + out, ("data", None, None)), None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"], unroll=cfg.scan_unroll)
        new_cache = None
    else:
        def body(x, inp):
            lp, st_l, cv_l = inp
            h = apply_norm(x, None, "rmsnorm")
            c = SSMCache(st_l, cv_l, cache.length)
            out, nc = ssm_block(
                lp, h, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                d_state=cfg.ssm_state, chunk=cfg.ssm_chunk, cache=c,
            )
            return x + out, (nc.state, nc.conv)

        x, (st_n, cv_n) = jax.lax.scan(body, x, (params["layers"], cache.state, cache.conv), unroll=cfg.scan_unroll)
        new_cache = SSMLMCache(st_n, cv_n, cache.length + t)

    x = apply_norm(x, params.get("final_norm"), cfg.norm_type)
    logits = apply_weight(x, params["lm_head"]["w"])
    return constrain(logits, ("data", None, "model")), new_cache, jnp.zeros((), jnp.float32)


def init_ssm_lm_cache(cfg, batch: int, dtype=jnp.bfloat16) -> SSMLMCache:
    d_inner, nheads, conv_dim = ssm_dims(
        cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state
    )
    return SSMLMCache(
        state=jnp.zeros((cfg.num_layers, batch, nheads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        conv=jnp.zeros((cfg.num_layers, batch, 3, conv_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )
