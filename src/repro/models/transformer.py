"""Decoder-only transformer LM (dense / MoE / VLM-backbone) with scan-stacked
layers, optional remat, KV-cache decode, and sharding constraints.

Layers are stacked along a leading axis and applied with ``jax.lax.scan`` so
the HLO (and compile time) is depth-independent — mandatory for the 80-layer
dry-run cells. SALAAD sees the stacked leaves and treats every slice as an
independent block (core/selection.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import constrain
from .attention import KVCache, PagedLayerCache, attention_block, init_qkv
from .layers import apply_mlp, apply_norm, apply_weight, embed, init_embedding, init_mlp, init_norm
from .moe import init_moe, moe_ffn


class LMCache(NamedTuple):
    k: jax.Array       # (L, B, Hkv, S, D)
    v: jax.Array
    length: jax.Array  # () — or (B,) for per-slot serving lengths


class PagedKVCache(NamedTuple):
    """Block-paged serving cache: a fixed pool of pages per layer plus a
    per-slot block table. Serving memory is governed by ``num_pages`` (the
    actual budget), not ``max_slots * max_len`` (the worst case). The block
    table and lengths are shared across layers; position j of slot b lives in
    page ``block_table[b, j // block_size]``, offset ``j % block_size``.

    int8 page pools (serving/kv_quant.py) carry per-(position, head) scale
    pools in ``k_scale``/``v_scale``; None means float payload.
    """

    k: jax.Array            # (L, num_pages, Hkv, block_size, D)
    v: jax.Array
    block_table: jax.Array  # (max_slots, pages_per_slot) int32; >= num_pages = unmapped
    length: jax.Array       # (max_slots,) int32
    k_scale: jax.Array | None = None  # (L, num_pages, Hkv, block_size, 1) f32
    v_scale: jax.Array | None = None

    @property
    def block_size(self) -> int:
        return self.k.shape[3]

    @property
    def num_pages(self) -> int:
        return self.k.shape[1]


def init_layer(key, cfg) -> dict:
    ka, km, kn = jax.random.split(key, 3)
    p: dict[str, Any] = {}
    p.update(
        init_qkv(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            cfg.param_dtype, bias=cfg.qkv_bias,
        )
    )
    pre = init_norm(kn, cfg.d_model, cfg.norm_type, cfg.param_dtype)
    p["pre_attn"] = pre
    p["pre_mlp"] = init_norm(jax.random.fold_in(kn, 1), cfg.d_model, cfg.norm_type, cfg.param_dtype)
    if cfg.num_experts:
        p["moe"] = init_moe(km, cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.param_dtype)
    else:
        p.update(init_mlp(km, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.param_dtype))
    return p


def init_lm(cfg, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "final_norm": init_norm(jax.random.fold_in(ke, 2), cfg.d_model, cfg.norm_type, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)).astype(cfg.param_dtype)
        }
    return params


def _is_layer_view(x) -> bool:
    """Duck-typed: a leaf that must not be sliced as scan xs (e.g. a stacked
    fused ``SLRLinear`` whose BSR tables are selected per layer inside the
    Pallas kernel's DMA index maps, ``serving/slr_params.py``)."""
    return getattr(x, "scan_by_index", False)


def layer_view(layers, l):
    """Per-layer view of a scan-stacked layer tree for index-driven scans.

    Ordinary stacked arrays are dynamic-sliced at layer ``l`` (exactly what
    ``lax.scan`` xs would do); ``scan_by_index`` leaves return ``at_layer(l)``
    views that keep their stacked tables whole — slicing those as xs would
    copy an entire sparse table out of HBM every layer of every tick.
    """
    def index_leaf(leaf):
        if _is_layer_view(leaf):
            return leaf.at_layer(l)
        return jax.lax.dynamic_index_in_dim(leaf, l, 0, keepdims=False)

    return jax.tree_util.tree_map(index_leaf, layers, is_leaf=_is_layer_view)


def _scan_by_index(layers) -> bool:
    return any(
        _is_layer_view(leaf)
        for leaf in jax.tree_util.tree_leaves(layers, is_leaf=_is_layer_view)
    )


def _layer_apply(lp, x, cfg, positions, cache: KVCache | None):
    """One transformer layer. Returns (x, aux_loss, new_kv)."""
    h = apply_norm(x, lp.get("pre_attn"), cfg.norm_type)
    attn_out, kv = attention_block(
        lp, h,
        n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
        positions=positions, rope_theta=cfg.rope_theta, causal=True,
        cache=cache, kernel_impl=cfg.kernel_impl,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
        causal_scheme=cfg.causal_scheme,
    )
    x = x + attn_out
    h = apply_norm(x, lp.get("pre_mlp"), cfg.norm_type)
    if cfg.num_experts:
        mlp_out, aux = moe_ffn(
            lp["moe"], h,
            num_experts=cfg.num_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, num_groups=cfg.moe_groups,
        )
    else:
        mlp_out, aux = apply_mlp(lp, h, cfg.mlp_type), jnp.zeros((), jnp.float32)
    x = x + mlp_out
    x = constrain(x, ("data", None, None))
    return x, aux, kv


def forward(
    params: dict,
    tokens: jax.Array,               # (B, T) int32
    cfg,
    *,
    prefix_embeds: jax.Array | None = None,   # (B, P, d) VLM patch stub
    cache: LMCache | None = None,
    position_offset: jax.Array | int = 0,
    collect_kv: bool = False,
) -> tuple[jax.Array, Any, jax.Array]:
    """Returns (logits (B, T', vocab), new_cache_or_kv, aux_loss).

    * train/eval: cache=None. new_cache_or_kv = stacked (k, v) heads per layer
      (useable to build a prefill cache).
    * decode: cache given, tokens (B, 1). Returns updated LMCache.
    """
    x = embed(params["embed"], tokens)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, t, _ = x.shape
    x = constrain(x, ("data", None, None))
    offset = jnp.asarray(position_offset)
    if offset.ndim:  # per-slot lengths: (B,) offsets -> (B, t) positions
        positions = offset[:, None] + jnp.arange(t)[None, :]
    else:
        positions = offset + jnp.arange(t)[None, :]

    aux_total = jnp.zeros((), jnp.float32)

    layers = params["layers"]
    unrolled = isinstance(layers, (list, tuple))
    # fused format: stacked sparse tables must not ride as scan xs — scan
    # layer indices instead and build per-layer views inside the body
    by_index = not unrolled and _scan_by_index(layers)
    layer_xs = jnp.arange(cfg.num_layers) if by_index else layers
    get_lp = (lambda xs: layer_view(layers, xs)) if by_index else (lambda xs: xs)

    if unrolled:
        # unrolled serving mode: per-layer param dicts (deployed formats whose
        # weights cannot stack under scan, e.g. block-CSR SLR matrices).
        x, aux_total, new_cache = _forward_unrolled(
            layers, x, cfg, positions, cache, collect_kv
        )
    elif cache is None:
        def body(carry, lp):
            x, aux = carry
            fn = lambda lp_, x_: _layer_apply(get_lp(lp_), x_, cfg, positions, None)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, a, kv = fn(lp, x)
            # train path: do NOT emit stacked KV heads (they are dead weight
            # but scan ys defeat DCE through remat -> ~70 GB/device at 4k)
            return (x, aux + a), (kv if collect_kv else None)

        (x, aux_total), kvs = jax.lax.scan(body, (x, aux_total), layer_xs, unroll=cfg.scan_unroll)
        new_cache = kvs  # (kh (L,B,H,T,D), vh (L,B,H,T,D))
    elif isinstance(cache, PagedKVCache):
        # paged decode: carry the page pools (layer-sliced like the contiguous
        # path below); block table and lengths are layer-invariant
        quant = cache.k_scale is not None

        def body(carry, inp):
            x, aux, k_p, v_p, k_s, v_s = carry
            lp, l_idx = inp
            layer_cache = PagedLayerCache(
                jax.lax.dynamic_index_in_dim(k_p, l_idx, 0, keepdims=False),
                jax.lax.dynamic_index_in_dim(v_p, l_idx, 0, keepdims=False),
                cache.block_table, cache.length,
                jax.lax.dynamic_index_in_dim(k_s, l_idx, 0, keepdims=False) if quant else None,
                jax.lax.dynamic_index_in_dim(v_s, l_idx, 0, keepdims=False) if quant else None,
            )
            x, a, kv = _layer_apply(get_lp(lp), x, cfg, positions, layer_cache)
            k_p = jax.lax.dynamic_update_index_in_dim(k_p, kv.k, l_idx, 0)
            v_p = jax.lax.dynamic_update_index_in_dim(v_p, kv.v, l_idx, 0)
            if quant:
                k_s = jax.lax.dynamic_update_index_in_dim(k_s, kv.k_scale, l_idx, 0)
                v_s = jax.lax.dynamic_update_index_in_dim(v_s, kv.v_scale, l_idx, 0)
            return (x, aux + a, k_p, v_p, k_s, v_s), None

        (x, aux_total, k_new, v_new, ks_new, vs_new), _ = jax.lax.scan(
            body,
            (x, aux_total, cache.k, cache.v, cache.k_scale, cache.v_scale),
            (layer_xs, jnp.arange(cfg.num_layers)),
            unroll=cfg.scan_unroll,
        )
        new_cache = PagedKVCache(
            k_new, v_new, cache.block_table, cache.length + t, ks_new, vs_new
        )
    else:
        # decode: thread the FULL stacked cache through the carry and update
        # layer slices in place — consuming cache.k as scan xs and restacking
        # ys doubles the cache residency (measured ~2x on gemma decode_32k);
        # the carry form lets XLA alias the donated buffers.
        def body(carry, inp):
            x, aux, k_full, v_full = carry
            lp, l_idx = inp
            k_l = jax.lax.dynamic_index_in_dim(k_full, l_idx, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(v_full, l_idx, 0, keepdims=False)
            layer_cache = KVCache(k_l, v_l, cache.length)
            x, a, kv = _layer_apply(get_lp(lp), x, cfg, positions, layer_cache)
            k_full = jax.lax.dynamic_update_index_in_dim(k_full, kv.k, l_idx, 0)
            v_full = jax.lax.dynamic_update_index_in_dim(v_full, kv.v, l_idx, 0)
            return (x, aux + a, k_full, v_full), None

        (x, aux_total, k_new, v_new), _ = jax.lax.scan(
            body,
            (x, aux_total, cache.k, cache.v),
            (layer_xs, jnp.arange(cfg.num_layers)),
            unroll=cfg.scan_unroll,
        )
        new_cache = LMCache(k_new, v_new, cache.length + t)

    x = apply_norm(x, params.get("final_norm"), cfg.norm_type)
    head = params["lm_head"]["w"] if "lm_head" in params else params["embed"]["embedding"].T
    logits = apply_weight(x, head)
    logits = constrain(logits, ("data", None, "model"))
    return logits, new_cache, aux_total


def _forward_unrolled(layers, x, cfg, positions, cache: LMCache | None, collect_kv: bool):
    """Python-loop layer stack for deployed formats that cannot scan.

    Semantics match the scan paths exactly: prefill (cache=None) returns
    stacked (k, v) heads when collect_kv, decode updates layer slices of the
    full LMCache in place.
    """
    aux_total = jnp.zeros((), jnp.float32)
    if cache is None:
        kvs = []
        for lp in layers:
            x, a, kv = _layer_apply(lp, x, cfg, positions, None)
            aux_total = aux_total + a
            if collect_kv:
                kvs.append(kv)
        if collect_kv:
            new_cache = (
                jnp.stack([k for k, _ in kvs]), jnp.stack([v for _, v in kvs])
            )
        else:
            new_cache = None
        return x, aux_total, new_cache
    t = x.shape[1]
    if isinstance(cache, PagedKVCache):
        quant = cache.k_scale is not None
        k_full, v_full = cache.k, cache.v
        k_s, v_s = cache.k_scale, cache.v_scale
        for l_idx, lp in enumerate(layers):
            layer_cache = PagedLayerCache(
                k_full[l_idx], v_full[l_idx], cache.block_table, cache.length,
                k_s[l_idx] if quant else None, v_s[l_idx] if quant else None,
            )
            x, a, kv = _layer_apply(lp, x, cfg, positions, layer_cache)
            aux_total = aux_total + a
            k_full = k_full.at[l_idx].set(kv.k)
            v_full = v_full.at[l_idx].set(kv.v)
            if quant:
                k_s = k_s.at[l_idx].set(kv.k_scale)
                v_s = v_s.at[l_idx].set(kv.v_scale)
        return x, aux_total, PagedKVCache(
            k_full, v_full, cache.block_table, cache.length + t, k_s, v_s
        )
    k_full, v_full = cache.k, cache.v
    for l_idx, lp in enumerate(layers):
        layer_cache = KVCache(k_full[l_idx], v_full[l_idx], cache.length)
        x, a, kv = _layer_apply(lp, x, cfg, positions, layer_cache)
        aux_total = aux_total + a
        k_full = k_full.at[l_idx].set(kv.k)
        v_full = v_full.at[l_idx].set(kv.v)
    return x, aux_total, LMCache(k_full, v_full, cache.length + t)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> LMCache:
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    return LMCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def init_paged_cache(
    cfg,
    max_slots: int,
    num_pages: int,
    block_size: int,
    pages_per_slot: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
) -> PagedKVCache:
    """Fixed page pool per layer; the whole block table starts unmapped."""
    pool = (cfg.num_layers, num_pages, cfg.num_kv_heads, block_size, cfg.head_dim)
    payload = jnp.int8 if quantized else dtype
    scale = (
        jnp.zeros(pool[:-1] + (1,), jnp.float32) if quantized else None
    )
    return PagedKVCache(
        k=jnp.zeros(pool, payload),
        v=jnp.zeros(pool, payload),
        block_table=jnp.full((max_slots, pages_per_slot), num_pages, jnp.int32),
        length=jnp.zeros((max_slots,), jnp.int32),
        k_scale=scale,
        v_scale=None if scale is None else jnp.zeros_like(scale),
    )


def scatter_prefill_pages(
    cache: PagedKVCache,
    kvs,                    # stacked prefill heads: (kh, vh), each (L, B, Hkv, T, D)
    page_map: jax.Array,    # (B, T // block_size) int32 page ids; >= num_pages drops
) -> PagedKVCache:
    """Write whole prompt blocks into the page pool (the prefill-side insert).

    T must be a multiple of the block size; trailing positions of a slot's
    last block may carry junk from prompt padding — the per-slot length mask
    never attends them.
    """
    kh, vh = kvs
    l, b, h, t, d = kh.shape
    bs = cache.block_size
    assert t % bs == 0, (t, bs)
    pages = page_map.reshape(-1)                       # (B * nb,)

    def scatter(pool, heads, quantize):
        # (L, B, H, T, D) -> (L, B*nb, H, bs, D) chunks aligned with ``pages``
        chunks = heads.reshape(l, b, h, t // bs, bs, d)
        chunks = chunks.transpose(0, 1, 3, 2, 4, 5).reshape(l, -1, h, bs, d)
        if quantize:
            from ..serving.kv_quant import quantize_kv

            q, s = quantize_kv(chunks)
            return (
                pool[0].at[:, pages].set(q, mode="drop"),
                pool[1].at[:, pages].set(s, mode="drop"),
            )
        return pool[0].at[:, pages].set(chunks.astype(pool[0].dtype), mode="drop"), None

    quant = cache.k_scale is not None
    k_new, k_s = scatter((cache.k, cache.k_scale), kh, quant)
    v_new, v_s = scatter((cache.v, cache.v_scale), vh, quant)
    return cache._replace(k=k_new, v=v_new, k_scale=k_s, v_scale=v_s)


def copy_cache_pages(
    cache: PagedKVCache,
    src: jax.Array,   # (n,) int32 source page ids
    dst: jax.Array,   # (n,) int32 destination page ids
) -> PagedKVCache:
    """Copy whole pages ``src[i] -> dst[i]`` across every pool the cache
    carries — k, v, and (when the pages are int8-quantized) BOTH scale pools;
    a page copied without its scales would dequantize garbage. The device half
    of copy-on-write prefix sharing (``serving/prefix_cache.py``): the engine
    remaps its block table to ``dst`` host-side after this call."""
    from ..kernels import ops

    k = ops.page_copy(cache.k, src, dst)
    v = ops.page_copy(cache.v, src, dst)
    k_s = v_s = None
    if cache.k_scale is not None:
        k_s = ops.page_copy(cache.k_scale, src, dst)
        v_s = ops.page_copy(cache.v_scale, src, dst)
    return cache._replace(k=k, v=v, k_scale=k_s, v_scale=v_s)


def cache_from_prefill(cfg, kvs, max_len: int, dtype=jnp.bfloat16) -> LMCache:
    """Build an LMCache from forward()'s stacked prefill (k, v) heads."""
    kh, vh = kvs  # (L, B, H, T, D)
    l, b, h, t, d = kh.shape
    # VLM prefill sequences include the patch prefix and may exceed the
    # nominal text max_len — grow the cache rather than truncate
    pad = max(max_len - t, 0)
    k = jnp.pad(kh.astype(dtype), ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    v = jnp.pad(vh.astype(dtype), ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    return LMCache(k=k, v=v, length=jnp.asarray(t, jnp.int32))
