"""Deterministic synthetic token stream with C4-like marginal statistics.

The container is offline, so the C4 pipeline is replaced by a seeded
generator producing Zipf-distributed tokens with short-range Markov structure
(so a language model actually has something learnable: local bigram structure
+ skip dependencies). The interface is the one a real tokenized-C4 loader
would have — ``batches(step)`` is a pure function of (seed, step), which is
what makes checkpoint/restart and elastic rescaling exactly replayable.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # C4-ish unigram tail
    markov_strength: float = 0.7  # P(next token depends on prev)


class SyntheticC4:
    """Deterministic, stateless-per-step token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        # fixed random bigram successor table: each token has 8 likely successors
        self._succ = rng.randint(0, v, size=(v, 8)).astype(np.int32)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = (probs / probs.sum()).astype(np.float64)

    def batch(self, step: int, host_id: int = 0, num_hosts: int = 1) -> dict:
        """Batch for ``step``; hosts carve disjoint slices of the global batch."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        per_host = cfg.global_batch // num_hosts
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step) % (2**31) + host_id
        )
        b, t = per_host, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(b, t + 1), p=self._probs).astype(np.int32)
        toks = base.copy()
        use_markov = rng.random_sample((b, t)) < cfg.markov_strength
        pick = rng.randint(0, 8, size=(b, t))
        succ = self._succ[toks[:, :-1], pick]
        toks[:, 1:] = np.where(use_markov, succ, base[:, 1:])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
