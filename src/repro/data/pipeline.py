"""Host-side input pipeline: background prefetch + device placement.

Training is GIL-friendly here (the generator is numpy), so a single
background thread hides batch synthesis/tokenization behind the device step
— the standard double-buffering that keeps TPUs fed. ``DevicePrefetcher``
optionally device_puts with the batch shardings so the host→HBM transfer
overlaps the previous step too.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax


class Prefetcher:
    """Wrap a ``batch(step)`` source with an N-deep background queue."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self._source = source
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            while not self._stop.is_set():
                try:
                    self._queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._queue.get()
        return batch

    def batch(self, step: int) -> dict:
        """Trainer-compatible access: serves from the queue when the step
        matches the stream position, falls back to direct synthesis for
        out-of-order requests (e.g. right after a restore)."""
        while True:
            got_step, batch = self._queue.get()
            if got_step == step:
                return batch
            if got_step > step:  # restored earlier than the stream: direct
                return self._source.batch(step)
            # got_step < step: drain stale entries

    def close(self):
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


class DevicePrefetcher(Prefetcher):
    """Prefetcher that also places batches on device (optionally sharded)."""

    def __init__(self, source, shardings: Any = None, **kw):
        self._shardings = shardings
        super().__init__(source, **kw)

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            if self._shardings is not None:
                batch = jax.device_put(batch, self._shardings)
            else:
                batch = jax.device_put(batch)
            while not self._stop.is_set():
                try:
                    self._queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1
