"""SALAAD core: the paper's contribution as a composable JAX module."""
from .admm import (  # noqa: F401
    BlockSLR,
    SalaadConfig,
    SLRState,
    admm_update,
    init_slr_state,
    penalty,
    slr_param_count,
    surrogate_params,
)
from .controller import ControllerConfig, controller_update  # noqa: F401
from .hpa import hpa_compress, hpa_keep_ratio, removable_params  # noqa: F401
from .prox import (  # noqa: F401
    density,
    effective_rank_ratio,
    effective_rank_ratio_from_singular_values,
    soft_threshold,
    svt,
)
from .rpca import rpca  # noqa: F401
from .rsvd import randomized_svd, rank_cap  # noqa: F401
from .salaad import Salaad  # noqa: F401
from .scaling import PAPER_RHO_CONSTANT, rho_for_block  # noqa: F401
from .selection import BlockInfo, SelectionConfig, select_blocks  # noqa: F401
from .sparse import CooMatrix  # noqa: F401
