"""The rho scaling law (Eq. 7):   rho = C / (N * sqrt(n*m)).

``N`` is the number of selected logical blocks in the model, ``(n, m)`` the
block's matrix shape. The proportionality constant is calibrated so that a
LLaMA-style 350M model (d_model=1024, 24 layers, ~170 logical blocks with a
typical 1024x2736 MLP projection) lands on the paper's reported
``rho = 5e-8`` (Table 3):  5e-8 * 170 * sqrt(1024*2736) ~= 0.014.
"""
from __future__ import annotations

import math

__all__ = ["PAPER_RHO_CONSTANT", "rho_for_block"]

PAPER_RHO_CONSTANT = 0.014


def rho_for_block(n: int, m: int, num_blocks: int, constant: float = PAPER_RHO_CONSTANT) -> float:
    """Eq. (7): rho proportional to 1 / (N sqrt(n m))."""
    return constant / (num_blocks * math.sqrt(n * m))
