"""Randomized SVD (range-finder + subspace iteration) — the TPU-native SVD path.

The paper computes a *full* ``torch.linalg.svd`` of every block (App. C prices an
8192x8192 SVD at 6.6e12 FLOPs). Full dense SVD is Householder-dominated and
maps poorly onto the MXU. SALAAD only ever needs the part of the spectrum that
survives thresholding at ``alpha/rho`` — and the I-controller regulates the
effective rank toward ~0.15*min(n,m) — so a randomized range-finder SVD
(Halko, Martinsson & Tropp 2011) with a rank cap and a couple of power
iterations is the right tool: it is matmul-dominated (MXU-friendly),
embarrassingly shardable, and its tail error is quantified in tests against
``jnp.linalg.svd``.

``randomized_svd`` is deterministic given the ``key`` argument; Algorithm 1's
second stage derives per-step keys from the training step counter so that
checkpoint/restart replays identically (fault-tolerance invariant, tested).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["randomized_svd", "rank_cap"]


def rank_cap(n: int, m: int, cap_ratio: float = 0.25, minimum: int = 8) -> int:
    """Sketch size used for a block of shape (n, m).

    The controller targets Gamma_hat = 0.15; we cap the sketch at
    ``cap_ratio * min(n, m)`` (default 0.25 — headroom above the target so the
    controller is never starved of spectrum) and align it to the 128-lane MXU
    tile when it is large enough to matter.
    """
    r = max(minimum, int(cap_ratio * min(n, m)))
    if r >= 128:
        r = (r + 127) // 128 * 128
    return min(r, min(n, m))


@partial(jax.jit, static_argnames=("rank", "n_iter"))
def randomized_svd(
    a: jax.Array,
    key: jax.Array,
    rank: int,
    n_iter: int = 2,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-``rank`` SVD of ``a`` (n, m): returns (U (n,r), s (r,), Vt (r,m)).

    Range finder with ``n_iter`` QR-stabilized power iterations:
      Omega ~ N(0,1) (m, r);  Q = orth(A Omega);  Q = orth(A Aᵀ Q)^n_iter
      B = Qᵀ A (r, m);  SVD(B) small;  U = Q @ U_B.

    All heavy ops are (n,m)x(m,r) matmuls + QR of tall-skinny (n,r) — both
    MXU-shaped. Computation runs in f32 even for bf16 weights (SVD accuracy).
    """
    n, m = a.shape
    r = min(rank, n, m)
    a32 = a.astype(jnp.float32)
    omega = jax.random.normal(key, (m, r), dtype=jnp.float32)
    q = jnp.linalg.qr(a32 @ omega)[0]
    for _ in range(n_iter):
        q = jnp.linalg.qr(a32.T @ q)[0]
        q = jnp.linalg.qr(a32 @ q)[0]
    b = q.T @ a32  # (r, m)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    return (q @ ub).astype(a.dtype), s.astype(a.dtype), vt.astype(a.dtype)
