"""Block selection: discover SALAAD-managed weight blocks in ANY param pytree.

This is what makes SALAAD "plug-and-play" (the paper's central framing): the
core never sees model code. We walk an arbitrary parameter pytree and select
every leaf that looks like a linear-map weight:

  * trailing two dims are the matrix ``(n, m)``;
  * any leading dims are *stacked* block axes (scan-stacked layers produce
    ``(L, n, m)``; stacked MoE experts produce ``(E, n, m)`` or ``(L, E, n, m)``)
    — each slice is an independent ADMM block with its own ``(alpha, beta)``,
    exactly matching the paper's block-wise I-controller;
  * path-based include/exclude regexes implement the paper's component policy
    (embedding included by default per §5.1; LM head excluded per App. H).

``N`` in the rho scaling law (Eq. 7) counts *logical* blocks, i.e. stacked
slices count individually.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

__all__ = ["SelectionConfig", "BlockInfo", "select_blocks", "path_str"]


@dataclass(frozen=True)
class SelectionConfig:
    """Which leaves become SALAAD blocks."""

    min_dim: int = 8               # both matrix dims must be >= this
    include_embedding: bool = True  # paper §5.1: embedding is benignly SLR-inducible
    include_lm_head: bool = False   # paper App. H: LM head is NOT benign; default off
    extra_exclude: tuple[str, ...] = ()   # additional path regexes to skip
    extra_include: tuple[str, ...] = ()   # path regexes that force inclusion

    # Path fragments identifying special components (matched case-insensitively).
    embedding_patterns: tuple[str, ...] = ("embed",)
    lm_head_patterns: tuple[str, ...] = ("lm_head", "unembed", "output_head")
    # 1-D bias/norm leaves are excluded by the ndim rule automatically; conv &
    # frontend stubs are excluded by name.
    default_exclude: tuple[str, ...] = ("norm", "scale", "bias", "conv", "frontend", "a_log", "dt_")


@dataclass(frozen=True)
class BlockInfo:
    """Static metadata for one selected leaf (possibly a stack of blocks)."""

    path: tuple[Any, ...]          # jax.tree_util key path
    name: str                      # '/'-joined readable path
    shape: tuple[int, ...]         # full leaf shape
    stack_dims: tuple[int, ...]    # leading stacked axes ( () for a plain matrix )
    n: int                         # matrix rows
    m: int                         # matrix cols
    is_embedding: bool = False

    @property
    def num_blocks(self) -> int:
        return int(np.prod(self.stack_dims)) if self.stack_dims else 1

    @property
    def matrix_params(self) -> int:
        return self.n * self.m


def path_str(path: tuple[Any, ...]) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _matches(name: str, patterns: tuple[str, ...]) -> bool:
    low = name.lower()
    return any(re.search(p, low) for p in patterns)


def select_blocks(params: Any, cfg: SelectionConfig = SelectionConfig()) -> list[BlockInfo]:
    """Return BlockInfo for every selected leaf, in deterministic path order."""
    leaves = jax.tree_util.tree_leaves_with_path(params)
    out: list[BlockInfo] = []
    for path, leaf in leaves:
        shape = tuple(getattr(leaf, "shape", ()))
        if len(shape) < 2:
            continue
        name = path_str(path)
        n, m = shape[-2], shape[-1]
        if min(n, m) < cfg.min_dim:
            continue
        forced = _matches(name, cfg.extra_include) if cfg.extra_include else False
        if not forced:
            if _matches(name, cfg.default_exclude) or (
                cfg.extra_exclude and _matches(name, cfg.extra_exclude)
            ):
                continue
            if _matches(name, cfg.lm_head_patterns) and not cfg.include_lm_head:
                continue
            is_emb = _matches(name, cfg.embedding_patterns)
            if is_emb and not cfg.include_embedding:
                continue
        else:
            is_emb = _matches(name, cfg.embedding_patterns)
        out.append(
            BlockInfo(
                path=path,
                name=name,
                shape=shape,
                stack_dims=shape[:-2],
                n=n,
                m=m,
                is_embedding=is_emb,
            )
        )
    out.sort(key=lambda b: b.name)
    return out


def total_logical_blocks(blocks: list[BlockInfo]) -> int:
    """N in the rho scaling law (Eq. 7): stacked slices count individually."""
    return sum(b.num_blocks for b in blocks)
