"""Proximal operators and structural statistics for SALAAD.

These are the closed-form building blocks of Algorithm 1's second stage:

  * ``soft_threshold``      — prox of ``tau * ||.||_1`` (element-wise shrinkage)
  * ``svt``                 — prox of ``tau * ||.||_*`` (singular value thresholding)
  * ``effective_rank_ratio``— Definition 4.1 (energy-coverage effective rank)
  * ``density``             — fraction of nonzeros of the sparse component

Everything is pure ``jnp`` and jit/vmap-safe: shapes are static, and the
energy-coverage argmin is expressed as a mask-sum rather than data-dependent
control flow so it lowers cleanly under ``pjit``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "soft_threshold",
    "svt",
    "svt_from_svd",
    "effective_rank_ratio",
    "effective_rank_ratio_from_singular_values",
    "density",
]


def soft_threshold(z: jax.Array, tau: jax.Array | float) -> jax.Array:
    """prox_{tau |.|_1}(z) = sign(z) * max(|z| - tau, 0), element-wise."""
    tau = jnp.asarray(tau, dtype=z.dtype)
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0)


def svt_from_svd(u: jax.Array, s: jax.Array, vt: jax.Array, tau) -> tuple[jax.Array, jax.Array]:
    """Apply singular-value soft thresholding given an existing SVD.

    Returns ``(s_thr, L)`` where ``s_thr = (s - tau)_+`` and
    ``L = u @ diag(s_thr) @ vt``.
    """
    s_thr = jnp.maximum(s - jnp.asarray(tau, dtype=s.dtype), 0)
    return s_thr, (u * s_thr[None, :]) @ vt


def svt(z: jax.Array, tau) -> tuple[jax.Array, jax.Array]:
    """prox_{tau |.|_*}(z) via full SVD. Returns ``(s_thr, L)``.

    Exact reference path; the scalable training path uses ``rsvd.randomized_svd``
    (see :mod:`repro.core.rsvd`) which only touches the top of the spectrum.
    """
    u, s, vt = jnp.linalg.svd(z, full_matrices=False)
    return svt_from_svd(u, s, vt, tau)


def effective_rank_ratio_from_singular_values(
    s: jax.Array, gamma: float = 0.999, denom: int | None = None
) -> jax.Array:
    """Definition 4.1 on a given (non-negative, any order) singular value vector.

    Gamma-energy effective rank ratio:
        min{k : sum_{i<=k} sigma_i / sum_j sigma_j >= gamma} / denom

    ``denom`` defaults to ``len(s)``; pass ``min(n, m)`` when ``s`` is a
    truncated spectrum (e.g. from the rank-capped randomized SVD — the tail is
    exactly zero in L, so the energy count is exact while the ratio must still
    be taken against the full matrix dimension).

    Implemented branch-free: sort descending, cumulative ratio, count entries
    strictly below the coverage target, +1 for the crossing index. An all-zero
    spectrum yields ratio 0 (the matrix is rank 0).
    """
    s = jnp.sort(jnp.abs(s), axis=-1)[..., ::-1]
    total = jnp.sum(s, axis=-1, keepdims=True)
    csum = jnp.cumsum(s, axis=-1)
    # k = 1 + (#prefix sums with coverage < gamma); guard total == 0.
    covered = csum >= gamma * total
    k = jnp.where(total[..., 0] > 0, 1 + jnp.sum(~covered[..., :-1], axis=-1), 0)
    # if even the first singular value covers gamma, k == 1 as required.
    d = denom if denom is not None else s.shape[-1]
    return k.astype(jnp.float32) / d


def effective_rank_ratio(mat: jax.Array, gamma: float = 0.999) -> jax.Array:
    """Definition 4.1 for a dense matrix (computes singular values)."""
    s = jnp.linalg.svd(mat, compute_uv=False)
    return effective_rank_ratio_from_singular_values(s, gamma)


def density(mat: jax.Array, eps: float = 0.0) -> jax.Array:
    """Fraction of entries with |x| > eps (Upsilon_S in the paper)."""
    return jnp.mean((jnp.abs(mat) > eps).astype(jnp.float32))
