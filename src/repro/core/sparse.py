"""Fixed-capacity sparse matrix representation for the S component.

TPU/XLA want static shapes, so S is stored as a *capped* coordinate list:

    values : (cap,) float     — entry values (0 for unused slots)
    idx    : (cap,) int32     — flattened row-major index, or -1 for unused

``cap`` is ``ceil(cap_density * n * m)`` (default 3x the paper's density
target of 0.05, giving the I-controller headroom). ``from_dense`` keeps the
``cap`` largest-magnitude entries — consistent with HPA's magnitude-importance
assumption, so the cap *is* an HPA pre-truncation, not an approximation of a
different scheme.

Deployment memory accounting: a CooMatrix costs ``cap * (bytes(value) + 4)``
vs ``n*m*bytes`` dense. The serving path converts to 128x128 block-CSR for
the Pallas BSR kernel (see kernels/bsr_matmul.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["CooMatrix", "from_dense", "to_dense", "nnz", "coo_cap"]


@dataclass(frozen=True)
class CooMatrix:
    values: jax.Array  # (..., cap)
    idx: jax.Array     # (..., cap) int32 flat index into (n*m), -1 = empty
    shape: tuple[int, int]  # (n, m) of the dense matrix — static metadata


# `shape` is static so jit treats it as part of the treedef, not a leaf.
jax.tree_util.register_dataclass(
    CooMatrix, data_fields=["values", "idx"], meta_fields=["shape"]
)


def coo_cap(n: int, m: int, cap_density: float = 0.15) -> int:
    cap = max(8, int(cap_density * n * m))
    if cap >= 512:
        cap = -(-cap // 512) * 512  # 512-aligned: shardable over a 512-chip mesh
    return min(cap, n * m)


def from_dense(s: jax.Array, cap: int) -> CooMatrix:
    """Keep the ``cap`` largest-|.| entries of dense ``s`` (trailing 2 dims)."""
    n, m = s.shape[-2:]
    flat = s.reshape(*s.shape[:-2], n * m)
    mag = jnp.abs(flat)
    _, top_idx = jax.lax.top_k(mag, cap)
    vals = jnp.take_along_axis(flat, top_idx, axis=-1)
    live = jnp.abs(vals) > 0
    return CooMatrix(
        values=jnp.where(live, vals, 0),
        idx=jnp.where(live, top_idx, -1).astype(jnp.int32),
        shape=(n, m),
    )


def to_dense(coo: CooMatrix) -> jax.Array:
    """Scatter back to a dense (..., n, m) matrix."""
    n, m = coo.shape
    safe_idx = jnp.where(coo.idx >= 0, coo.idx, 0)
    vals = jnp.where(coo.idx >= 0, coo.values, 0)

    def scatter_one(v, i):
        return jnp.zeros((n * m,), v.dtype).at[i].add(v).reshape(n, m)

    flat_batch = coo.values.shape[:-1]
    if flat_batch:
        f = scatter_one
        for _ in flat_batch:
            f = jax.vmap(f)
        return f(vals, safe_idx)
    return scatter_one(vals, safe_idx)


def nnz(coo: CooMatrix) -> jax.Array:
    """Number of live entries (per stacked slice)."""
    return jnp.sum((coo.idx >= 0).astype(jnp.int32), axis=-1)
