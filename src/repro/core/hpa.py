"""Homomorphic Parameter Allocation (HPA) — §4.3 + App. D.

Deploy-time, continuous, architecture-preserving capacity control. Given a
parameter-removal budget ``C`` and a mixing coefficient ``kappa``:

    phi_L = kappa*C / C_L          phi_S = (1-kappa)*C / C_S

where ``C_L`` / ``C_S`` are the total removable parameters in the low-rank /
sparse components. Footnote 3's feasibility rule is implemented: if either
ratio exceeds 1, the surplus budget is reassigned to the other component
(always feasible when C <= C_L + C_S).

Per block the SAME global fractions are applied (Remark 4.2 — "homomorphism"
preserves learned block heterogeneity): the smallest ``phi_L`` fraction of
singular values and the smallest ``phi_S`` fraction of sparse entries (by
magnitude — the paper's importance proxy I(u) ∝ |u|) are removed.

Parameter cost accounting uses the *deployed* representation: a rank unit of
an (n, m) block costs (n + m) parameters (one column of U·diag(s) plus one
row of Vᵀ); a sparse unit costs 1 value (+4 bytes of index, reported
separately as overhead, matching how the paper counts PRM).

Runs eagerly (deployment path) — no jit required, works on CPU hosts.
"""
from __future__ import annotations

from dataclasses import replace

import jax.numpy as jnp
import numpy as np

from . import sparse
from .admm import BlockSLR, SLRState
from .selection import BlockInfo

__all__ = ["removable_params", "hpa_compress", "hpa_keep_ratio"]


def removable_params(state: SLRState, blocks: list[BlockInfo]) -> tuple[int, int]:
    """(C_L, C_S): removable parameter totals across blocks."""
    c_l = 0
    c_s = 0
    for info in blocks:
        blk = state[info.name]
        live_rank = int(np.sum(np.asarray(blk.s_vals) > 0))
        c_l += live_rank * (info.n + info.m)
        c_s += int(np.sum(np.asarray(blk.s_coo.idx) >= 0))
    return c_l, c_s


def _split_budget(c: int, kappa: float, c_l: int, c_s: int) -> tuple[float, float]:
    """Global ratios with footnote-3 surplus reassignment."""
    if c > c_l + c_s:
        raise ValueError(f"budget C={c} exceeds removable params {c_l + c_s}")
    bl, bs = kappa * c, (1.0 - kappa) * c
    if c_l > 0 and bl > c_l:
        bs += bl - c_l
        bl = c_l
    if c_s > 0 and bs > c_s:
        bl += bs - c_s
        bl = min(bl, c_l)
        bs = c_s
    phi_l = bl / c_l if c_l > 0 else 0.0
    phi_s = bs / c_s if c_s > 0 else 0.0
    return min(phi_l, 1.0), min(phi_s, 1.0)


def _truncate_block(blk: BlockSLR, info: BlockInfo, phi_l: float, phi_s: float) -> BlockSLR:
    """Remove the smallest phi_l fraction of singular values and phi_s fraction
    of sparse entries, per stacked slice, by magnitude."""
    s_vals = np.asarray(blk.s_vals, np.float64)          # (..., r)
    live = s_vals > 0
    # Per slice: keep ceil((1 - phi_l) * live) largest singular values.
    live_counts = live.sum(axis=-1)                       # (...,)
    keep_counts = np.ceil((1.0 - phi_l) * live_counts).astype(np.int64)
    order = np.argsort(-s_vals, axis=-1)                  # descending
    ranks = np.empty_like(order)
    np.put_along_axis(ranks, order, np.arange(s_vals.shape[-1])[(None,) * (s_vals.ndim - 1)] * np.ones_like(order), axis=-1)
    keep_mask_l = ranks < keep_counts[..., None]
    keep_mask_l &= live

    new_s_vals = np.where(keep_mask_l, s_vals, 0.0)
    # rescale p columns: p = U diag(s); zeroing a singular value zeroes its column.
    scale = np.where(s_vals > 0, new_s_vals / np.maximum(s_vals, 1e-30), 0.0)
    new_p = np.asarray(blk.p) * scale[..., None, :]

    vals = np.asarray(blk.s_coo.values, np.float64)       # (..., cap)
    idx = np.asarray(blk.s_coo.idx)
    live_s = idx >= 0
    mags = np.where(live_s, np.abs(vals), -np.inf)
    live_s_counts = live_s.sum(axis=-1)
    keep_s_counts = np.floor((1.0 - phi_s) * live_s_counts).astype(np.int64)
    order_s = np.argsort(-mags, axis=-1)
    ranks_s = np.empty_like(order_s)
    np.put_along_axis(ranks_s, order_s, np.arange(mags.shape[-1])[(None,) * (mags.ndim - 1)] * np.ones_like(order_s), axis=-1)
    keep_mask_s = (ranks_s < keep_s_counts[..., None]) & live_s

    new_vals = np.where(keep_mask_s, vals, 0.0)
    new_idx = np.where(keep_mask_s, idx, -1).astype(np.int32)

    return replace(
        blk,
        p=jnp.asarray(new_p, blk.p.dtype),
        s_vals=jnp.asarray(new_s_vals, blk.s_vals.dtype),
        s_coo=sparse.CooMatrix(
            jnp.asarray(new_vals, blk.s_coo.values.dtype),
            jnp.asarray(new_idx),
            blk.s_coo.shape,
        ),
    )


def hpa_compress(
    state: SLRState,
    blocks: list[BlockInfo],
    remove_budget: int,
    kappa: float,
) -> tuple[SLRState, dict]:
    """HPA truncation under a parameter-removal budget. Returns (state, report)."""
    c_l, c_s = removable_params(state, blocks)
    phi_l, phi_s = _split_budget(remove_budget, kappa, c_l, c_s)
    new_state: SLRState = dict(state)
    for info in blocks:
        new_state[info.name] = _truncate_block(state[info.name], info, phi_l, phi_s)
    c_l2, c_s2 = removable_params(new_state, blocks)
    report = {
        "phi_L": phi_l,
        "phi_S": phi_s,
        "params_before": c_l + c_s,
        "params_after": c_l2 + c_s2,
        "removed": (c_l + c_s) - (c_l2 + c_s2),
        "index_overhead_entries": c_s2,  # one int32 per surviving sparse entry
    }
    return new_state, report


def hpa_keep_ratio(
    state: SLRState, blocks: list[BlockInfo], keep_ratio: float, kappa: float
) -> tuple[SLRState, dict]:
    """Convenience: keep ``keep_ratio`` of the current SLR parameter count."""
    c_l, c_s = removable_params(state, blocks)
    budget = int(round((1.0 - keep_ratio) * (c_l + c_s)))
    return hpa_compress(state, blocks, budget, kappa)
