"""High-level SALAAD API: wrap any (loss_fn, optimizer) into Algorithm 1.

The plug-and-play contract:

    salaad = Salaad(cfg)
    slr_state, blocks = salaad.init(params)
    loss = task_loss(params, batch) + salaad.penalty(params, slr_state)   # stage 1
    ...every K steps...
    slr_state, stats = salaad.update(params, slr_state, step)             # stage 2
    deploy = salaad.surrogate(params, slr_state)                          # L + S
    deploy_small, report = salaad.compress(slr_state, budget, kappa)      # HPA

No model or optimizer internals are touched — the framework's trainer uses
exactly this interface, and so can any external training loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax

from . import admm, hpa
from .admm import SalaadConfig, SLRState
from .selection import BlockInfo

__all__ = ["Salaad", "SalaadConfig"]


@dataclass
class Salaad:
    cfg: SalaadConfig = field(default_factory=SalaadConfig)
    blocks: list[BlockInfo] | None = None

    def init(self, params: Any) -> SLRState:
        state, blocks = admm.init_slr_state(params, self.cfg)
        self.blocks = blocks
        return state

    def penalty(self, params: Any, state: SLRState) -> jax.Array:
        assert self.blocks is not None, "call init() first"
        return admm.penalty(params, state, self.blocks)

    def update(self, params: Any, state: SLRState, step) -> tuple[SLRState, dict]:
        assert self.blocks is not None
        return admm.admm_update(params, state, self.blocks, self.cfg, step)

    def surrogate(self, params: Any, state: SLRState) -> Any:
        assert self.blocks is not None
        return admm.surrogate_params(params, state, self.blocks)

    def compress(self, state: SLRState, remove_budget: int, kappa: float):
        assert self.blocks is not None
        return hpa.hpa_compress(state, self.blocks, remove_budget, kappa)

    def param_count(self, state: SLRState) -> dict:
        assert self.blocks is not None
        return admm.slr_param_count(state, self.blocks)
