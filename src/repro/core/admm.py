"""Two-stage ADMM for SLR induction — Algorithm 1 of the paper.

Stage 1 (guided learning) lives in the training loop: ``K`` ordinary optimizer
steps on the coupled loss

    l_c(X) = l(X) + sum_i rho_i/2 * || X_i - (L_i + S_i - Y_i/rho_i) ||_F^2

This module provides ``penalty`` for that term (with the surrogate target
``Z = L + S - Y/rho`` stop-gradiented: it is a constant during stage 1) and
``admm_update`` for stage 2 — the closed-form proximal sweep

    L <- SVT_{alpha/rho}(X - S + Y/rho)
    S <- shrink_{beta/rho}(X - L + Y/rho)
    Y <- Y + rho (X - L - S)

followed by the I-controller update of (alpha, beta).

Memory layout (beyond-paper, see DESIGN.md §2):
  * L is stored factored as ``p = U diag(s_thr)`` (n, r) and ``vt`` (r, m)
    with r the randomized-SVD rank cap — never dense;
  * S is a fixed-capacity COO list (``core.sparse``);
  * only Y is dense.
Surrogate tensors inherit the sharding of their weight (the launcher pins
them with the same NamedSharding), so the update is fully SPMD — this is the
TPU analogue of the paper's per-GPU block placement (App. C).

Stacked leaves (scan-stacked layers ``(Lyr, n, m)``, stacked experts
``(Lyr, E, n, m)``) are handled by flattening the leading dims and vmapping
the per-block update, so every slice keeps its own (alpha, beta) — exactly
the paper's block-wise controller.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import sparse
from .controller import ControllerConfig, controller_update
from .prox import effective_rank_ratio_from_singular_values, soft_threshold
from .rsvd import randomized_svd, rank_cap
from .scaling import PAPER_RHO_CONSTANT, rho_for_block
from .selection import BlockInfo, SelectionConfig, select_blocks, total_logical_blocks

__all__ = [
    "SalaadConfig",
    "BlockSLR",
    "SLRState",
    "init_slr_state",
    "penalty",
    "admm_update",
    "surrogate_params",
    "slr_param_count",
]


@dataclass(frozen=True)
class SalaadConfig:
    """Everything that parameterizes Algorithm 1."""

    rho_constant: float = PAPER_RHO_CONSTANT  # proportionality in Eq. (7)
    controller: ControllerConfig = field(default_factory=ControllerConfig)
    selection: SelectionConfig = field(default_factory=SelectionConfig)
    rank_cap_ratio: float = 0.25     # randomized-SVD sketch cap (vs min(n,m))
    coo_cap_density: float = 0.15    # S capacity (vs n*m); 3x the 0.05 target
    rsvd_iters: int = 2              # power iterations in the range finder
    admm_inner_steps: int = 1        # J in Algorithm 1 (paper default: 1)
    update_every: int = 40           # K in Algorithm 1 (paper App. C: K=40)
    surrogate_dtype: Any = jnp.float32  # dtype of (p, vt, S, Y); bf16 at scale
    exact_svd: bool = False          # tests: use jnp.linalg.svd instead of rsvd


@dataclass(frozen=True)
class BlockSLR:
    """Per-leaf surrogate state; leading dims mirror the weight's stack dims."""

    p: jax.Array          # (..., n, r)   U diag(s_thr)  — L = p @ vt
    vt: jax.Array         # (..., r, m)
    s_vals: jax.Array     # (..., r)      thresholded singular values
    s_coo: sparse.CooMatrix  # sparse S
    y: jax.Array          # (..., n, m)   dual
    z: jax.Array          # (..., n, m)   cached penalty target L + S - Y/rho
    alpha: jax.Array      # (...,)        per-slice nuclear-norm weight
    beta: jax.Array       # (...,)        per-slice l1 weight
    rho: float            # static — Eq. (7) value for this block shape


jax.tree_util.register_dataclass(
    BlockSLR,
    data_fields=["p", "vt", "s_vals", "s_coo", "y", "z", "alpha", "beta"],
    meta_fields=["rho"],
)

# An SLRState is a dict: block name -> BlockSLR (a plain pytree).
SLRState = dict


def _leaf_by_path(params: Any, path: tuple) -> jax.Array:
    leaf = params
    for p in path:
        if hasattr(p, "key"):
            leaf = leaf[p.key]
        elif hasattr(p, "idx"):
            leaf = leaf[p.idx]
        elif hasattr(p, "name"):
            leaf = getattr(leaf, p.name)
        else:
            leaf = leaf[p]
    return leaf


def init_slr_state(
    params: Any, cfg: SalaadConfig = SalaadConfig()
) -> tuple[SLRState, list[BlockInfo]]:
    """Zero-initialized surrogate state for every selected block.

    With (L, S, Y) = 0 the coupled penalty starts as plain weight decay toward
    the SLR manifold through Z=0 scaled by the (tiny) rho — matching the
    paper's observation that stage 1 "does not interfere with the behavior of
    the underlying optimizer".
    """
    blocks = select_blocks(params, cfg.selection)
    n_logical = max(1, total_logical_blocks(blocks))
    state: SLRState = {}
    for info in blocks:
        x = _leaf_by_path(params, info.path)
        n, m = info.n, info.m
        r = rank_cap(n, m, cfg.rank_cap_ratio)
        cap = sparse.coo_cap(n, m, cfg.coo_cap_density)
        stack = info.stack_dims
        dt = cfg.surrogate_dtype
        state[info.name] = BlockSLR(
            p=jnp.zeros((*stack, n, r), dt),
            vt=jnp.zeros((*stack, r, m), dt),
            s_vals=jnp.zeros((*stack, r), dt),
            s_coo=sparse.CooMatrix(
                values=jnp.zeros((*stack, cap), dt),
                idx=jnp.full((*stack, cap), -1, jnp.int32),
                shape=(n, m),
            ),
            y=jnp.zeros((*stack, n, m), dt),
            z=jnp.zeros((*stack, n, m), dt),
            alpha=jnp.zeros(stack, jnp.float32),
            beta=jnp.zeros(stack, jnp.float32),
            rho=rho_for_block(n, m, n_logical, cfg.rho_constant),
        )
    return state, blocks


def _z_target(blk: BlockSLR) -> jax.Array:
    """Z = L + S - Y/rho, reconstructed from the compact storage."""
    l_dense = blk.p @ blk.vt
    s_dense = sparse.to_dense(blk.s_coo).astype(l_dense.dtype)
    return l_dense + s_dense - blk.y / blk.rho


def penalty(params: Any, state: SLRState, blocks: list[BlockInfo]) -> jax.Array:
    """Stage-1 coupled-loss term  sum_i rho_i/2 ||X_i - Z_i||_F^2.

    Uses the CACHED dense target Z (refreshed by every admm_update): Z is a
    constant within a guided-learning phase, so deriving it from (L, S, Y)
    every microstep would only add a scatter + matmul per block per step to
    the hot path. Computed in f32 for a well-scaled scalar.
    """
    total = jnp.zeros((), jnp.float32)
    for info in blocks:
        blk = state[info.name]
        x = _leaf_by_path(params, info.path).astype(jnp.float32)
        z = jax.lax.stop_gradient(blk.z).astype(jnp.float32)
        total = total + 0.5 * blk.rho * jnp.sum((x - z) ** 2)
    return total


# ---------------------------------------------------------------- stage 2 ---


def _admm_update_single(
    x: jax.Array,
    p: jax.Array,
    vt: jax.Array,
    s_vals: jax.Array,
    s_coo_values: jax.Array,
    s_coo_idx: jax.Array,
    y: jax.Array,
    alpha: jax.Array,
    beta: jax.Array,
    key: jax.Array,
    *,
    rho: float,
    shape: tuple[int, int],
    rank: int,
    cap: int,
    cfg: SalaadConfig,
) -> tuple[tuple, dict]:
    """One J-sweep of proximal updates for a single (n, m) block."""
    n, m = shape
    dt = p.dtype
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    s_dense = sparse.to_dense(
        sparse.CooMatrix(s_coo_values, s_coo_idx, shape)
    ).astype(jnp.float32)

    def sweep(j, carry):
        s_dense, y32, p_, vt_, s_vals_ = carry
        mmat = x32 - s_dense + y32 / rho
        if cfg.exact_svd:
            u, s, v = jnp.linalg.svd(mmat, full_matrices=False)
            u, s, v = u[:, :rank], s[:rank], v[:rank, :]
        else:
            u, s, v = randomized_svd(mmat, jax.random.fold_in(key, j), rank, cfg.rsvd_iters)
        s_thr = jnp.maximum(s - alpha / rho, 0.0)
        p_new = u * s_thr[None, :]
        l_dense = p_new @ v
        s_new = soft_threshold(x32 - l_dense + y32 / rho, beta / rho)
        y_new = y32 + rho * (x32 - l_dense - s_new)
        return (s_new, y_new, p_new, v, s_thr)

    s_dense, y32, p_new, vt_new, s_thr = jax.lax.fori_loop(
        0,
        cfg.admm_inner_steps,
        sweep,
        (s_dense, y32, jnp.zeros_like(p, jnp.float32), jnp.zeros_like(vt, jnp.float32), jnp.zeros_like(s_vals, jnp.float32)),
    )

    coo = sparse.from_dense(s_dense, cap)
    s_back = sparse.to_dense(coo)
    rank_ratio = effective_rank_ratio_from_singular_values(
        s_thr, cfg.controller.gamma, denom=min(n, m)
    )
    dens = sparse.nnz(coo).astype(jnp.float32) / (n * m)
    alpha_new, beta_new = controller_update(
        alpha, beta, rank_ratio, dens, rho, cfg.controller
    )
    l_dense = p_new @ vt_new
    recon_err = jnp.linalg.norm(x32 - l_dense - s_back)
    z_new = l_dense + s_back - y32 / rho
    stats = {
        "rank_ratio": rank_ratio,
        "density": dens,
        "recon_err": recon_err,
        "alpha": alpha_new,
        "beta": beta_new,
    }
    new = (
        p_new.astype(dt),
        vt_new.astype(dt),
        s_thr.astype(dt),
        coo.values.astype(dt),
        coo.idx,
        y32.astype(dt),
        z_new.astype(dt),
        alpha_new,
        beta_new,
    )
    return new, stats


def _update_leaf(x: jax.Array, blk: BlockSLR, info: BlockInfo, key: jax.Array, cfg: SalaadConfig):
    n, m = info.n, info.m
    r = blk.p.shape[-1]
    cap = blk.s_coo.values.shape[-1]
    fn = partial(
        _admm_update_single,
        rho=blk.rho,
        shape=(n, m),
        rank=r,
        cap=cap,
        cfg=cfg,
    )
    stack = info.stack_dims
    if stack:
        nb = int(np.prod(stack))
        flat = lambda a, tail: a.reshape(nb, *tail)  # noqa: E731
        keys = jax.random.split(key, nb)
        new, stats = jax.vmap(fn)(
            flat(x, (n, m)),
            flat(blk.p, (n, r)),
            flat(blk.vt, (r, m)),
            flat(blk.s_vals, (r,)),
            flat(blk.s_coo.values, (cap,)),
            flat(blk.s_coo.idx, (cap,)),
            flat(blk.y, (n, m)),
            blk.alpha.reshape(nb),
            blk.beta.reshape(nb),
            keys,
        )
        unflat = lambda a: a.reshape(*stack, *a.shape[1:])  # noqa: E731
        new = tuple(unflat(a) for a in new)
        stats = {k: unflat(v) for k, v in stats.items()}
    else:
        new, stats = fn(
            x, blk.p, blk.vt, blk.s_vals, blk.s_coo.values, blk.s_coo.idx,
            blk.y, blk.alpha, blk.beta, key,
        )
    p, vt, s_vals, coo_v, coo_i, y, z, alpha, beta = new
    blk_new = BlockSLR(
        p=p, vt=vt, s_vals=s_vals,
        s_coo=sparse.CooMatrix(coo_v, coo_i, (n, m)),
        y=y, z=z, alpha=alpha, beta=beta, rho=blk.rho,
    )
    return blk_new, stats


def admm_update(
    params: Any,
    state: SLRState,
    blocks: list[BlockInfo],
    cfg: SalaadConfig,
    step: jax.Array | int,
) -> tuple[SLRState, dict]:
    """Stage 2 + I-controller for every block. Deterministic in ``step``
    (rSVD keys are folded from it) so checkpoint/restart replays identically.
    """
    base_key = jax.random.PRNGKey(0)
    new_state: SLRState = {}
    all_stats: dict = {}
    for i, info in enumerate(blocks):
        x = _leaf_by_path(params, info.path)
        key = jax.random.fold_in(jax.random.fold_in(base_key, jnp.asarray(step, jnp.int32)), i)
        blk_new, stats = _update_leaf(x.astype(jnp.float32), state[info.name], info, key, cfg)
        new_state[info.name] = blk_new
        all_stats[info.name] = stats
    # aggregates (paper's delta-bar: mean reconstruction error over blocks)
    recon = [jnp.mean(s["recon_err"]) for s in all_stats.values()]
    all_stats["_mean_recon_err"] = jnp.mean(jnp.stack(recon)) if recon else jnp.zeros(())
    return new_state, all_stats


# --------------------------------------------------------------- deploy ----


def surrogate_params(params: Any, state: SLRState, blocks: list[BlockInfo]) -> Any:
    """X_hat = L + S for selected blocks; other leaves pass through.

    This is the paper's structured surrogate model used at deployment.
    """
    by_name = {info.name: info for info in blocks}

    def replace_leaf(path, leaf):
        from .selection import path_str

        name = path_str(path)
        if name in by_name and name in state:
            blk = state[name]
            dense = blk.p @ blk.vt + sparse.to_dense(blk.s_coo).astype(blk.p.dtype)
            return dense.astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(replace_leaf, params)


def slr_param_count(state: SLRState, blocks: list[BlockInfo]) -> dict:
    """Deployment parameter accounting (factored L + COO S), per block + total."""
    out = {}
    total = 0
    for info in blocks:
        blk = state[info.name]
        rank_live = np.asarray(jnp.sum((blk.s_vals > 0).astype(jnp.int32), axis=-1))
        nnz_live = np.asarray(sparse.nnz(blk.s_coo))
        l_params = int(np.sum(rank_live) * (info.n + info.m))
        s_params = int(np.sum(nnz_live))
        out[info.name] = {"L": l_params, "S": s_params}
        total += l_params + s_params
    out["_total"] = total
    return out
