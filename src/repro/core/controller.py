"""The I(ntegral)-controller (§4.2): block-wise adaptive (alpha, beta).

Integrates the tracking error between observed structure and targets:

    alpha <- alpha + rho * (Gamma_L^gamma - Gamma_hat) * dalpha
    beta  <- beta  + rho * (Upsilon_S    - Upsilon_hat) * dbeta

If the observed rank ratio exceeds the target, alpha (hence the SVT threshold
alpha/rho) grows and rank is pushed down — and vice versa; likewise for
density/beta. Thresholds are clamped at >= 0 (negative thresholds are
meaningless for the prox operators). Everything is element-wise so stacked
blocks carry per-slice controller state for free.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["ControllerConfig", "controller_update"]


@dataclass(frozen=True)
class ControllerConfig:
    target_rank_ratio: float = 0.15   # Gamma_hat (paper §5.1)
    target_density: float = 0.05      # Upsilon_hat
    dalpha: float = 0.1               # paper: order 1e-1
    dbeta: float = 0.003              # paper: order 1e-3 (best PPL at 0.003, Tbl 3)
    gamma: float = 0.999              # energy coverage for the rank ratio


def controller_update(
    alpha: jax.Array,
    beta: jax.Array,
    rank_ratio: jax.Array,
    density: jax.Array,
    rho: jax.Array | float,
    cfg: ControllerConfig,
) -> tuple[jax.Array, jax.Array]:
    """One integral step. All args broadcast over stacked-block dims."""
    alpha_new = alpha + rho * (rank_ratio - cfg.target_rank_ratio) * cfg.dalpha
    beta_new = beta + rho * (density - cfg.target_density) * cfg.dbeta
    return jnp.maximum(alpha_new, 0.0), jnp.maximum(beta_new, 0.0)
