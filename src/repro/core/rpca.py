"""Robust PCA via inexact ALM (Lin, Chen & Ma 2010) — the post-hoc baseline.

The paper uses RPCA twice: (i) App. A shows post-hoc RPCA on standard-trained
weights yields weak SLR structure; (ii) Fig. 3's "vanilla" curves apply
RPCA + HPA to full-rank checkpoints. We implement the standard inexact
augmented-Lagrange-multiplier iteration:

    L_{k+1} = SVT_{1/mu}(X - S_k + Y_k/mu)
    S_{k+1} = shrink_{lambda/mu}(X - L_{k+1} + Y_k/mu)
    Y_{k+1} = Y_k + mu (X - L_{k+1} - S_{k+1})
    mu <- min(mu * rho_mu, mu_max)

with lambda = lam_scale / sqrt(max(n, m)) and the usual mu_0 = 1.25/||X||_2.
Fixed iteration count (static shapes; convergence monitored via the returned
residual history) so it jits and vmaps over stacked blocks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .prox import soft_threshold, svt

__all__ = ["rpca"]


@partial(jax.jit, static_argnames=("n_iter",))
def rpca(
    x: jax.Array,
    n_iter: int = 50,
    lam_scale: float = 1.0,
    rho_mu: float = 1.2,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decompose ``x ~= L + S``. Returns (L, S, residual_history)."""
    x = x.astype(jnp.float32)
    n, m = x.shape
    lam = lam_scale / jnp.sqrt(jnp.asarray(max(n, m), jnp.float32))
    sigma1 = jnp.linalg.norm(x, 2)
    mu0 = 1.25 / jnp.maximum(sigma1, 1e-12)
    mu_max = mu0 * 1e7
    x_fro = jnp.maximum(jnp.linalg.norm(x), 1e-12)

    def body(carry, _):
        l, s, y, mu = carry
        _, l_new = svt(x - s + y / mu, 1.0 / mu)
        s_new = soft_threshold(x - l_new + y / mu, lam / mu)
        y_new = y + mu * (x - l_new - s_new)
        res = jnp.linalg.norm(x - l_new - s_new) / x_fro
        mu_new = jnp.minimum(mu * rho_mu, mu_max)
        return (l_new, s_new, y_new, mu_new), res

    init = (jnp.zeros_like(x), jnp.zeros_like(x), x / jnp.maximum(sigma1, 1e-12), mu0)
    (l, s, _, _), hist = jax.lax.scan(body, init, None, length=n_iter)
    return l, s, hist
