"""Whisper-small: encoder-decoder; conv frontend is a STUB supplying
precomputed frame embeddings (input_specs). [arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_theta=None,          # absolute sinusoidal positions
    encoder_layers=12,
    encoder_seq=1500,
    source="arXiv:2212.04356; unverified",
)
