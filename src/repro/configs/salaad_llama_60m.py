"""Paper LLaMA-60m: the SALAAD experimental family (GaLore/SLTrain dims)."""
import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="salaad-llama-60m",
    family="dense",
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=1376,
    vocab_size=32000,
    param_dtype=jnp.float32,   # paper trains fp32 (§5.1)
    source="paper §5.1; Touvron et al. 2023 family",
)
