"""Mamba2-370M: attention-free SSD. [arXiv:2405.21060]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    source="arXiv:2405.21060; unverified",
)
