"""Qwen3-MoE-30B-A3B: 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    top_k=8,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
