"""Paper LLaMA-350m: the SALAAD experimental family (GaLore/SLTrain dims)."""
import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="salaad-llama-350m",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2736,
    vocab_size=32000,
    param_dtype=jnp.float32,   # paper trains fp32 (§5.1)
    source="paper §5.1; Touvron et al. 2023 family",
)
