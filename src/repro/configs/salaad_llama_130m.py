"""Paper LLaMA-130m: the SALAAD experimental family (GaLore/SLTrain dims)."""
import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="salaad-llama-130m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    param_dtype=jnp.float32,   # paper trains fp32 (§5.1)
    source="paper §5.1; Touvron et al. 2023 family",
)
