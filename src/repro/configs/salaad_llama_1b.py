"""Paper LLaMA-1b: the SALAAD experimental family (GaLore/SLTrain dims)."""
import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="salaad-llama-1b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5461,
    vocab_size=32000,
    param_dtype=jnp.float32,   # paper trains fp32 (§5.1)
    source="paper §5.1; Touvron et al. 2023 family",
)
