"""InternVL2-76B backbone (InternLM2-style LLM); InternViT frontend is a
STUB supplying precomputed patch embeddings. [arXiv:2404.16821]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    num_patches=256,
    source="arXiv:2404.16821; unverified",
)
