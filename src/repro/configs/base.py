"""ArchConfig: one dataclass describing every supported architecture family,
plus the assigned input-shape grid and the per-arch registry.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "ARCH_IDS"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    # --- moe ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    attn_every: int = 0         # hybrid: shared attn block after every k ssm layers
    # --- variants ---
    mlp_type: str = "swiglu"    # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | nonparam_ln | layernorm
    qkv_bias: bool = False
    rope_theta: float | None = 1e4  # None => sinusoidal absolute (whisper)
    tie_embeddings: bool = False
    # --- encdec (audio): frontend is a STUB providing frame embeddings ---
    encoder_layers: int = 0
    encoder_seq: int = 0        # e.g. whisper 1500 frames
    # --- vlm: frontend is a STUB providing patch embeddings ---
    num_patches: int = 0
    # --- numerics / execution ---
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    kernel_impl: str = "blockwise"     # blockwise | pallas | dense
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    causal_scheme: str = "full"        # full | balanced (perf: skip upper tri)
    moe_groups: int | None = None      # dispatch groups (defaults to batch)
    scan_unroll: int | bool = 1        # dry-run sets full unroll for honest HLO costs
    source: str = ""                   # provenance note

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def reduced(self) -> "ArchConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        def shrink(v, lo, hi):
            return max(lo, min(v, hi))

        kv = shrink(self.num_kv_heads, 1, 2) if self.num_kv_heads else 0
        heads = 0
        if self.num_heads:
            # preserve GQA grouping: heads multiple of kv heads
            group = max(1, self.num_heads // max(self.num_kv_heads, 1))
            heads = kv * shrink(group, 1, 2)
        return replace(
            self,
            num_layers=shrink(self.num_layers, 2, 4 if self.attn_every == 0 else self.attn_every * 2),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32 if self.head_dim else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            # dropless at smoke scale so prefill+decode == full forward exactly
            capacity_factor=(
                float(min(self.num_experts, 4)) / max(min(self.top_k, 2), 1)
                if self.num_experts
                else self.capacity_factor
            ),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            encoder_layers=shrink(self.encoder_layers, 0, 2),
            encoder_seq=min(self.encoder_seq, 16),
            num_patches=min(self.num_patches, 8),
            param_dtype=jnp.float32,
            remat=False,
            kernel_impl="dense",
            attn_q_block=32,
            attn_kv_block=32,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2_2p7b",
    "dbrx_132b",
    "qwen3_moe_30b_a3b",
    "whisper_small",
    "olmo_1b",
    "phi3_medium_14b",
    "gemma_7b",
    "qwen1p5_4b",
    "internvl2_76b",
    "mamba2_370m",
    # the paper's own LLaMA family
    "salaad_llama_60m",
    "salaad_llama_130m",
    "salaad_llama_350m",
    "salaad_llama_1b",
]


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def runnable_cells() -> list[tuple[str, str]]:
    """The assigned (arch x shape) grid, minus the documented skips."""
    cells = []
    assigned = ARCH_IDS[:10]
    for a in assigned:
        cfg = get_arch(a)
        for s in SHAPES.values():
            if s.name == "long_500k" and not cfg.sub_quadratic:
                continue  # DESIGN.md §5: quadratic attention at 524k is skipped
            cells.append((a, s.name))
    return cells
