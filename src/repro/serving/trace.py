"""Per-request span tracing with Chrome-trace and JSONL export.

Where :mod:`repro.serving.telemetry` answers "how is the fleet doing"
(aggregates), this module answers "why was THIS request slow" (timelines).
A :class:`RequestTracer` collects spans and instants from the engine hooks:

* one track (``tid``) per engine SLOT under pid 1 — a request's life renders
  as nested spans on the slot it occupied: ``request`` envelope >
  ``admission_wait`` > ``prefill`` / ``prefill_chunk`` spans > ``decode``
  span, with instant markers for first token, tier switches, CoW copies,
  prefix-cache hits, speculative accept runs, and eviction/resume;
* one track per JITTED PROGRAM under pid 2 — ``decode[t0]``, ``prefill[32]``,
  ``chunk``, ``verify`` wall-clock slices, so a TTFT bubble on a slot track
  lines up visually with the program call that caused it.

Export formats:

* ``save_chrome(path)`` — Chrome trace-event JSON (the ``traceEvents``
  array form). Open in Perfetto (https://ui.perfetto.dev) or
  ``chrome://tracing``. ``ph:"X"`` complete events carry ``ts``/``dur`` in
  MICROSECONDS relative to the tracer's epoch; ``ph:"i"`` instants mark
  point events; ``ph:"M"`` metadata names the tracks.
* ``save_jsonl(path)`` — one structured event dict per line, stable schema
  (``kind``/``name``/``ts_us``/``dur_us``/``slot``/``uid``/``args``), for
  ad-hoc analysis without a trace viewer.

Tracing is host-side only and costs one list-append per event; the token
stream is bitwise-identical with tracing on or off (tests/test_telemetry.py).

    python -m repro.serving.trace validate trace.json   # schema check
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field

__all__ = ["RequestTracer", "validate_chrome_trace"]

_SLOT_PID = 1      # one tid per engine slot
_PROGRAM_PID = 2   # one tid per jitted program


@dataclass
class _Span:
    """An open span on a slot track; closed spans move to ``events``."""
    name: str
    t0: float
    args: dict = field(default_factory=dict)


class RequestTracer:
    """Collects slot-track spans + program-track slices for one engine run.

    All timestamps are ``time.monotonic()`` seconds; export converts to µs
    relative to the tracer's construction (so traces start near ts=0).
    Spans on one slot track nest strictly: ``begin_span``/``end_span`` pairs
    form a stack per slot, and the exporter emits them as ``ph:"X"``
    complete events (Perfetto infers nesting from containment).
    """

    def __init__(self, engine: str = "engine"):
        self.engine = engine
        self.epoch = time.monotonic()
        self.events: list[dict] = []       # closed events, export order
        self._open: dict[int, list[_Span]] = {}   # slot -> span stack
        self._programs: dict[str, int] = {}       # program name -> tid

    # ------------------------------------------------------------ helpers --

    def _us(self, t: float) -> int:
        return int(round((t - self.epoch) * 1e6))

    def _program_tid(self, name: str) -> int:
        tid = self._programs.get(name)
        if tid is None:
            tid = self._programs[name] = len(self._programs) + 1
        return tid

    # -------------------------------------------------------- slot spans ---

    def begin_span(self, slot: int, name: str, t: float | None = None,
                   **args):
        self._open.setdefault(slot, []).append(
            _Span(name, time.monotonic() if t is None else t, dict(args))
        )

    def end_span(self, slot: int, name: str, t: float | None = None, **args):
        """Close the innermost open span named ``name`` on ``slot``; spans
        opened after it (still unclosed, e.g. on eviction) are discarded —
        an aborted child span has no meaningful duration."""
        t = time.monotonic() if t is None else t
        stack = self._open.get(slot, [])
        for i in range(len(stack) - 1, -1, -1):
            if stack[i].name == name:
                span = stack[i]
                del stack[i:]
                span.args.update(args)
                self.events.append({
                    "kind": "span", "name": span.name, "slot": slot,
                    "ts_us": self._us(span.t0),
                    "dur_us": max(self._us(t) - self._us(span.t0), 0),
                    "args": span.args,
                })
                return
        # unmatched end (e.g. resume path after eviction dropped the stack):
        # record a zero-duration span so the event is still visible
        self.events.append({
            "kind": "span", "name": name, "slot": slot,
            "ts_us": self._us(t), "dur_us": 0, "args": dict(args),
        })

    def has_open(self, slot: int, name: str) -> bool:
        """True if an unclosed span named ``name`` is open on ``slot`` — the
        engines use this to close lifecycle spans lazily (a prefill span ends
        at whichever token event arrives first: first token, resume
        completion, or eviction)."""
        return any(s.name == name for s in self._open.get(slot, ()))

    def instant(self, slot: int, name: str, t: float | None = None, **args):
        self.events.append({
            "kind": "instant", "name": name, "slot": slot,
            "ts_us": self._us(time.monotonic() if t is None else t),
            "args": dict(args),
        })

    def program_span(self, program: str, tier: int, t0: float, dur_s: float):
        """One jitted-program call on the program pid (called by
        ``EngineTelemetry.measure_program``)."""
        self.events.append({
            "kind": "program", "name": program, "tier": tier,
            "ts_us": self._us(t0), "dur_us": max(int(round(dur_s * 1e6)), 0),
            "args": {"tier": tier},
        })

    # --------------------------------------------------- request lifecycle --

    def request_begin(self, slot: int, uid: int, t: float | None = None,
                      **args):
        self.begin_span(slot, "request", t, uid=uid, **args)

    def request_end(self, slot: int, uid: int, t: float | None = None,
                    **args):
        self.end_span(slot, "request", t, uid=uid, **args)

    # ------------------------------------------------------------ export ---

    def chrome_events(self) -> list[dict]:
        out = [
            {"ph": "M", "pid": _SLOT_PID, "tid": 0, "name": "process_name",
             "args": {"name": f"{self.engine} slots"}},
            {"ph": "M", "pid": _PROGRAM_PID, "tid": 0, "name": "process_name",
             "args": {"name": f"{self.engine} jitted programs"}},
        ]
        slots = sorted({e["slot"] for e in self.events if "slot" in e})
        for s in slots:
            out.append({"ph": "M", "pid": _SLOT_PID, "tid": s,
                        "name": "thread_name", "args": {"name": f"slot {s}"}})
        for prog, tid in sorted(self._programs.items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "pid": _PROGRAM_PID, "tid": tid,
                        "name": "thread_name", "args": {"name": prog}})
        for e in self.events:
            if e["kind"] == "span":
                out.append({"ph": "X", "pid": _SLOT_PID, "tid": e["slot"],
                            "name": e["name"], "ts": e["ts_us"],
                            "dur": e["dur_us"], "cat": "request",
                            "args": e["args"]})
            elif e["kind"] == "instant":
                out.append({"ph": "i", "pid": _SLOT_PID, "tid": e["slot"],
                            "name": e["name"], "ts": e["ts_us"], "s": "t",
                            "cat": "request", "args": e["args"]})
            elif e["kind"] == "program":
                out.append({"ph": "X", "pid": _PROGRAM_PID,
                            "tid": self._program_tid(e["name"]),
                            "name": e["name"], "ts": e["ts_us"],
                            "dur": e["dur_us"], "cat": "program",
                            "args": e["args"]})
        return out

    def save_chrome(self, path):
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, f)

    def save_jsonl(self, path):
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")


# ------------------------------------------------------------- validation ---


def validate_chrome_trace(doc) -> dict:
    """Structural validation of an exported Chrome trace: every event has a
    legal ``ph`` with the fields that phase requires, complete events on one
    track don't partially overlap (spans nest or are disjoint), and request
    envelopes contain their children. Returns summary counts; raises
    ValueError on violations. Used by the CI telemetry smoke and the schema
    round-trip test."""
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("object form must carry a traceEvents array")
    elif isinstance(doc, list):
        events = doc
    else:
        raise ValueError("trace must be an object or array")

    counts = {"X": 0, "i": 0, "M": 0}
    tracks: dict[tuple, list[tuple[int, int, str]]] = {}
    for e in events:
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            raise ValueError(f"unsupported ph {ph!r}: {e}")
        if "pid" not in e or "name" not in e:
            raise ValueError(f"event missing pid/name: {e}")
        if ph in ("X", "i"):
            ts = e.get("ts")
            if not isinstance(ts, int) or ts < 0:
                raise ValueError(f"ts must be a non-negative int (µs): {e}")
            if "tid" not in e:
                raise ValueError(f"event missing tid: {e}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, int) or dur < 0:
                raise ValueError(f"X event needs non-negative int dur: {e}")
            tracks.setdefault((e["pid"], e["tid"]), []).append(
                (e["ts"], e["ts"] + dur, e["name"])
            )
        if ph == "i" and e.get("s", "t") not in ("t", "p", "g"):
            raise ValueError(f"instant scope must be t/p/g: {e}")
        counts[ph] += 1

    # spans on a track must nest (contain) or be disjoint — partial overlap
    # means mismatched begin/end bookkeeping and renders as garbage
    for key, spans in tracks.items():
        # parents before equal-start children (longer span first)
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple[int, int, str]] = []
        for s in spans:
            while stack and stack[-1][1] <= s[0]:
                stack.pop()
            if stack and s[1] > stack[-1][1]:
                raise ValueError(
                    f"partially overlapping spans on track {key}: "
                    f"{stack[-1]} vs {s}"
                )
            stack.append(s)

    if counts["X"] == 0:
        raise ValueError("trace has no complete (ph=X) events")
    return {"events": sum(counts.values()), **counts,
            "tracks": len(tracks)}


def _main(argv=None) -> int:
    import argparse
    import pathlib
    import sys

    ap = argparse.ArgumentParser(
        description="validate a Chrome trace-event JSON file"
    )
    ap.add_argument("cmd", choices=["validate"])
    ap.add_argument("path")
    a = ap.parse_args(argv)
    try:
        doc = json.loads(pathlib.Path(a.path).read_text())
        rep = validate_chrome_trace(doc)
    except (ValueError, json.JSONDecodeError) as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 1
    print(json.dumps({"ok": True, **rep}))
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
