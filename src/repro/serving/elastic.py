"""Elastic deployment as a serving-time dimension: one weight bank, many tiers.

SALAAD's headline claim — one training run yields a *continuous spectrum* of
deployable capacities (HPA, §4.3) — used to live only offline in this repo:
``benchmarks/fig3_elastic.py`` swept budgets, but every engine was built
around ONE fixed-budget ``DeployedModel`` and changing capacity meant
rebuilding (and re-jitting) the whole engine. This module makes the spectrum
a first-class serving dimension:

``ModelBank``
    Holds the trained SLR (L + S) weights ONCE and materializes an ordered
    set of budget **tiers** — each a :class:`~repro.serving.deployed.
    DeployedModel` view produced by HPA truncation of the same state. Tier 0
    is the largest capacity; indices grow toward the cheap end of the
    spectrum. Leaves that HPA does not touch (embeddings, norms, any
    unselected block) are the *same array objects* in every tier — the bank
    reports that shared base alongside per-tier ``param_bytes``.

``Engine`` (protocol)
    The front-end contract every serving engine implements:
    ``submit / step / run / has_work / capabilities``. ``capabilities`` is a
    structured dict (families, KV layout, per-feature availability) — it
    feeds ``EngineCapabilityError`` messages and the ``launch/serve.py
    --help`` table, so "that feature is paged-only" is data, not prose.

``TierController``
    The serving-time counterpart of ``core/controller.py``'s I-controller:
    it integrates the tracking error between the free-page fraction of the
    paged engine's pool and a setpoint, and emits a tier *downshift* — under
    page pressure every slot serves at a cheaper tier (faster steps, sooner
    completions, sooner frees) BEFORE the engine resorts to eviction; when
    pressure clears the shift decays back to zero and slots return to their
    requested tiers. Because the paged KV's block table and page pools are
    tier-agnostic, a slot switches tiers mid-stream with no KV copy and no
    recompilation (each tier's program compiles once, on first use).

The same tier-agnosticism extends to the radix prompt cache
(serving/prefix_cache.py): shared KV pages carry no tier tag, so a prefix
prefilled while serving at one tier is reattached by admissions pinned to any
other — exactly the approximation a mid-stream tier switch already makes. The
controller's pressure signal counts the cache's reclaimable LRU tail as free
capacity, so a warm prefix cache does not read as scarcity and trigger
spurious downshifts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator, Protocol, runtime_checkable

import jax
import numpy as np

from ..core.hpa import hpa_keep_ratio
from .deployed import DeployedModel
from .slr_params import SLRLinear

__all__ = [
    "Engine",
    "ModelBank",
    "Tier",
    "TierController",
    "TierControllerConfig",
    "format_capability_table",
]


# ------------------------------------------------------------------- bank ---


@dataclass(frozen=True)
class Tier:
    """One budget tier of a :class:`ModelBank` (ordered: 0 = largest)."""

    index: int
    name: str
    keep: float | None          # HPA keep-ratio, None for wrapped weights
    model: DeployedModel
    param_bytes: int            # total served bytes of this tier's view

    @property
    def params(self) -> Any:
        return self.model.params


def _tier_bytes(model: DeployedModel) -> int:
    return model.param_bytes()["total_bytes"]


class ModelBank:
    """The trained SLR weights held once, served at an ordered set of tiers.

    Construction either wraps already-deployed models (``ModelBank(cfg,
    models)`` — the caller's order IS the tier order, largest first) or
    materializes the spectrum from one (params, SLR state) pair
    (:meth:`build`). Either way the bank replaces the old ``(arch_cfg,
    params)`` engine constructor contract: engines take ``(bank, ecfg)`` and
    read the architecture config and every tier's parameter tree from here.
    """

    def __init__(self, cfg, models, keeps=None, names=None):
        if not models:
            raise ValueError("ModelBank needs at least one tier")
        keeps = list(keeps) if keeps is not None else [None] * len(models)
        names = list(names) if names is not None else [None] * len(models)
        if len(keeps) != len(models) or len(names) != len(models):
            raise ValueError(
                f"{len(models)} tier model(s) but {len(keeps)} keep(s) / "
                f"{len(names)} name(s)"
            )
        self.cfg = cfg
        self._tiers: list[Tier] = []
        for i, model in enumerate(models):
            if not isinstance(model, DeployedModel):
                # raw param tree (e.g. a dense init): serve it as-is
                model = DeployedModel(cfg, model, fmt="dense")
            name = names[i] or (
                f"keep={keeps[i]:g}" if keeps[i] is not None else f"tier{i}"
            )
            self._tiers.append(
                Tier(index=i, name=name, keep=keeps[i], model=model,
                     param_bytes=_tier_bytes(model))
            )

    # ------------------------------------------------------------- build ---

    @classmethod
    def build(
        cls,
        cfg,
        params: Any,
        state,
        blocks,
        budgets=(1.0,),
        *,
        kappa: float = 0.7,
        fmt: str = "factored",
        bsr_block: int = 128,
    ) -> "ModelBank":
        """Materialize the elastic spectrum: one HPA truncation + deployment
        per budget, all views over the same base ``params`` tree. Budgets are
        sorted descending (tier 0 = largest capacity) and must be unique and
        in (0, 1]."""
        budgets = [float(b) for b in budgets]
        if not budgets:
            raise ValueError("ModelBank.build needs at least one budget")
        if len(set(budgets)) != len(budgets):
            raise ValueError(f"duplicate budgets in {budgets}")
        for b in budgets:
            if not 0.0 < b <= 1.0:
                raise ValueError(f"budget {b} outside (0, 1]")
        budgets = sorted(budgets, reverse=True)
        models = []
        for keep in budgets:
            slr_c, _ = hpa_keep_ratio(state, blocks, keep, kappa)
            models.append(
                DeployedModel.build(cfg, params, slr_c, blocks, fmt=fmt,
                                    bsr_block=bsr_block)
            )
        return cls(cfg, models, keeps=budgets)

    @classmethod
    def single(cls, cfg, weights) -> "ModelBank":
        """Wrap one already-deployed model (or a raw param tree) as a
        single-tier bank — the shortest path from one weight tree to an
        engine constructor."""
        return cls(cfg, [weights])

    # ------------------------------------------------------------ access ---

    def __len__(self) -> int:
        return len(self._tiers)

    def __iter__(self) -> Iterator[Tier]:
        return iter(self._tiers)

    def __getitem__(self, i: int) -> Tier:
        return self._tiers[self.resolve(i)]

    @property
    def tiers(self) -> tuple[Tier, ...]:
        return tuple(self._tiers)

    @property
    def num_tiers(self) -> int:
        return len(self._tiers)

    def resolve(self, tier: int) -> int:
        """Validated tier index (negative indices count from the cheap end)."""
        t = int(tier)
        n = len(self._tiers)
        if not -n <= t < n:
            raise ValueError(
                f"tier {tier} out of range for a {n}-tier bank "
                f"({[x.name for x in self._tiers]})"
            )
        return t % n

    def params(self, tier: int) -> Any:
        return self._tiers[self.resolve(tier)].params

    # -------------------------------------------------------- accounting ---

    def shared_base_bytes(self) -> int:
        """Bytes of leaves that are the SAME array object in every tier —
        the weight memory one bank amortizes across the whole spectrum
        (embeddings, norms, unselected blocks: HPA never copies them)."""
        if len(self._tiers) == 1:
            return 0

        def leaf_ids(tree) -> dict[int, Any]:
            is_slr = lambda x: isinstance(x, SLRLinear)  # noqa: E731
            return {
                id(leaf): leaf
                for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_slr)
                if not isinstance(leaf, SLRLinear)
            }

        common = None
        first = leaf_ids(self._tiers[0].params)
        for tier in self._tiers[1:]:
            ids = set(leaf_ids(tier.params))
            common = ids if common is None else common & ids
        common &= set(first)
        return sum(
            int(np.prod(first[i].shape)) * first[i].dtype.itemsize
            for i in common
        )

    def report(self) -> dict:
        """Per-tier served bytes + the shared base, for provenance payloads."""
        return {
            "num_tiers": len(self._tiers),
            "tiers": [
                {
                    "index": t.index,
                    "name": t.name,
                    "keep": t.keep,
                    "fmt": t.model.fmt,
                    "param_bytes": t.param_bytes,
                }
                for t in self._tiers
            ],
            "shared_base_bytes": self.shared_base_bytes(),
        }


# --------------------------------------------------------------- protocol ---


@runtime_checkable
class Engine(Protocol):
    """The serving front-end contract. ``launch/serve.py``, the ``serve_*``
    benchmarks, and the examples program against THIS, not a concrete class —
    it is the seam the ROADMAP's remaining serving items (sharded serving,
    ssm/hybrid/encdec engines) plug into."""

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               deadline: float | None = None,
               tier: int | None = None,
               adapter: int | None = None,
               submitted_at: float | None = None) -> int:
        """Enqueue a request; returns its uid. ``tier`` pins the request to a
        bank tier (None = the engine's default tier). ``adapter`` names a
        registered adapter when the engine serves an ``AdapterBank``
        (None = the bank's default adapter). ``submitted_at``
        (monotonic clock) lets open-loop harnesses backdate the submission to
        the SCHEDULED arrival — the one timestamp basis every TTFT metric
        uses (None = now). Raises ``RequestRejected`` when the request can
        never be served."""
        ...

    def step(self) -> list:
        """One engine tick; returns the requests that finished this tick."""
        ...

    def run(self, max_steps: int = 10_000) -> list:
        """Drive everything to completion (batch mode)."""
        ...

    @property
    def has_work(self) -> bool: ...

    @classmethod
    def capabilities(cls) -> dict:
        """Structured capability report: which cache families this engine
        serves, its KV layout, and per-feature availability."""
        ...

    def stats_snapshot(self) -> dict:
        """Host-side serving stats: scheduler/jit counters plus the
        ``serving/telemetry.py`` metrics-registry snapshot. Every engine
        also carries ``engine.metrics`` (an ``EngineTelemetry`` — one metric
        schema across engines, Prometheus-exportable) and ``start_trace()``
        (a ``serving/trace.py`` span tracer with Chrome-trace export)."""
        ...


def format_capability_table(engines: dict[str, type]) -> str:
    """Render ``capabilities()`` of several engine classes as a text table
    (the ``launch/serve.py --help`` epilog)."""
    caps = {name: cls.capabilities() for name, cls in engines.items()}
    features = sorted({f for c in caps.values() for f in c["features"]})
    rows = [["engine", "families", "kv"] + features]
    for name, c in caps.items():
        fam = ",".join(c["families"])
        rows.append(
            [name, fam, c["kv"]]
            + [_fmt_feature(c["features"][f]) for f in features]
        )
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(cell.ljust(w) for cell, w in zip(row, widths)).rstrip()
             for row in rows]
    lines.insert(1, "-" * len(lines[0]))
    return "\n".join(lines)


def _fmt_feature(v) -> str:
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, (list, tuple)):
        return ",".join(str(x) for x in v)
    return str(v)


# ------------------------------------------------------------- controller ---


@dataclass(frozen=True)
class TierControllerConfig:
    target_free_frac: float = 0.25   # free-page fraction setpoint
    gain: float = 4.0                # integral gain: tiers per unit pressure
    ema: float = 0.5                 # smoothing of the observed free fraction


class TierController:
    """I-controller over the serving-tier downshift (``core/controller.py``
    style, like the speculative window's ``SpecController``).

    Integrates the tracking error between the setpoint and the observed
    (EMA-smoothed) free-page fraction of the paged pool:

        shift_f <- clip(shift_f + gain * (target_free - free_frac), 0, T-1)

    Pressure (free fraction below the setpoint) accumulates a positive shift:
    every slot serves ``shift`` tiers below its requested tier, so decode
    steps get cheaper, sequences finish sooner, and pages return to the pool
    — the engine spends capacity *quality* before it spends *requests*
    (eviction stays the last resort when the pool actually runs dry). When
    pressure clears the error changes sign and the shift decays back to 0.
    The float state quantizes to an int at read time, so the engine runs at
    most ``num_tiers`` distinct (already-compiled) programs.
    """

    def __init__(self, num_tiers: int,
                 cfg: TierControllerConfig = TierControllerConfig()):
        if num_tiers < 1:
            raise ValueError(f"num_tiers must be >= 1, got {num_tiers}")
        if not 0.0 < cfg.target_free_frac < 1.0:
            raise ValueError(
                f"target_free_frac {cfg.target_free_frac} outside (0, 1)"
            )
        self.cfg = cfg
        self.num_tiers = int(num_tiers)
        self.shift_f = 0.0
        self.free_ema: float | None = None

    @property
    def shift(self) -> int:
        return int(round(self.shift_f))

    def update(self, free_frac: float) -> int:
        """One integral step on the observed free-page fraction."""
        c = self.cfg
        self.free_ema = (
            float(free_frac) if self.free_ema is None
            else c.ema * self.free_ema + (1.0 - c.ema) * float(free_frac)
        )
        err = c.target_free_frac - self.free_ema
        self.shift_f = float(
            np.clip(self.shift_f + c.gain * err, 0.0, self.num_tiers - 1)
        )
        return self.shift
