"""Batched serving engines: continuous batching over jitted programs.

Two batched engines share one scheduler skeleton (admit → grow → one jitted
decode per tick):

``ServingEngine`` (PR 1) — slot-padded: a fixed decode batch of ``max_slots``
sequences sharing one contiguous KV cache in which EVERY slot reserves
``max_len`` positions. Serving memory is governed by the longest possible
request, not the actual workload.

``PagedServingEngine`` — block-paged: KV lives in a fixed pool of
``num_blocks`` pages of ``block_size`` tokens (``models.transformer.
PagedKVCache``); a host-side :class:`BlockAllocator` hands pages to slots on
demand. Requests admit whenever free pages cover their prompt plus a decode
reservation (mid-stream admission — admission is re-tried every tick, not
between request groups), finished or evicted slots return pages immediately,
and when the pool runs dry a victim (longest-remaining or LRU) is evicted
back to the queue and later resumes by re-prefilling prompt + generated
tokens. Decode attention gathers pages through the block table (pure-JAX
gather, or the Pallas ``kernels/paged_attention.py`` kernel under
``kernel_impl='pallas'``); ``kv_dtype='int8'`` stores pages quantized via
``serving/kv_quant.py``.

Device programs (all shapes static, so serving never recompiles):
  * ``prefill[bucket]`` — batched prompt forward; KV rows (slot-padded) or
    whole prompt blocks (paged) and the first sampled token scatter into
    place inside the same jitted call
  * ``decode`` — (params, tokens (S, 1), cache, active (S,), step)
    -> (next_tokens (S,), cache); ONE call per engine tick

Weights may be a raw param tree (dense) or a ``DeployedModel`` serving
SLR (L + S) weights in factored / block-CSR form — the programs are format-
agnostic because every linear site goes through ``models.layers.apply_weight``.

``ReferenceEngine`` preserves the seed per-slot/per-token path: it is the
baseline that ``benchmarks/serve_throughput.py`` measures against, and the
fallback for cache families without per-slot lengths (ssm/hybrid/encdec).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib
from ..models import transformer as transformer_lib

log = logging.getLogger(__name__)

BATCHED_FAMILIES = ("dense", "moe", "vlm")  # cache families with per-slot lengths

# float payload dtypes; "int8" is also accepted but only by the paged engine,
# which stores int8 payload pools + f32 scale pools (never a bare int8 cache)
_KV_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "bf16": jnp.bfloat16,
}
_EVICT_POLICIES = ("longest_remaining", "lru")


class RequestRejected(ValueError):
    """Raised by ``submit`` when a request can never be served by this engine
    (too long for the cache, or larger than the whole page pool). A graceful
    error path — the engine keeps serving everything already accepted."""


class EngineCapabilityError(RequestRejected):
    """A paged-only feature (quantized KV pages, speculative decoding) was
    requested on an engine/cache family that cannot provide it. Subclasses
    :class:`RequestRejected` so callers handle both through one error path."""


def _validate_request(prompt: list[int], max_new_tokens: int, max_len: int):
    if len(prompt) < 1:
        raise RequestRejected("empty prompt")
    if len(prompt) + max_new_tokens > max_len:
        raise RequestRejected(
            f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
            f"cache capacity {max_len}"
        )


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    first_token_at: float = 0.0      # TTFT = first_token_at - submitted_at
    finished_at: float = 0.0
    token_times: list[float] = field(default_factory=list)
    deadline: float | None = None    # absolute wall-clock SLO deadline
    evictions: int = 0


@dataclass
class EngineConfig:
    max_slots: int = 4        # concurrent sequences (decode batch)
    max_len: int = 256        # max prompt+generation length per request
    greedy: bool = True
    temperature: float = 1.0  # used when greedy=False (on-device sampling)
    eos_token: int | None = None
    seed: int = 0
    min_bucket: int = 8       # smallest prefill length bucket
    # paged engine only:
    block_size: int = 16      # tokens per KV page
    num_blocks: int | None = None   # page pool size; None = max_slots * max_len worth
    kv_dtype: str = "float32"       # float32 | bfloat16 | int8 (paged pages quantized)
    evict_policy: str = "longest_remaining"  # or "lru"
    decode_reserve: int | None = None  # decode headroom (tokens) required to admit;
    #                                    None = one block
    # speculative engine only (serving/speculative.py):
    spec_k: int = 0                 # draft tokens per tick; 0 = speculation off
    spec_adaptive: bool = False     # adapt k from observed acceptance rate
    spec_draft_mode: str = "auto"   # auto | parallel (greedy lookahead draft)
    #                                 | sequential (autoregressive proposals)
    spec_draft_kv_dtype: str = "bfloat16"  # draft page-pool payload (its own,
    #                                        smaller pool; never affects the
    #                                        target distribution)


def _as_params(params_or_deployed):
    """Accept a raw param tree or a serving.deployed.DeployedModel."""
    return getattr(params_or_deployed, "params", None) \
        if hasattr(params_or_deployed, "fmt") else params_or_deployed


def decode_emitted_tokens(done: list[Request]) -> int:
    """Tokens these requests emitted from DECODE steps: every (re-)admission
    emits its first token from the prefill program, the rest amortize over
    decode calls. The convention lives here so benchmark/launcher metrics
    (tokens-per-step) cannot drift from the engines that define it."""
    return sum(len(r.out_tokens) - 1 - r.evictions for r in done)


class ServingEngine:
    """Single-host batched slot-padded engine; the multi-pod path swaps the
    jitted fns for their pjit'd versions (same signatures — launch/serve.py)."""

    _speculative = False   # only serving.speculative.SpeculativeEngine drafts

    def __init__(self, arch_cfg, params, ecfg: EngineConfig = EngineConfig()):
        self._init_common(arch_cfg, params, ecfg)
        if ecfg.kv_dtype == "int8":
            raise ValueError(
                "int8 KV needs the paged engine (PagedServingEngine stores "
                "quantized pages); the contiguous engine serves float caches"
            )
        cache = model_lib.init_cache(
            arch_cfg, ecfg.max_slots, ecfg.max_len,
            dtype=_KV_DTYPES[ecfg.kv_dtype],
        )
        self.cache = cache._replace(
            length=jnp.zeros((ecfg.max_slots,), jnp.int32)
        )
        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(4,))

    def _init_common(self, arch_cfg, params, ecfg: EngineConfig):
        if arch_cfg.family not in BATCHED_FAMILIES:
            raise ValueError(
                f"batched engine needs a KV-cache family, got {arch_cfg.family!r};"
                " use ReferenceEngine for ssm/hybrid/encdec"
            )
        if ecfg.spec_k and not self._speculative:
            # never silently drop a requested feature: spec_k is only
            # consumed by serving.speculative.SpeculativeEngine
            raise EngineCapabilityError(
                f"{type(self).__name__} does not speculate "
                f"(spec_k={ecfg.spec_k} requested); use SpeculativeEngine"
            )
        if ecfg.kv_dtype not in _KV_DTYPES and ecfg.kv_dtype != "int8":
            raise ValueError(f"unknown kv_dtype {ecfg.kv_dtype!r}")
        if ecfg.evict_policy not in _EVICT_POLICIES:
            raise ValueError(
                f"unknown evict_policy {ecfg.evict_policy!r}; "
                f"expected one of {_EVICT_POLICIES}"
            )
        self.cfg = arch_cfg
        self.ecfg = ecfg
        deployed = _as_params(params)
        self.params = deployed if deployed is not None else params
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}   # slot -> request
        self._uid = 0
        self._steps = 0
        self._last_token = np.zeros(ecfg.max_slots, np.int64)
        self._base_key = jax.random.PRNGKey(ecfg.seed)

        # instrumentation: device calls vs (re)traces — tests assert the
        # decode loop is one device call per step and compiles exactly once
        self.decode_calls = 0
        self.decode_traces = 0
        self.prefill_calls = 0
        self.prefill_traces = 0
        self.evictions = 0

    # ------------------------------------------------------------ intake ---

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               deadline: float | None = None) -> int:
        self._validate(prompt, max_new_tokens)
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens,
                    submitted_at=time.time(), deadline=deadline)
        )
        return self._uid

    def _validate(self, prompt: list[int], max_new_tokens: int):
        _validate_request(prompt, max_new_tokens, self.ecfg.max_len)

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self._active)

    @property
    def queued(self) -> int:
        return len(self._queue)

    # ----------------------------------------------------- device programs ---

    def _sample(self, logits: jax.Array, step: jax.Array, salt: int,
                slots: jax.Array | None = None) -> jax.Array:
        """Greedy or temperature sampling, on device. logits: (S, vocab).

        ``salt`` separates the prefill / decode / draft / verify streams — all
        can sample within the same engine tick and must not share gumbel
        noise. Each row additionally folds its slot id (``slots``; default row
        index) into the key, so slots carry independent streams: eviction /
        re-prefill resume and draft-vs-verify sampling never correlate across
        slots. The greedy path is untouched.
        """
        if self.ecfg.greedy or self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.fold_in(self._base_key, step), salt)
        if slots is None:
            slots = jnp.arange(logits.shape[0])
        keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(slots)
        g = jax.vmap(lambda k: jax.random.gumbel(k, logits.shape[-1:]))(keys)
        return jnp.argmax(logits / self.ecfg.temperature + g, axis=-1).astype(jnp.int32)

    def _decode_fn(self, params, tokens, cache, active, step):
        self.decode_traces += 1  # python side effect: counts traces only
        logits, new_cache = model_lib.decode_step(params, tokens, cache, self.cfg)
        # only active slots advance their valid prefix; inactive slots wrote a
        # junk row at their frozen position — the next real token overwrites it
        # (paged: inactive slots map to unmapped pages, the write dropped)
        new_len = jnp.where(active, new_cache.length, cache.length)
        next_tok = self._sample(logits[:, -1], step, salt=0)
        return next_tok, new_cache._replace(length=new_len)

    def _prefill_fn(self, params, tokens, lengths, slot_ids, cache, step):
        self.prefill_traces += 1
        logits, pcache = model_lib.prefill(
            params, {"tokens": tokens}, self.cfg, max_len=self.ecfg.max_len,
            cache_dtype=cache.k.dtype,
        )
        # scatter the prefilled KV rows / lengths into the target slots;
        # padded rows carry slot_id == max_slots and drop out of bounds
        k = cache.k.at[:, slot_ids].set(pcache.k, mode="drop")
        v = cache.v.at[:, slot_ids].set(pcache.v, mode="drop")
        new_len = cache.length.at[slot_ids].set(lengths, mode="drop")
        # the logits at the last prompt position yield the first generated token
        last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)
        first_tok = self._sample(last[:, 0], step, salt=1, slots=slot_ids)
        return first_tok, cache._replace(k=k, v=v, length=new_len)

    # ------------------------------------------------------------- steps ---

    def _bucket(self, n: int) -> int:
        b = self.ecfg.min_bucket
        while b < n:
            b *= 2
        return min(b, self.ecfg.max_len)

    def _admit(self, free: list[int], done: list[Request], step: int):
        """Batch all admissible queued requests through one prefill call."""
        take = min(len(free), len(self._queue))
        if not take:
            return
        reqs = [self._queue.pop(0) for _ in range(take)]
        s = self.ecfg.max_slots
        bucket = self._bucket(max(len(r.prompt) for r in reqs))
        tokens = np.zeros((s, bucket), np.int32)
        lengths = np.ones((s,), np.int32)        # padded rows: 1 valid token
        slot_ids = np.full((s,), s, np.int32)    # out-of-range => dropped
        slots = []
        now = time.time()
        for i, req in enumerate(reqs):
            slot = free.pop()
            slots.append(slot)
            req.admitted_at = now
            self._active[slot] = req
            tokens[i, : len(req.prompt)] = req.prompt
            lengths[i] = len(req.prompt)
            slot_ids[i] = slot
        first, self.cache = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(slot_ids), self.cache, jnp.asarray(step, jnp.int32),
        )
        self.prefill_calls += 1
        firsts = np.asarray(first)               # one fetch per admit batch
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            self._record(slot, req, int(firsts[i]), free, done)

    def _record(self, slot: int, req: Request, tok: int, free, done):
        now = time.time()
        req.out_tokens.append(tok)
        req.token_times.append(now)
        if req.first_token_at == 0.0:
            req.first_token_at = now
        self._last_token[slot] = tok
        if len(req.out_tokens) >= req.max_new_tokens or (
            self.ecfg.eos_token is not None and tok == self.ecfg.eos_token
        ):
            req.done = True
            req.finished_at = now
            done.append(req)
            del self._active[slot]
            free.append(slot)
            self._release(slot)

    def _release(self, slot: int):
        """Hook: the paged engine returns the slot's pages to the pool."""

    def _pre_decode(self, free: list[int], done: list[Request]):
        """Hook: the paged engine grows page allocations / evicts here."""

    def _device_cache(self):
        """Hook: the paged engine pushes host block-table updates here."""
        return self.cache

    def step(self) -> list[Request]:
        """ONE engine tick: admit whatever fits, then one jitted decode step
        over all active slots. Returns requests that finished this tick."""
        done: list[Request] = []
        s = self.ecfg.max_slots
        self._steps += 1
        free = [x for x in range(s) if x not in self._active]
        self._admit(free, done, self._steps)
        if not self._active:
            return done
        self._pre_decode(free, done)
        if not self._active:
            return done
        active = np.zeros((s,), bool)
        for slot in self._active:
            active[slot] = True
        self._decode_tick(active, free, done)
        return done

    def _decode_tick(self, active: np.ndarray, free: list[int],
                     done: list[Request]):
        """Device portion of a tick (hook: the speculative engine replaces
        this with its draft + k-wide verify program)."""
        s = self.ecfg.max_slots
        tokens = np.zeros((s, 1), np.int32)
        for slot in self._active:
            tokens[slot, 0] = self._last_token[slot]
        nxt, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self._device_cache(),
            jnp.asarray(active), jnp.asarray(self._steps, jnp.int32),
        )
        self.decode_calls += 1
        toks = np.asarray(nxt)               # ONE host sync per step
        for slot, req in list(self._active.items()):
            self._record(slot, req, int(toks[slot]), free, done)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive everything to completion (batch mode)."""
        done: list[Request] = []
        steps = 0
        while self.has_work and steps < max_steps:
            steps += 1
            done.extend(self.step())
        return done


# ------------------------------------------------------------------ paged ---


class BlockAllocator:
    """Host-side allocator over a fixed pool of KV pages.

    Pages are interchangeable — any free page can map any (slot, block)
    position, so there is no external fragmentation by construction; the only
    waste is internal (the partially-filled last block of each sequence).
    Invariants (asserted in tests): a page is never handed out twice, frees
    must return owned pages, and free + allocated always equals the pool.
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))
        self._owned: set[int] = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._owned)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """n pages, or None if the pool cannot cover them (no partial grants)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned.update(pages)
        return pages

    def free(self, pages: list[int]):
        for p in pages:
            if p not in self._owned:
                raise ValueError(f"freeing page {p} that is not allocated")
            self._owned.remove(p)
            self._free.append(p)


class PagedServingEngine(ServingEngine):
    """Continuously-batched engine over a block-paged KV cache.

    Serving memory is ``num_blocks * block_size`` tokens of KV shared by all
    slots — short requests no longer pay for ``max_len``. Admission happens
    whenever a slot AND enough free pages exist (checked every tick); decode
    allocations grow one page at a time, and pool exhaustion evicts a victim
    back to the queue (it resumes later by re-prefilling prompt + generated
    tokens, which under greedy decoding reproduces the same continuation).
    """

    def __init__(self, arch_cfg, params, ecfg: EngineConfig = EngineConfig()):
        self._init_common(arch_cfg, params, ecfg)
        bs = ecfg.block_size
        assert bs >= 1
        self._bs = bs
        self._max_len = -(-ecfg.max_len // bs) * bs
        self._nb_slot = self._max_len // bs          # block-table width
        self.num_blocks = ecfg.num_blocks or ecfg.max_slots * self._nb_slot
        self.allocator = BlockAllocator(self.num_blocks)
        self._quantized = ecfg.kv_dtype == "int8"
        self.cache = model_lib.init_paged_cache(
            arch_cfg, ecfg.max_slots, self.num_blocks, bs, self._nb_slot,
            dtype=jnp.float32 if self._quantized else _KV_DTYPES[ecfg.kv_dtype],
            quantized=self._quantized,
        )
        # host mirror of the block table; pushed to device only when dirty
        self._table = np.full(
            (ecfg.max_slots, self._nb_slot), self.num_blocks, np.int32
        )
        self._table_dirty = False
        self._pages: dict[int, list[int]] = {}       # slot -> page ids
        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(5,))

    # ------------------------------------------------------------ intake ---

    def _validate(self, prompt: list[int], max_new_tokens: int):
        super()._validate(prompt, max_new_tokens)
        need = -(-(len(prompt) + max_new_tokens) // self._bs)
        if need > self.num_blocks:
            raise RequestRejected(
                f"request needs {need} KV pages but the whole pool holds "
                f"{self.num_blocks}"
            )

    def _bucket(self, n: int) -> int:
        b = super()._bucket(n)
        return min(-(-max(b, self._bs) // self._bs) * self._bs, self._max_len)

    # ----------------------------------------------------- device programs ---

    def _prefill_fn(self, params, tokens, lengths, slot_ids, page_map, cache, step):
        self.prefill_traces += 1
        logits, kvs, _ = model_lib._forward(
            params, {"tokens": tokens}, self.cfg, collect_kv=True
        )
        cache = transformer_lib.scatter_prefill_pages(cache, kvs, page_map)
        new_len = cache.length.at[slot_ids].set(lengths, mode="drop")
        last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)
        first_tok = self._sample(last[:, 0], step, salt=1, slots=slot_ids)
        return first_tok, cache._replace(length=new_len)

    # ------------------------------------------------------------- steps ---

    def _admit(self, free: list[int], done: list[Request], step: int):
        """Admit every queued request that a free slot + free pages can cover
        (earliest deadline first when deadlines are present, else FIFO)."""
        if not self._queue or not free:
            return
        if any(r.deadline is not None for r in self._queue):
            self._queue.sort(
                key=lambda r: (r.deadline is None, r.deadline or 0.0, r.uid)
            )
        reserve = self.ecfg.decode_reserve or self._bs
        admitted: list[tuple[int, Request, list[int], int]] = []
        while self._queue and free:
            req = self._queue[0]
            ptoks = req.prompt + req.out_tokens      # evicted requests resume
            remaining = max(req.max_new_tokens - len(req.out_tokens), 1)
            want = len(ptoks) + min(max(reserve, 1), remaining)
            blocks = min(-(-want // self._bs), self._nb_slot)
            pages = self.allocator.alloc(blocks)
            if pages is None:
                break                                # pool full: stay queued
            self._queue.pop(0)
            slot = free.pop()
            req.admitted_at = time.time()
            self._active[slot] = req
            self._pages[slot] = pages
            self._table[slot, : len(pages)] = pages
            self._table_dirty = True
            admitted.append((slot, req, pages, len(ptoks)))
        if not admitted:
            return

        s = self.ecfg.max_slots
        bucket = self._bucket(max(plen for _, _, _, plen in admitted))
        nb_bucket = bucket // self._bs
        tokens = np.zeros((s, bucket), np.int32)
        lengths = np.ones((s,), np.int32)
        slot_ids = np.full((s,), s, np.int32)
        page_map = np.full((s, nb_bucket), self.num_blocks, np.int32)
        for i, (slot, req, pages, plen) in enumerate(admitted):
            ptoks = req.prompt + req.out_tokens
            tokens[i, :plen] = ptoks
            lengths[i] = plen
            slot_ids[i] = slot
            prompt_blocks = -(-plen // self._bs)
            page_map[i, :prompt_blocks] = pages[:prompt_blocks]
        firsts = self._prefill_admitted(tokens, lengths, slot_ids, page_map, step)
        for i, (slot, req, _, _) in enumerate(admitted):
            self._record(slot, req, int(firsts[i]), free, done)

    def _prefill_admitted(self, tokens, lengths, slot_ids, page_map, step):
        """Device portion of admission (hook: the speculative engine also
        prefills the draft page pools here). Returns first tokens (host)."""
        first, self.cache = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(slot_ids), jnp.asarray(page_map), self.cache,
            jnp.asarray(step, jnp.int32),
        )
        self.prefill_calls += 1
        return np.asarray(first)

    def _pre_decode(self, free: list[int], done: list[Request]):
        """Grow each active slot's pages to cover this tick's KV writes; evict
        when the pool is dry. The next decode writes the KV of the latest
        sampled token at position len(prompt) + len(out) - 1; the speculative
        engine widens the window (``_write_window`` > 1) to cover all k draft
        positions. Writes past the table's capacity drop device-side, so the
        need is capped at the table width."""
        window = getattr(self, "_write_window", 1)
        for slot in list(self._active):
            req = self._active.get(slot)
            if req is None:
                continue
            write_pos = len(req.prompt) + len(req.out_tokens) - 1 + (window - 1)
            need = min(write_pos // self._bs + 1, self._nb_slot)
            while slot in self._active and len(self._pages[slot]) < need:
                page = self.allocator.alloc(1)
                if page is not None:
                    idx = len(self._pages[slot])
                    self._pages[slot].append(page[0])
                    self._table[slot, idx] = page[0]
                    self._table_dirty = True
                    continue
                victim = self._choose_victim()
                self._evict(victim, free)

    def _choose_victim(self) -> int:
        if self.ecfg.evict_policy == "lru":
            # least-recently admitted slot
            return min(self._active, key=lambda s: (self._active[s].admitted_at, s))
        # longest_remaining: its pages stay pinned for the longest otherwise
        return max(
            self._active,
            key=lambda s: (
                self._active[s].max_new_tokens - len(self._active[s].out_tokens), s
            ),
        )

    def _evict(self, slot: int, free: list[int]):
        """Return the slot's pages and push its request to the queue head; it
        re-prefills prompt + generated tokens on re-admission."""
        req = self._active.pop(slot)
        req.evictions += 1
        self.evictions += 1
        self._release(slot)
        self._queue.insert(0, req)
        free.append(slot)

    def _release(self, slot: int):
        pages = self._pages.pop(slot, None)
        if pages:
            self.allocator.free(pages)
        self._table[slot, :] = self.num_blocks
        self._table_dirty = True

    def _device_cache(self):
        if self._table_dirty:
            self.cache = self.cache._replace(block_table=jnp.asarray(self._table))
            self._table_dirty = False
        return self.cache


# -------------------------------------------------------------- reference ---


class ReferenceEngine:
    """The seed per-slot, per-token engine (scalar-length cache, slot slicing
    with host write-back, one ``int()`` sync per slot per token).

    Kept as (a) the measured baseline for ``benchmarks/serve_throughput.py``
    and (b) the serving path for cache families without per-slot lengths.
    """

    def __init__(self, arch_cfg, params, ecfg: EngineConfig = EngineConfig()):
        missing = []
        if ecfg.kv_dtype != "float32":
            missing.append(f"kv_dtype={ecfg.kv_dtype!r}")
        if ecfg.spec_k:
            missing.append(f"speculative decoding (spec_k={ecfg.spec_k})")
        if missing:
            raise EngineCapabilityError(
                f"family {arch_cfg.family!r} serves through ReferenceEngine "
                f"(per-slot loop, contiguous float32 cache); paged-only "
                f"feature(s) requested: {', '.join(missing)}"
            )
        log.info(
            "ReferenceEngine serving family %r: per-slot per-token loop, "
            "contiguous float32 cache — no paged features (kv_dtype, "
            "speculation, eviction/resume)",
            arch_cfg.family,
        )
        self.cfg = arch_cfg
        self.ecfg = ecfg
        deployed = _as_params(params)
        self.params = deployed if deployed is not None else params
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}   # slot -> request
        self._uid = 0

        self.cache = model_lib.init_cache(
            arch_cfg, ecfg.max_slots, ecfg.max_len, dtype=jnp.float32
        )
        self._decode = jax.jit(
            lambda p, tok, cache: model_lib.decode_step(p, tok, cache, arch_cfg)
        )

    # ------------------------------------------------------------ intake ---

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               deadline: float | None = None) -> int:
        _validate_request(prompt, max_new_tokens, self.ecfg.max_len)
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens,
                    submitted_at=time.time(), deadline=deadline)
        )
        return self._uid

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self._active)

    # ------------------------------------------------------------- steps ---

    def _prefill_into_slot(self, slot: int, req: Request):
        """Per-token insertion into this slot's cache rows (the LAST prompt
        token is fed by the first decode step, so prefill stops one short)."""
        self._slot_len[slot] = 0
        for tok in req.prompt[:-1]:
            self._step_slot(slot, tok)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        self._slot_len = getattr(self, "_slot_len", [0] * self.ecfg.max_slots)
        done: list[Request] = []
        free = [s for s in range(self.ecfg.max_slots) if s not in self._active]
        steps = 0
        while (self._queue or self._active) and steps < max_steps:
            steps += 1
            while self._queue and free:
                slot = free.pop()
                req = self._queue.pop(0)
                self._active[slot] = req
                self._prefill_into_slot(slot, req)
            if not self._active:
                continue
            for slot, req in list(self._active.items()):
                last = (req.out_tokens or req.prompt)[-1]
                nxt = self._step_slot(slot, last)
                req.out_tokens.append(int(nxt))
                now = time.time()
                req.token_times.append(now)
                if req.first_token_at == 0.0:
                    req.first_token_at = now
                if (
                    len(req.out_tokens) >= req.max_new_tokens
                    or (self.ecfg.eos_token is not None and nxt == self.ecfg.eos_token)
                ):
                    req.done = True
                    req.finished_at = now
                    done.append(req)
                    del self._active[slot]
                    free.append(slot)
        return done

    def _step_slot(self, slot: int, token: int) -> int:
        """One decode step for one slot (per-slot cache view + write-back)."""
        sub_cache = jax.tree.map(
            lambda x: x[:, slot : slot + 1] if x.ndim >= 2 and x.shape[1] == self.ecfg.max_slots else x,
            self.cache,
        )
        sub_cache = sub_cache._replace(length=jnp.asarray(self._slot_len[slot], jnp.int32))
        tok = jnp.asarray([[token]], jnp.int32)
        logits, new_sub = self._decode(self.params, tok, sub_cache)

        def write_back(full, sub):
            if full.ndim >= 2 and full.shape[1] == self.ecfg.max_slots:
                return full.at[:, slot : slot + 1].set(sub)
            return full

        updated = jax.tree.map(write_back, self.cache, new_sub)
        self.cache = updated._replace(length=self.cache.length)
        self._slot_len[slot] += 1
        return int(jnp.argmax(logits[0, -1]))
