"""Batched serving engines: continuous batching over jitted programs.

Two batched engines share one scheduler skeleton (admit → grow → one jitted
decode per tick):

``ServingEngine`` (PR 1) — slot-padded: a fixed decode batch of ``max_slots``
sequences sharing one contiguous KV cache in which EVERY slot reserves
``max_len`` positions. Serving memory is governed by the longest possible
request, not the actual workload.

``PagedServingEngine`` — block-paged: KV lives in a fixed pool of
``num_blocks`` pages of ``block_size`` tokens (``models.transformer.
PagedKVCache``); a host-side :class:`BlockAllocator` hands pages to slots on
demand. Requests admit whenever free pages cover their prompt plus a decode
reservation (mid-stream admission — admission is re-tried every tick, not
between request groups), finished or evicted slots return pages immediately,
and when the pool runs dry a victim (longest-remaining or LRU) is evicted
back to the queue and later resumes by re-prefilling prompt + generated
tokens. Decode attention gathers pages through the block table (pure-JAX
gather, or the Pallas ``kernels/paged_attention.py`` kernel under
``kernel_impl='pallas'``); ``kv_dtype='int8'`` stores pages quantized via
``serving/kv_quant.py``.

Chunked prefill (``EngineConfig.prefill_chunk``, paged engine only): prompt
processing is split into block-aligned chunks interleaved with decode ticks.
Each tick advances every mid-prefill slot by at most ONE chunk (a single
jitted ``(S, chunk)`` program over the paged cache — the chunk's queries
attend previously written pages, its KV scatters in at the slot's current
length), so a long prompt no longer head-of-line-blocks active decoders, and
eviction-resume re-prefills its prompt + generated history chunk-by-chunk
instead of in one monolithic call. A slot whose next chunk cannot get pages
simply stalls and resumes from the last completed chunk once pages free up.
Greedy output is bitwise-identical to one-shot prefill
(tests/test_chunked_prefill.py).

Prefix caching (``EngineConfig.prefix_cache``, paged engine only): the
:class:`BlockAllocator` ref-counts pages and a radix index over token
prefixes (``serving/prefix_cache.py``) lets requests that share a prompt
prefix share the PHYSICAL pages holding it — admission attaches matching
full pages read-only and prefills only the unmatched suffix; the one
divergent-write case (a fully-cached prompt resuming inside its final hit
page) is privatized by a batched copy-on-write page copy
(``kernels/page_copy.py``) before any program runs. Retired and evicted
slots publish their pages back, so eviction-resume reattaches surviving
pages, and index-only pages form an LRU tail reclaimed under pressure before
any live slot is evicted. Streams are bitwise-identical to cache-off
(tests/test_prefix_cache.py).

Device programs (all shapes static, so serving never recompiles):
  * ``prefill[bucket]`` — batched prompt forward; KV rows (slot-padded) or
    whole prompt blocks (paged) and the first sampled token scatter into
    place inside the same jitted call
  * ``chunk`` — (params, tokens (S, chunk), counts (S,), slot_ids, cache,
    step) -> (first_tokens (S,), cache); at most ONE call per tick covering
    every mid-prefill slot (chunked mode replaces ``prefill`` entirely)
  * ``decode`` — (params, tokens (S, 1), cache, active (S,), step)
    -> (next_tokens (S,), cache); ONE call per engine tick

Weights may be a raw param tree (dense) or a ``DeployedModel`` serving
SLR (L + S) weights in factored / block-CSR form — the programs are format-
agnostic because every linear site goes through ``models.layers.apply_weight``.

``ReferenceEngine`` preserves the seed per-slot/per-token path: it is the
baseline that ``benchmarks/serve_throughput.py`` measures against, and the
fallback for cache families without per-slot lengths (ssm/hybrid/encdec).

Elastic tiers (``serving/elastic.py``): every engine is constructed from a
``ModelBank`` — the trained SLR weights held once, materialized as an ordered
set of budget tiers — instead of one fixed-budget parameter tree. A request
pins a tier at ``submit`` (or takes the engine default); each tick the engine
groups decode-phase slots by their *effective* tier and runs one jitted
decode per active tier over the SHARED cache (block table and pages are
tier-agnostic, so a slot can switch tiers mid-stream with no KV copy, and
each tier's program compiles exactly once, on first use). On the paged engine
``tier_policy='pressure'`` runs a :class:`~repro.serving.elastic.
TierController`: under page pressure the serving tier downshifts (cheaper
steps, sooner completions, sooner frees) BEFORE the engine resorts to
eviction, and upshifts when pressure clears.

Multi-tenant adapters (``serving/adapters.py``): constructing an engine from
an :class:`~repro.serving.adapters.AdapterBank` (with ``EngineConfig.
adapters=True``) serves N registered (L+S) adapters over one shared base.
Requests pick an adapter at ``submit``; admission pins it into the bank's
fixed-capacity device pool (LRU swap-in for non-resident adapters, counted
by ``serve_adapter_swaps_total``), and each tick either batches slots running
DIFFERENT adapters through one fused multi-adapter program (``batched`` mode,
fused format) or runs one program per distinct resident adapter (``grouped``
mode). Pool swaps and per-call ``sel`` binds are data-only, so adapter
switches never retrace; under the prefix cache each adapter gets its own
radix index (KV pages are adapter-specific) over the one shared allocator.
"""
from __future__ import annotations

import contextlib
import json
import logging
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib
from ..models import transformer as transformer_lib
from ..parallel.sharding import ServingMesh, parse_mesh_spec
from .adapters import AdapterBank, AdapterError
from .deployed import DeployedModel
from .elastic import ModelBank, TierController, TierControllerConfig
from .prefix_cache import PrefixCache
from .telemetry import EngineTelemetry, NullTelemetry
from .trace import RequestTracer

log = logging.getLogger(__name__)

# All INTERNAL timestamps (submitted_at, admitted_at, first_token_at,
# token_times, finished_at) use the monotonic clock: an NTP step during a run
# must never yield a negative TTFT/ITL. Only ``Request.deadline`` stays on the
# wall clock — it is an absolute SLO contract handed in by the caller.
_now = time.monotonic

BATCHED_FAMILIES = ("dense", "moe", "vlm")  # cache families with per-slot lengths

# float payload dtypes; "int8" is also accepted but only by the paged engine,
# which stores int8 payload pools + f32 scale pools (never a bare int8 cache)
_KV_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "bf16": jnp.bfloat16,
}
_EVICT_POLICIES = ("longest_remaining", "lru")


class RequestRejected(ValueError):
    """Raised by ``submit`` when a request can never be served by this engine
    (too long for the cache, or larger than the whole page pool). A graceful
    error path — the engine keeps serving everything already accepted."""


class EngineCapabilityError(RequestRejected):
    """A paged-only feature (quantized KV pages, speculative decoding) was
    requested on an engine/cache family that cannot provide it. Subclasses
    :class:`RequestRejected` so callers handle both through one error path."""


def _validate_request(prompt: list[int], max_new_tokens: int, max_len: int):
    if len(prompt) < 1:
        raise RequestRejected("empty prompt")
    if len(prompt) + max_new_tokens > max_len:
        raise RequestRejected(
            f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
            f"cache capacity {max_len}"
        )


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    # timestamps below are time.monotonic() values (see _now above) — compare
    # them to each other, never to the wall clock
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    first_token_at: float = 0.0      # TTFT = first_token_at - submitted_at
    finished_at: float = 0.0
    token_times: list[float] = field(default_factory=list)
    deadline: float | None = None    # absolute WALL-CLOCK SLO deadline
    tier: int = 0                    # requested ModelBank tier (0 = largest)
    adapter: int | None = None       # AdapterBank adapter id (multi-tenant
    #                                  serving; always None on plain banks)
    evictions: int = 0
    requeued_at: float = 0.0         # last eviction's re-queue stamp — the
    #                                  basis for a RE-admission's queue wait
    # tokens this request emitted from a PREFILL/CHUNK program (one per
    # admission that reached the end of its prompt; a mid-prefill eviction
    # emits nothing, so this is NOT simply 1 + evictions)
    prefill_emitted: int = 0


@dataclass
class EngineConfig:
    max_slots: int = 4        # concurrent sequences (decode batch)
    max_len: int = 256        # max prompt+generation length per request
    greedy: bool = True
    temperature: float = 1.0  # used when greedy=False (on-device sampling)
    eos_token: int | None = None
    seed: int = 0
    min_bucket: int = 8       # smallest prefill length bucket
    # paged engine only:
    block_size: int = 16      # tokens per KV page
    num_blocks: int | None = None   # page pool size; None = max_slots * max_len worth
    kv_dtype: str = "float32"       # float32 | bfloat16 | int8 (paged pages quantized)
    evict_policy: str = "longest_remaining"  # or "lru"
    decode_reserve: int | None = None  # decode headroom (tokens) required to admit;
    #                                    None = one block
    prefill_chunk: int | None = None   # paged engine only: split prompt
    #                                    processing into block-aligned chunks of
    #                                    this many tokens, interleaved with
    #                                    decode ticks (None = one-shot prefill;
    #                                    must be a positive multiple of
    #                                    block_size)
    # prefix cache (paged engine only; serving/prefix_cache.py):
    prefix_cache: bool = False      # radix prompt index over ref-counted KV
    #                                 pages: admissions attach matching full
    #                                 pages read-only and prefill only the
    #                                 unmatched suffix; retired slots publish
    #                                 their pages back
    prefix_min_hit_pages: int = 1   # smallest radix match worth attaching
    #                                 (shorter hits prefill from scratch)
    # elastic tiers (serving/elastic.py):
    default_tier: int = 0           # bank tier used when submit(tier=None)
    tier_policy: str = "static"     # static | pressure (paged engine only:
    #                                 downshift the serving tier under page
    #                                 pressure before resorting to eviction)
    tier_target_free: float = 0.25  # pressure setpoint: free-page fraction
    tier_gain: float = 4.0          # controller integral gain (tiers/error)
    tier_ema: float = 0.5           # smoothing of the free-fraction signal
    # speculative engine only (serving/speculative.py):
    spec_k: int = 0                 # draft tokens per tick; 0 = speculation off
    spec_adaptive: bool = False     # adapt k from observed acceptance rate
    spec_draft_mode: str = "auto"   # auto | parallel (greedy lookahead draft)
    #                                 | sequential (autoregressive proposals)
    spec_draft_kv_dtype: str = "bfloat16"  # draft page-pool payload (its own,
    #                                        smaller pool; never affects the
    #                                        target distribution)
    spec_target_tier: int = 0       # bank tier the verifier serves
    spec_draft_tier: int = -1       # bank tier that drafts (-1 = cheapest)
    # observability (serving/telemetry.py, serving/trace.py) — host-side
    # only, never touches the device path:
    telemetry: bool = True          # metrics registry + per-program timing /
    #                                 retrace detection; False = every hook is
    #                                 a no-op (NullTelemetry)
    trace: bool = False             # per-request span tracer (Chrome-trace /
    #                                 JSONL export via engine.tracer)
    # tensor-parallel serving (parallel/sharding.ServingMesh): a mesh spec
    # string like "model=2,data=1". Kept as a STRING so EngineConfig stays
    # dataclasses.asdict / JSON-safe (engine_provenance) and never touches
    # jax device state at construction; the engine builds the ServingMesh.
    mesh: str | None = None
    # multi-tenant adapter serving (serving/adapters.py): True means the
    # engine's bank IS an AdapterBank (and vice versa — the flag keeps
    # multi-tenancy explicit in config / provenance dumps, never inferred)
    adapters: bool = False
    max_resident_adapters: int | None = None  # device adapter-pool rows;
    #                                           None = all registered resident

    def __post_init__(self):
        """Validate at CONSTRUCTION: a bad config used to surface as a
        downstream shape/jit failure deep inside the first prefill (or worse,
        as a silently-degenerate pool). Every check here raises a ValueError
        that names the field and the constraint."""
        for name in ("max_slots", "max_len", "block_size", "min_bucket"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name}={v!r} must be a positive int")
        if self.num_blocks is not None and self.num_blocks < 1:
            raise ValueError(
                f"num_blocks={self.num_blocks} must be positive (or None for "
                f"a max_slots * max_len worth of pages)"
            )
        if self.kv_dtype not in _KV_DTYPES and self.kv_dtype != "int8":
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r}; expected one of "
                f"{sorted(_KV_DTYPES) + ['int8']}"
            )
        if self.evict_policy not in _EVICT_POLICIES:
            raise ValueError(
                f"unknown evict_policy {self.evict_policy!r}; "
                f"expected one of {_EVICT_POLICIES}"
            )
        if self.decode_reserve is not None and self.decode_reserve < 1:
            raise ValueError(
                f"decode_reserve={self.decode_reserve} must be positive "
                f"(or None for one block)"
            )
        if self.prefill_chunk is not None and (
            self.prefill_chunk < 1 or self.prefill_chunk % self.block_size
        ):
            raise ValueError(
                f"prefill_chunk={self.prefill_chunk} must be a positive "
                f"multiple of block_size={self.block_size} (chunks scatter "
                f"whole pages)"
            )
        if not isinstance(self.prefix_min_hit_pages, int) \
                or self.prefix_min_hit_pages < 1:
            raise ValueError(
                f"prefix_min_hit_pages={self.prefix_min_hit_pages!r} must be "
                "a positive int (a zero-page hit is not a hit)"
            )
        if self.tier_policy not in ("static", "pressure"):
            raise ValueError(
                f"unknown tier_policy {self.tier_policy!r}; "
                f"expected 'static' or 'pressure'"
            )
        if not 0.0 < self.tier_target_free < 1.0:
            raise ValueError(
                f"tier_target_free={self.tier_target_free} outside (0, 1)"
            )
        if self.tier_gain <= 0:
            raise ValueError(f"tier_gain={self.tier_gain} must be positive")
        if not 0.0 <= self.tier_ema < 1.0:
            raise ValueError(f"tier_ema={self.tier_ema} outside [0, 1)")
        if self.spec_k < 0:
            raise ValueError(f"spec_k={self.spec_k} must be >= 0")
        if self.spec_draft_mode not in ("auto", "parallel", "sequential"):
            raise ValueError(
                f"unknown spec_draft_mode {self.spec_draft_mode!r}; "
                f"expected auto | parallel | sequential"
            )
        if (self.spec_draft_kv_dtype not in _KV_DTYPES
                and self.spec_draft_kv_dtype != "int8"):
            raise ValueError(
                f"unknown spec_draft_kv_dtype {self.spec_draft_kv_dtype!r}; "
                f"expected one of {sorted(_KV_DTYPES) + ['int8']}"
            )
        if self.mesh is not None:
            if not isinstance(self.mesh, str):
                raise ValueError(
                    f"mesh={self.mesh!r} must be a spec string like "
                    f"'model=2,data=1' (or None for single-device)"
                )
            # format-only validation (raises field-naming ValueErrors);
            # device-count and head-divisibility checks need the arch + real
            # devices and happen in the engine constructor
            parse_mesh_spec(self.mesh)
        if self.max_resident_adapters is not None:
            if not isinstance(self.max_resident_adapters, int) \
                    or self.max_resident_adapters < 1:
                raise ValueError(
                    f"max_resident_adapters={self.max_resident_adapters!r} "
                    "must be a positive int (or None for every registered "
                    "adapter resident)"
                )
            if not self.adapters:
                raise ValueError(
                    "max_resident_adapters sizes the AdapterBank device pool "
                    "and needs adapters=True"
                )


def decode_emitted_tokens(done: list[Request]) -> int:
    """Tokens these requests emitted from DECODE steps: every (re-)admission
    that completes its prefill emits one token from the prefill/chunk program,
    the rest amortize over decode calls. Counted via ``Request.
    prefill_emitted`` rather than ``1 + evictions`` because an eviction that
    lands MID-PREFILL emits nothing for that admission (chunked prefill made
    that state reachable). The convention lives here so benchmark/launcher
    metrics (tokens-per-step) cannot drift from the engines that define it."""
    return sum(len(r.out_tokens) - r.prefill_emitted for r in done)


def _resolve_engine_args(name: str, model, params=None, ecfg=None):
    """Resolve the Engine-protocol constructor contract.

    ``Engine(bank, ecfg)`` where ``bank`` is a :class:`~repro.serving.
    elastic.ModelBank` — including a multi-tenant :class:`~repro.serving.
    adapters.AdapterBank` — or a bare ``DeployedModel`` (accepted as a
    single-tier convenience). The pre-elastic ``Engine(arch_cfg, params,
    ecfg)`` form was removed after its deprecation cycle and now raises a
    ``TypeError`` naming the replacement.
    """
    if isinstance(model, (ModelBank, DeployedModel)):
        if params is not None and ecfg is not None:
            raise TypeError(
                f"{name}(bank, ecfg) takes no third argument; per-tier "
                "weights live in the ModelBank"
            )
        cfg_arg = params if params is not None else ecfg  # positional OR ecfg=
        if cfg_arg is not None and not isinstance(cfg_arg, EngineConfig):
            raise TypeError(
                f"{name}(bank, ecfg): second argument must be an "
                f"EngineConfig, got {type(cfg_arg).__name__}"
            )
        bank = model if isinstance(model, ModelBank) \
            else ModelBank.single(model.cfg, model)
        return bank, cfg_arg if cfg_arg is not None else EngineConfig()
    if not hasattr(model, "family"):
        raise TypeError(
            f"{name} expects a ModelBank (serving.elastic) or DeployedModel "
            f"first argument, got {type(model).__name__}"
        )
    raise TypeError(
        f"{name}(arch_cfg, params, ecfg) was removed: build a ModelBank "
        f"(serving/elastic.py) — or a serving.adapters.AdapterBank for "
        f"multi-tenant serving — and construct {name}(bank, ecfg); "
        f"ModelBank.single(arch_cfg, params) wraps one weight tree"
    )


def _bank_tier_state(bank: ModelBank, ecfg: EngineConfig):
    """Per-tier parameter list + validated default tier, shared by every
    engine's constructor (keeps the error contract in one place)."""
    tier_params = [t.params for t in bank]
    try:
        default = bank.resolve(ecfg.default_tier)
    except ValueError as e:
        raise ValueError(f"default_tier: {e}") from None
    return tier_params, default


def _resolve_request_tier(bank: ModelBank, default: int,
                          tier: int | None) -> int:
    """Validated bank tier for a request (None = the engine default).
    Submit-time tier errors are RequestRejected, per the Engine protocol."""
    if tier is None:
        return default
    try:
        return bank.resolve(tier)
    except ValueError as e:
        raise RequestRejected(str(e)) from None


def _capability_error(engine_cls, family: str, missing: list[str]):
    """An :class:`EngineCapabilityError` that carries the engine's structured
    ``capabilities()`` dict, so callers (and ``launch/serve.py`` users) see
    WHICH features are paged-only instead of a bare string."""
    caps = engine_cls.capabilities()
    return EngineCapabilityError(
        f"family {family!r} serves through {engine_cls.__name__}; requested "
        f"feature(s) unavailable: {', '.join(missing)}. Engine capabilities: "
        f"{json.dumps(caps, sort_keys=True)}"
    )


def _resolve_serving_mesh(ecfg: EngineConfig, arch_cfg, bank: ModelBank):
    """Build + validate the ServingMesh for ``ecfg.mesh`` (None = unsharded).

    Every check raises a ValueError naming the field and the constraint:
    the 'model' axis must divide both head counts (the KV pools and the
    shard_map'd paged kernels split the head axis), and the Pallas BSR /
    fused formats are rejected — their block-CSR tables are addressed by a
    global block grid that the scalar-prefetched DMA index maps walk, which
    no axis partition can split.
    """
    if ecfg.mesh is None:
        return None
    smesh = ServingMesh.from_spec(ecfg.mesh)
    m = smesh.model_size
    if m > 1:
        heads = arch_cfg.num_heads
        kv_heads = arch_cfg.num_kv_heads or heads
        for fname, h in (("num_heads", heads), ("num_kv_heads", kv_heads)):
            if not h or h % m:
                raise ValueError(
                    f"mesh={ecfg.mesh!r}: model axis size {m} must divide "
                    f"{fname}={h} (KV pools and paged attention shard the "
                    f"head axis)"
                )
        for tier in bank:
            fmt = getattr(tier.model, "fmt", "dense")
            if fmt in ("bsr", "fused"):
                raise ValueError(
                    f"mesh={ecfg.mesh!r}: deployment format {fmt!r} cannot "
                    f"shard over the model axis (its BSR block grid is "
                    f"indexed globally by the Pallas DMA index maps); serve "
                    f"'dense' or 'factored' tiers under a mesh"
                )
    return smesh


def _device_put_tiers(tier_params: list, smesh: ServingMesh) -> list:
    """Materialize every bank tier against ONE sharded base.

    Leaves shared across tiers by object identity (the bank's shared dense
    base: embeddings, norms, unselected matrices) are device_put ONCE and the
    same placed array is re-used in every tier's tree — so elastic banks keep
    one physical copy per device and ``ModelBank.shared_base_bytes`` (an
    ``id()`` intersection) still reports the sharing.
    """
    placed: dict[int, jax.Array] = {}
    out = []
    for tree in tier_params:
        shardings = smesh.params_shardings(tree)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        assert len(leaves) == len(shard_leaves)
        new = []
        for leaf, sh in zip(leaves, shard_leaves):
            key = id(leaf)
            if key not in placed:
                placed[key] = jax.device_put(leaf, sh)
            new.append(placed[key])
        out.append(jax.tree_util.tree_unflatten(treedef, new))
    return out


def _kv_pool_device_bytes(cache) -> dict[str, int]:
    """Per-device KV payload bytes, from the placed pools' actual shards —
    the number behind the ``serve_kv_pool_device_bytes`` gauge and
    BENCH_shard.json's 1/N-scaling check."""
    per_dev: dict[str, int] = {}
    for leaf in jax.tree_util.tree_leaves(cache):
        if getattr(leaf, "ndim", 0) != 5:  # payload/scale pools only
            continue
        for shard in leaf.addressable_shards:
            key = str(shard.device)
            per_dev[key] = per_dev.get(key, 0) + int(np.prod(shard.data.shape)) \
                * leaf.dtype.itemsize
    return per_dev


class ServingEngine:
    """Single-host batched slot-padded engine; the multi-pod path swaps the
    jitted fns for their pjit'd versions (same signatures — launch/serve.py)."""

    _speculative = False   # only serving.speculative.SpeculativeEngine drafts
    _chunked = False       # only PagedServingEngine prefills chunk-by-chunk
    _paged = False         # only PagedServingEngine has a page pool (the
    #                        pressure tier policy needs one)

    def __init__(self, model, params=None, ecfg: EngineConfig | None = None):
        bank, ecfg = _resolve_engine_args(type(self).__name__, model, params,
                                          ecfg)
        self._init_common(bank, ecfg)
        if ecfg.kv_dtype == "int8":
            raise ValueError(
                "int8 KV needs the paged engine (PagedServingEngine stores "
                "quantized pages); the contiguous engine serves float caches"
            )
        cache = model_lib.init_cache(
            self.cfg, ecfg.max_slots, ecfg.max_len,
            dtype=_KV_DTYPES[ecfg.kv_dtype],
        )
        self.cache = cache._replace(
            length=jnp.zeros((ecfg.max_slots,), jnp.int32)
        )
        self._place_cache()
        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(4,))

    @classmethod
    def capabilities(cls) -> dict:
        """Structured capability report (Engine protocol): which cache
        families this engine serves, its KV layout, and feature availability
        — the data behind ``EngineCapabilityError`` messages and the
        ``launch/serve.py --help`` table."""
        return {
            "engine": cls.__name__,
            "families": list(BATCHED_FAMILIES),
            "kv": "contiguous",
            "features": {
                "kv_dtype": ["float32", "bfloat16"],
                "continuous_batching": True,
                "deadlines_edf": True,
                "chunked_prefill": False,
                "eviction_resume": False,
                "speculative": False,
                "elastic_tiers": True,
                "tier_pressure_controller": False,
                "prefix_caching": False,
                "tensor_parallel": True,
                "multi_tenant_adapters": True,
            },
        }

    def _init_common(self, bank: ModelBank, ecfg: EngineConfig):
        arch_cfg = bank.cfg
        if arch_cfg.family not in BATCHED_FAMILIES:
            raise ValueError(
                f"batched engine needs a KV-cache family, got {arch_cfg.family!r};"
                " use ReferenceEngine for ssm/hybrid/encdec"
            )
        if ecfg.spec_k and not self._speculative:
            # never silently drop a requested feature: spec_k is only
            # consumed by serving.speculative.SpeculativeEngine
            raise EngineCapabilityError(
                f"{type(self).__name__} does not speculate "
                f"(spec_k={ecfg.spec_k} requested); use SpeculativeEngine. "
                f"Engine capabilities: "
                f"{json.dumps(self.capabilities(), sort_keys=True)}"
            )
        if ecfg.prefill_chunk is not None and not self._chunked:
            raise EngineCapabilityError(
                f"{type(self).__name__} prefills in one shot "
                f"(prefill_chunk={ecfg.prefill_chunk} requested); chunked "
                "prefill needs the paged engine. Engine capabilities: "
                f"{json.dumps(self.capabilities(), sort_keys=True)}"
            )
        if ecfg.prefix_cache and not self._paged:
            raise EngineCapabilityError(
                f"{type(self).__name__} has no page pool to share "
                "(prefix_cache=True requested); the radix prompt cache needs "
                "the paged engine. Engine capabilities: "
                f"{json.dumps(self.capabilities(), sort_keys=True)}"
            )
        if ecfg.tier_policy == "pressure" and not self._paged:
            raise EngineCapabilityError(
                f"{type(self).__name__} has no page pool to feel pressure "
                "from (tier_policy='pressure' requested); the tier "
                "controller needs the paged engine. Engine capabilities: "
                f"{json.dumps(self.capabilities(), sort_keys=True)}"
            )
        # multi-tenant adapters: the bank type and the config flag must agree
        # — neither a silently-ignored AdapterBank nor a flag with no pool
        self._adapters: AdapterBank | None = \
            bank if isinstance(bank, AdapterBank) else None
        if ecfg.adapters != (self._adapters is not None):
            raise ValueError(
                "adapters=True needs an AdapterBank (serving.adapters) as the "
                "engine's bank, and an AdapterBank needs adapters=True — got "
                f"adapters={ecfg.adapters} with {type(bank).__name__}"
            )
        if self._adapters is not None:
            if ecfg.mesh is not None:
                raise ValueError(
                    f"mesh={ecfg.mesh!r} + adapters is unsupported: the "
                    "pooled adapter tables are indexed by scalar-prefetched "
                    "DMA maps no axis partition can split; serve adapters "
                    "unsharded"
                )
            if self._speculative:
                raise EngineCapabilityError(
                    "SpeculativeEngine does not serve AdapterBanks (draft + "
                    "verify would each need a pool); use PagedServingEngine"
                )
            self._adapters.materialize(ecfg.max_resident_adapters)
        self.cfg = arch_cfg
        self.ecfg = ecfg
        self.bank = bank
        self._tier_params, self._default_tier = _bank_tier_state(bank, ecfg)
        # tensor parallelism: resolve the mesh spec, then materialize ALL
        # tiers against one sharded base (shared leaves placed once) so
        # elastic / speculative / prefix-cached serving inherit TP for free
        self.mesh = _resolve_serving_mesh(ecfg, arch_cfg, bank)
        if self.mesh is not None:
            self._tier_params = _device_put_tiers(self._tier_params, self.mesh)
        # back-compat alias: the default tier's tree (the speculative engine
        # re-points it at the verify target's tier)
        self.params = self._tier_params[self._default_tier]
        # effective tier per slot (requested tier + controller downshift),
        # refreshed every tick; decode groups by this
        self._slot_tier = np.zeros(ecfg.max_slots, np.int64)
        # multi-tenant: adapter-pool row per slot (batched decode binds this
        # map verbatim) + the adapter id each slot pinned (unpinned when the
        # slot releases, so LRU never swaps out a streaming adapter)
        self._slot_pool = np.zeros(ecfg.max_slots, np.int32)
        self._slot_adapter: dict[int, int] = {}
        self._tier_shift = 0
        self.tier_controller: TierController | None = None
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}   # slot -> request
        # slot -> tokens prefilled so far; a slot present here is MID-PREFILL
        # (chunked paged engine only — always empty on the other engines) and
        # does not participate in decode ticks
        self._progress: dict[int, int] = {}
        self._uid = 0
        self._steps = 0
        self._last_token = np.zeros(ecfg.max_slots, np.int64)
        self._base_key = jax.random.PRNGKey(ecfg.seed)

        # instrumentation: device calls vs (re)traces — tests assert the
        # decode loop is one device call per step and compiles exactly once.
        # The ``*_calls``/``*_traces`` pairs stay PLAIN INTS on purpose: the
        # trace counters increment as a python side effect INSIDE traced
        # functions (so they count traces only), which a registry-backed
        # property could not express; the retrace detector reads their deltas
        self.decode_calls = 0
        self.decode_traces = 0
        self.prefill_calls = 0
        self.prefill_traces = 0

        # observability: the unified metrics registry (+ optional tracer).
        # The legacy counter attributes (evictions, tier_switches,
        # downshift_ticks, prefix_*, cow_copies, ...) are now read-only
        # properties over this registry — one metrics substrate everywhere
        self.metrics = (EngineTelemetry if ecfg.telemetry
                        else NullTelemetry)(type(self).__name__)
        if self._adapters is not None:
            self.metrics.set_resident_adapters(len(self._adapters.resident))
        self.tracer: RequestTracer | None = None
        if ecfg.trace:
            self.start_trace()

    # ----------------------------------------------------- observability ---

    def start_trace(self, tracer: RequestTracer | None = None) -> RequestTracer:
        """Attach a per-request span tracer (serving/trace.py); subsequent
        activity records slot-track spans and program-track slices. Returns
        the tracer (``tracer.save_chrome(path)`` / ``save_jsonl(path)``)."""
        self.tracer = tracer if tracer is not None \
            else RequestTracer(type(self).__name__)
        self.metrics.tracer = self.tracer
        return self.tracer

    # Migrated ad-hoc counters: read-only views over the metrics registry
    # (the registry is the single writer — see the hooks at the old
    # increment sites). With telemetry=False these all read 0.

    @property
    def evictions(self) -> int:
        return int(self.metrics.counter_value(self.metrics.evictions))

    @property
    def tier_switches(self) -> int:
        return int(self.metrics.counter_value(self.metrics.tier_switches))

    @property
    def downshift_ticks(self) -> int:
        return int(self.metrics.counter_value(self.metrics.downshift_ticks))

    def stats_snapshot(self) -> dict:
        """Host-side serving stats: scheduler/jit counters plus the full
        metrics-registry snapshot. ``launch/serve.py`` derives its summary
        from this, and the Prometheus exporter serves the same registry."""
        return {
            "engine": type(self).__name__,
            "steps": self._steps,
            "decode_calls": self.decode_calls,
            "decode_traces": self.decode_traces,
            "prefill_calls": self.prefill_calls,
            "prefill_traces": self.prefill_traces,
            "jit_retraces": self.metrics.retraces(),
            "metrics": self.metrics.snapshot(),
        }

    # ------------------------------------------------------------ intake ---

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               deadline: float | None = None,
               tier: int | None = None,
               submitted_at: float | None = None,
               adapter: int | None = None) -> int:
        """Enqueue a request. ``submitted_at`` (monotonic clock) lets open-
        loop harnesses backdate the submission to the SCHEDULED arrival, so
        TTFT/queue-wait metrics share one basis however the driver batches
        its submits; None = now. ``adapter`` picks a registered AdapterBank
        adapter (None = the bank's default; rejected on plain-bank engines)."""
        try:
            self._validate(prompt, max_new_tokens)
            tier_r = self._resolve_tier(tier)
            adapter_r = self._resolve_adapter(adapter)
        except RequestRejected:
            self.metrics.on_reject()
            raise
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens,
                    submitted_at=_now() if submitted_at is None
                    else submitted_at,
                    deadline=deadline, tier=tier_r, adapter=adapter_r)
        )
        self.metrics.on_submit()
        return self._uid

    def _validate(self, prompt: list[int], max_new_tokens: int):
        _validate_request(prompt, max_new_tokens, self.ecfg.max_len)

    def _resolve_tier(self, tier: int | None) -> int:
        return _resolve_request_tier(self.bank, self._default_tier, tier)

    def _resolve_adapter(self, adapter: int | None) -> int | None:
        """Validated adapter id (None = the bank's default). Submit-time
        errors are ``RequestRejected``, like tiers; an adapter unregistered
        AFTER submit is caught at admission instead (the request finishes
        rejected — the graceful error path)."""
        if self._adapters is None:
            if adapter is not None:
                raise RequestRejected(
                    f"adapter={adapter} needs an AdapterBank engine "
                    "(serving.adapters); this engine serves a plain ModelBank"
                )
            return None
        aid = self._adapters.default_adapter if adapter is None else adapter
        if aid not in self._adapters.registry:
            raise RequestRejected(f"unknown adapter id {aid}")
        return aid

    # ------------------------------------------------------------- tiers ---

    def _effective_tier(self, req: Request) -> int:
        """Requested tier plus the controller's downshift, clamped to the
        cheap end of the bank (downshift only ever moves toward smaller
        capacities; it never upgrades a request past what it asked for)."""
        return min(req.tier + self._tier_shift, len(self._tier_params) - 1)

    def _update_tier_shift(self):
        """Hook: the paged engine integrates page pressure here."""

    def _refresh_slot_tiers(self):
        """Recompute each active slot's effective tier. A change is pure
        host-side bookkeeping — the KV cache is tier-agnostic (no copy) and
        every tier's program is already compiled after its first use, so a
        mid-stream switch costs nothing on device."""
        for slot, req in self._active.items():
            eff = self._effective_tier(req)
            if eff != self._slot_tier[slot]:
                self.metrics.inc(self.metrics.tier_switches)
                if self.tracer is not None:
                    self.tracer.instant(slot, "tier_switch", uid=req.uid,
                                        frm=int(self._slot_tier[slot]), to=eff)
                self._slot_tier[slot] = eff

    def _tier_groups(self, slots) -> list[tuple]:
        """Active slots grouped by program key (ascending): the effective
        tier, widened — grouped adapter mode only — to ``(tier, pool row)``
        so every call serves ONE adapter through the single-tenant ops.
        Batched adapter mode keeps the plain tier key: one multi-adapter
        program covers mixed-adapter slots."""
        grouped = self._adapters is not None \
            and self._adapters.mode == "grouped"
        groups: dict = {}
        for s in slots:
            key = (int(self._slot_tier[s]), int(self._slot_pool[s])) \
                if grouped else int(self._slot_tier[s])
            groups.setdefault(key, []).append(s)
        return sorted(groups.items())

    # ---------------------------------------------------------- adapters ---

    def _adapter_admit(self, req: Request, done: list[Request]):
        """Acquire + pin ``req``'s adapter into the device pool. Returns
        ``("ok", row)`` (``row`` is None on plain banks); ``("busy", None)``
        when every pool row is pinned by a streaming slot — keep the request
        queued and retry next tick; ``("gone", None)`` when the adapter was
        unregistered after submit — the request finishes rejected."""
        if self._adapters is None:
            return "ok", None
        try:
            row, swapped = self._adapters.acquire(req.adapter)
        except AdapterError:
            req.done = True
            req.finished_at = _now()
            self.metrics.on_reject()
            done.append(req)
            return "gone", None
        if row is None:
            return "busy", None
        if swapped:
            self.metrics.inc(self.metrics.adapter_swaps)
        self._adapters.pin(req.adapter)
        self.metrics.set_resident_adapters(len(self._adapters.resident))
        return "ok", row

    def _call_params(self, key, rows=None):
        """The parameter tree for ONE program call: the tier's tree on plain
        banks; on AdapterBanks a fresh ``bind`` of the live pool — a grouped
        key carries its pool row (one adapter per call, scalar sel), batched
        calls bind the ``rows`` map (slot- or group-indexed, matching the
        program's row convention). Binds are data-only: same treedef and
        shapes every call, so programs never retrace across adapters."""
        if self._adapters is None:
            return self._tier_params[key]
        if isinstance(key, tuple):            # grouped: (tier, pool row)
            return self._adapters.bind(key[1])
        return self._adapters.bind(np.asarray(rows, np.int32))

    def _order_queue(self):
        """Earliest-deadline-first admission order, shared by BOTH batched
        engines (the slot-padded engine used to pop FIFO and ignore
        deadlines). Tiebreaks are stable: among equal deadlines (or no
        deadlines at all) evicted/resumed requests go first — they already
        spent pool time, and finishing them releases memory soonest — then
        FIFO by uid. EDF stays primary: an evicted request with a LATER
        deadline does not jump an urgent fresh one (the old paged queue-head
        insert did exactly that, and the pre-admit re-sort then dropped it)."""
        self._queue.sort(
            key=lambda r: (
                r.deadline is None, r.deadline or 0.0, -r.evictions, r.uid
            )
        )

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self._active)

    @property
    def queued(self) -> int:
        return len(self._queue)

    # ----------------------------------------------------- device programs ---

    def _sample(self, logits: jax.Array, step: jax.Array, salt: int,
                slots: jax.Array | None = None) -> jax.Array:
        """Greedy or temperature sampling, on device. logits: (S, vocab).

        ``salt`` separates the prefill / decode / draft / verify streams — all
        can sample within the same engine tick and must not share gumbel
        noise. Each row additionally folds its slot id (``slots``; default row
        index) into the key, so slots carry independent streams: eviction /
        re-prefill resume and draft-vs-verify sampling never correlate across
        slots. The greedy path is untouched.
        """
        if self.ecfg.greedy or self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.fold_in(self._base_key, step), salt)
        if slots is None:
            slots = jnp.arange(logits.shape[0])
        keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(slots)
        g = jax.vmap(lambda k: jax.random.gumbel(k, logits.shape[-1:]))(keys)
        return jnp.argmax(logits / self.ecfg.temperature + g, axis=-1).astype(jnp.int32)

    def _decode_fn(self, params, tokens, cache, active, step):
        self.decode_traces += 1  # python side effect: counts traces only
        logits, new_cache = model_lib.decode_step(params, tokens, cache, self.cfg)
        # only active slots advance their valid prefix; inactive slots wrote a
        # junk row at their frozen position — the next real token overwrites it
        # (paged: inactive slots map to unmapped pages, the write dropped)
        new_len = jnp.where(active, new_cache.length, cache.length)
        next_tok = self._sample(logits[:, -1], step, salt=0)
        return next_tok, new_cache._replace(length=new_len)

    def _prefill_fn(self, params, tokens, lengths, slot_ids, cache, step):
        self.prefill_traces += 1
        logits, pcache = model_lib.prefill(
            params, {"tokens": tokens}, self.cfg, max_len=self.ecfg.max_len,
            cache_dtype=cache.k.dtype,
        )
        # scatter the prefilled KV rows / lengths into the target slots;
        # padded rows carry slot_id == max_slots and drop out of bounds
        k = cache.k.at[:, slot_ids].set(pcache.k, mode="drop")
        v = cache.v.at[:, slot_ids].set(pcache.v, mode="drop")
        new_len = cache.length.at[slot_ids].set(lengths, mode="drop")
        # the logits at the last prompt position yield the first generated token
        last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)
        first_tok = self._sample(last[:, 0], step, salt=1, slots=slot_ids)
        return first_tok, cache._replace(k=k, v=v, length=new_len)

    # ------------------------------------------------------------- steps ---

    def _bucket(self, n: int) -> int:
        b = self.ecfg.min_bucket
        while b < n:
            b *= 2
        return min(b, self.ecfg.max_len)

    def _admit(self, free: list[int], done: list[Request], step: int):
        """Batch all admissible queued requests through one prefill call PER
        EFFECTIVE TIER (earliest deadline first — see ``_order_queue``; a
        single-tier bank degenerates to exactly the old one-call admit)."""
        take = min(len(free), len(self._queue))
        if not take:
            return
        self._order_queue()
        reqs = [self._queue.pop(0) for _ in range(take)]
        s = self.ecfg.max_slots
        now = _now()
        admitted: list[tuple[int, Request]] = []
        requeue: list[Request] = []
        for req in reqs:
            astat, arow = self._adapter_admit(req, done)
            if astat == "gone":
                continue
            if astat == "busy":     # every pool row pinned: retry next tick
                requeue.append(req)
                continue
            slot = free.pop()
            self.metrics.on_admit(req, slot, now,
                                  prefill_tokens=len(req.prompt))
            req.admitted_at = now
            self._active[slot] = req
            self._slot_tier[slot] = self._effective_tier(req)
            if arow is not None:
                self._slot_pool[slot] = arow
                self._slot_adapter[slot] = req.adapter
            if self.tracer is not None:
                self.tracer.request_begin(slot, req.uid, t=now, tier=req.tier)
                self.tracer.begin_span(slot, "prefill", t=now,
                                       tokens=len(req.prompt))
            admitted.append((slot, req))
        self._queue[:0] = requeue
        for key, slots in self._tier_groups(slot for slot, _ in admitted):
            group = [(slot, self._active[slot]) for slot in slots]
            bucket = self._bucket(max(len(r.prompt) for _, r in group))
            tokens = np.zeros((s, bucket), np.int32)
            lengths = np.ones((s,), np.int32)     # padded rows: 1 valid token
            slot_ids = np.full((s,), s, np.int32)  # out-of-range => dropped
            rows = np.zeros((s,), np.int32)        # GROUP-indexed pool rows
            for i, (slot, req) in enumerate(group):
                tokens[i, : len(req.prompt)] = req.prompt
                lengths[i] = len(req.prompt)
                slot_ids[i] = slot
                rows[i] = self._slot_pool[slot]
            with self.metrics.measure_program(
                f"prefill[{bucket}]", key, traces=lambda: self.prefill_traces
            ):
                first, self.cache = self._prefill(
                    self._call_params(key, rows), jnp.asarray(tokens),
                    jnp.asarray(lengths), jnp.asarray(slot_ids), self.cache,
                    jnp.asarray(step, jnp.int32),
                )
                self.prefill_calls += 1
                firsts = np.asarray(first)       # one fetch per tier group
            for i, (slot, req) in enumerate(group):
                req.prefill_emitted += 1
                self._record(slot, req, int(firsts[i]), free, done)

    def _record(self, slot: int, req: Request, tok: int, free, done):
        now = _now()
        req.out_tokens.append(tok)
        req.token_times.append(now)
        first = req.first_token_at == 0.0
        if first:
            req.first_token_at = now
        # the ONE emission point: serve_tokens_total{kind="emitted"} counts
        # each generated token exactly once, however many times eviction
        # re-prefills its context (re-work lands in kind="prefill_compute")
        self.metrics.on_token(req, now, first)
        if self._adapters is not None:
            self.metrics.inc(self.metrics.adapter_tokens, 1, str(req.adapter))
        tr = self.tracer
        if tr is not None and tr.has_open(slot, "prefill"):
            # prefill (or resume re-prefill) just yielded its token: close
            # the prefill span and open the decode envelope
            tr.end_span(slot, "prefill", t=now)
            if first:
                tr.instant(slot, "first_token", t=now, uid=req.uid)
            tr.begin_span(slot, "decode", t=now, uid=req.uid)
        self._last_token[slot] = tok
        if len(req.out_tokens) >= req.max_new_tokens or (
            self.ecfg.eos_token is not None and tok == self.ecfg.eos_token
        ):
            req.done = True
            req.finished_at = now
            self.metrics.on_finish()
            if tr is not None:
                if tr.has_open(slot, "decode"):
                    tr.end_span(slot, "decode", t=now)
                tr.request_end(slot, req.uid, t=now,
                               tokens=len(req.out_tokens),
                               evictions=req.evictions)
            done.append(req)
            self._retire(slot, req)
            del self._active[slot]
            free.append(slot)
            self._release(slot)

    def _retire(self, slot: int, req: Request):
        """Hook: the prefix-caching paged engine publishes the slot's full
        pages into the radix index here (finish AND eviction), before
        ``_release`` returns whatever it kept to the pool."""

    def _release(self, slot: int):
        """Hook extended by the paged engine (it returns the slot's pages);
        the base unpins the slot's adapter so LRU residency can swap it out
        once no slot streams with it."""
        if self._adapters is not None:
            aid = self._slot_adapter.pop(slot, None)
            if aid is not None:
                self._adapters.unpin(aid)

    def _pre_decode(self, free: list[int], done: list[Request]):
        """Hook: the paged engine grows page allocations / evicts here."""

    def _prefill_progress(self, free: list[int], done: list[Request],
                          step: int):
        """Hook: the chunked paged engine advances mid-prefill slots by one
        chunk here (at most one jitted chunk program per tick)."""

    def _device_cache(self):
        """Hook: the paged engine pushes host block-table updates here."""
        return self.cache

    def _place_cache(self):
        """Shard the KV cache over the mesh (head axis over 'model'; block
        tables / lengths replicated) and record the per-device pool bytes
        gauge. No-op without a mesh — single-device arrays stay as-is."""
        if self.mesh is not None:
            self.cache = jax.device_put(
                self.cache, self.mesh.cache_shardings(self.cache)
            )
        if self.metrics.enabled:
            self.metrics.set_pool_device_bytes(_kv_pool_device_bytes(self.cache))

    def _mesh_scope(self):
        """The mesh context for one tick: activates the ServingMesh so
        ``parallel.sharding.constrain`` and the shard_map-wrapped paged
        kernels see it at trace time; a null context when unsharded."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def step(self) -> list[Request]:
        """ONE engine tick: admit whatever fits, refresh effective tiers
        (pressure controller first — downshift precedes any eviction),
        advance mid-prefill slots by one chunk, then one jitted decode step
        per active tier over the decode-phase slots. Returns requests that
        finished this tick."""
        with self.metrics.measure_tick():
            with self._mesh_scope():
                done = self._step_inner()
            self._update_gauges()
        return done

    def _step_inner(self) -> list[Request]:
        done: list[Request] = []
        s = self.ecfg.max_slots
        self._steps += 1
        free = [x for x in range(s) if x not in self._active]
        self._admit(free, done, self._steps)
        if not self._active:
            return done
        self._update_tier_shift()
        self._refresh_slot_tiers()
        self._prefill_progress(free, done, self._steps)
        self._pre_decode(free, done)
        active = np.zeros((s,), bool)
        for slot in self._active:
            if slot not in self._progress:   # mid-prefill slots don't decode
                active[slot] = True
        if active.any():
            self._decode_tick(active, free, done)
        return done

    def _update_gauges(self):
        """End-of-tick pool/queue gauges — host counters only, no device
        reads (the paged engine adds page-pool occupancy). Short-circuits
        when telemetry is off so gauge ARGUMENTS cost nothing either."""
        if not self.metrics.enabled:
            return
        self.metrics.set_pool(queue=len(self._queue),
                              active=len(self._active),
                              shift=self._tier_shift)
        if self._adapters is not None:
            self.metrics.set_resident_adapters(len(self._adapters.resident))

    def _decode_tick(self, active: np.ndarray, free: list[int],
                     done: list[Request]):
        """Device portion of a tick (hook: the speculative engine replaces
        this with its draft + k-wide verify program): ONE jitted decode per
        active tier, every call masked to its tier's slots over the shared
        cache. A single-tier bank degenerates to exactly one call per tick;
        each tier's program compiles once, on first use, so a slot switching
        tiers mid-stream never triggers a retrace."""
        s = self.ecfg.max_slots
        tokens = np.zeros((s, 1), np.int32)
        decode_slots = [int(x) for x in np.nonzero(active)[0]]
        for slot in decode_slots:
            tokens[slot, 0] = self._last_token[slot]
        tok_dev = jnp.asarray(tokens)
        step_dev = jnp.asarray(self._steps, jnp.int32)
        out = np.zeros((s,), np.int64)
        for key, slots in self._tier_groups(decode_slots):
            mask = np.zeros((s,), bool)
            mask[slots] = True
            with self.metrics.measure_program(
                "decode", key, traces=lambda: self.decode_traces
            ):
                nxt, self.cache = self._decode(
                    self._call_params(key, self._slot_pool), tok_dev,
                    self._device_cache(), jnp.asarray(mask), step_dev,
                )
                self.decode_calls += 1
                toks = np.asarray(nxt)       # one host sync per active tier
            out[slots] = toks[slots]
        for slot, req in list(self._active.items()):
            if slot in self._progress:
                continue
            self._record(slot, req, int(out[slot]), free, done)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive everything to completion (batch mode)."""
        done: list[Request] = []
        steps = 0
        while self.has_work and steps < max_steps:
            steps += 1
            done.extend(self.step())
        return done


# ------------------------------------------------------------------ paged ---


class BlockAllocator:
    """Host-side REF-COUNTED allocator over a fixed pool of KV pages.

    Pages are interchangeable — any free page can map any (slot, block)
    position, so there is no external fragmentation by construction; the only
    waste is internal (the partially-filled last block of each sequence).

    Prefix sharing (``serving/prefix_cache.py``) lets one physical page back
    several logical (slot, block) positions plus the radix index, so ownership
    is a per-page reference count: ``alloc`` grants pages at refcount 1,
    ``share`` adds a holder, ``release`` drops one and returns the page to the
    pool when the count hits zero. ``free`` keeps its strict pre-refcount
    contract — it only accepts EXCLUSIVE pages (refcount exactly 1), so a
    caller that believes it is the sole owner fails loudly if it is not.

    Invariants (asserted in tests): a page is never handed out twice, every
    mutation validates its whole argument list BEFORE touching state (a bad
    call leaves the allocator untouched), and free + distinct-owned always
    equals the pool whatever the refcounts are.
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))
        self._refs: dict[int, int] = {}    # page -> holders (absent = free)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Distinct owned pages (a shared page counts once)."""
        return len(self._refs)

    def refcount(self, page: int) -> int:
        """Holders of ``page`` (0 = free)."""
        return self._refs.get(page, 0)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def _validate_owned(self, pages: list[int], verb: str):
        bad = sorted({p for p in pages if p not in self._refs})
        if bad:
            raise ValueError(f"{verb} page(s) {bad} that are not allocated")
        if len(set(pages)) != len(pages):
            raise ValueError(
                f"duplicate page(s) in {verb} list {sorted(pages)}"
            )

    def alloc(self, n: int) -> list[int] | None:
        """n pages at refcount 1, or None if the pool cannot cover them (no
        partial grants)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def share(self, pages: list[int]):
        """Add one holder to each page — all of them or none of them (the
        whole list validates before any count moves)."""
        self._validate_owned(pages, "sharing")
        for p in pages:
            self._refs[p] += 1

    def release(self, pages: list[int]) -> list[int]:
        """Drop one holder from each page; pages reaching zero return to the
        pool. Validates the whole list first, then returns the pages actually
        freed (callers use it to account reclaim)."""
        self._validate_owned(pages, "releasing")
        freed: list[int] = []
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)
                freed.append(p)
        return freed

    def free(self, pages: list[int]):
        """Return EXCLUSIVE pages to the pool — all of them or none of them.

        The whole list is validated BEFORE any state changes: a bad entry
        (unowned page, a duplicate within the list, or a page somebody else
        still holds a reference to) used to raise mid-loop with the earlier
        pages already freed, leaving free + used != pool for every caller that
        caught the error. Now a bad free raises without mutating anything, so
        the allocator invariant survives."""
        self._validate_owned(pages, "free")
        shared = sorted({p for p in pages if self._refs[p] != 1})
        if shared:
            raise ValueError(
                f"freeing shared page(s) {shared} (refcount > 1); drop "
                "references with release() instead"
            )
        for p in pages:
            del self._refs[p]
        self._free.extend(pages)


class PagedServingEngine(ServingEngine):
    """Continuously-batched engine over a block-paged KV cache.

    Serving memory is ``num_blocks * block_size`` tokens of KV shared by all
    slots — short requests no longer pay for ``max_len``. Admission happens
    whenever a slot AND enough free pages exist (checked every tick); decode
    allocations grow one page at a time, and pool exhaustion evicts a victim
    back to the queue (it resumes later by re-prefilling prompt + generated
    tokens, which under greedy decoding reproduces the same continuation).

    With ``prefill_chunk`` set, admission only reserves pages for the FIRST
    chunk and marks the slot mid-prefill; each tick then advances every
    mid-prefill slot by one chunk through a single jitted ``(S, chunk)``
    program (``models.model.chunk_prefill_step``: scatter into pages at the
    slot's current length, causal mask offset by it) while decode-phase slots
    keep decoding. Pages are reserved chunk-by-chunk; a chunk that cannot get
    pages stalls its slot at the last completed chunk (no progress lost)
    rather than blocking the tick.

    With ``prefix_cache`` set, admission walks the radix prompt index first
    and attaches cached prefix pages read-only (see ``_admit``/``_retire``
    and ``serving/prefix_cache.py``); page ownership then counts references,
    copy-on-write privatizes the one page a hit admission may write into,
    and the index's unreferenced LRU tail is the first thing ``_alloc``
    reclaims under pressure.
    """

    _chunked = True
    _paged = True

    def __init__(self, model, params=None, ecfg: EngineConfig | None = None):
        bank, ecfg = _resolve_engine_args(type(self).__name__, model, params,
                                          ecfg)
        self._init_common(bank, ecfg)
        arch_cfg = self.cfg
        bs = ecfg.block_size
        assert bs >= 1
        self._bs = bs
        self._max_len = -(-ecfg.max_len // bs) * bs
        self._nb_slot = self._max_len // bs          # block-table width
        self.num_blocks = ecfg.num_blocks or ecfg.max_slots * self._nb_slot
        self.allocator = BlockAllocator(self.num_blocks)
        self._quantized = ecfg.kv_dtype == "int8"
        self._chunk = ecfg.prefill_chunk
        if self._chunk is not None:
            if self._chunk < 1 or self._chunk % bs:
                raise ValueError(
                    f"prefill_chunk={self._chunk} must be a positive multiple "
                    f"of block_size={bs} (chunks scatter whole pages)"
                )
            self._chunk = min(self._chunk, self._max_len)
        self.cache = model_lib.init_paged_cache(
            arch_cfg, ecfg.max_slots, self.num_blocks, bs, self._nb_slot,
            dtype=jnp.float32 if self._quantized else _KV_DTYPES[ecfg.kv_dtype],
            quantized=self._quantized,
        )
        # host mirror of the block table; pushed to device only when dirty
        self._table = np.full(
            (ecfg.max_slots, self._nb_slot), self.num_blocks, np.int32
        )
        self._table_dirty = False
        self._pages: dict[int, list[int]] = {}       # slot -> page ids
        self._ptarget: dict[int, int] = {}           # slot -> prefill target len
        self.chunk_calls = 0
        self.chunk_traces = 0
        # prefix sharing (serving/prefix_cache.py): radix index over prompt
        # prefixes at page granularity + the CoW copy program. Multi-tenant:
        # cached KV depends on the adapter's weights, so pages must never
        # match across adapters — one index PER ADAPTER ID (created on
        # demand by _prefix_of), all holding references in the ONE shared
        # allocator; an unregistered adapter's index simply stops being
        # consulted and its pages age out through the shared LRU reclaim
        self._prefix = PrefixCache(self.allocator, bs) \
            if ecfg.prefix_cache and self._adapters is None else None
        self._prefix_caches: dict[int, PrefixCache] = {}
        # slot -> device-length reset applied at the next _device_cache push:
        # a hit admission's length is stale until its first chunk program
        # runs, and junk rows written meanwhile must not land in pages the
        # slot attached read-only
        self._len_reset: dict[int, int] = {}
        if ecfg.tier_policy == "pressure":
            self.tier_controller = TierController(
                len(self.bank),
                TierControllerConfig(
                    target_free_frac=ecfg.tier_target_free,
                    gain=ecfg.tier_gain, ema=ecfg.tier_ema,
                ),
            )
        self._place_cache()
        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(5,))
        self._chunk_prog = jax.jit(self._chunk_fn, donate_argnums=(5,))
        self._copy_prog = jax.jit(
            transformer_lib.copy_cache_pages, donate_argnums=(0,)
        )
        # fixed-shape scatter for _len_reset (OOB pad indices drop)
        self._len_prog = jax.jit(
            lambda length, idx, val: length.at[idx].set(val, mode="drop")
        )

    @classmethod
    def capabilities(cls) -> dict:
        caps = ServingEngine.capabilities.__func__(cls)
        caps["kv"] = "paged"
        caps["features"].update(
            kv_dtype=["float32", "bfloat16", "int8"],
            chunked_prefill=True,
            eviction_resume=True,
            tier_pressure_controller=True,
            prefix_caching=True,
        )
        return caps

    # Prefix-cache counters: registry-backed read-only views (the hooks in
    # ``_admit`` are the single writers). With telemetry=False these read 0.

    @property
    def prefix_lookups(self) -> int:
        return int(self.metrics.counter_value(self.metrics.prefix, "lookups"))

    @property
    def prefix_hits(self) -> int:
        return int(self.metrics.counter_value(self.metrics.prefix, "hits"))

    @property
    def prefix_hit_tokens(self) -> int:
        """Prompt tokens served from the index instead of prefill compute."""
        return int(self.metrics.counter_value(self.metrics.tokens,
                                              "prefix_hit"))

    @property
    def cow_copies(self) -> int:
        """Pages privatized by copy-on-write."""
        return int(self.metrics.counter_value(self.metrics.prefix,
                                              "cow_copies"))

    @property
    def reattached_pages(self) -> int:
        """Pages evicted slots got back on resume."""
        return int(self.metrics.counter_value(self.metrics.prefix,
                                              "reattached_pages"))

    def _prefix_of(self, aid: int | None) -> PrefixCache | None:
        """The radix index serving adapter ``aid``: the shared one on plain
        banks, a per-adapter index under multi-tenant serving (created on
        first use — cached KV is adapter-specific). None with the cache off."""
        if not self.ecfg.prefix_cache:
            return None
        if self._adapters is None:
            return self._prefix
        pc = self._prefix_caches.get(aid)
        if pc is None:
            pc = self._prefix_caches[aid] = PrefixCache(self.allocator,
                                                        self._bs)
        return pc

    def _all_prefixes(self) -> list[PrefixCache]:
        if self._prefix is not None:
            return [self._prefix]
        return list(self._prefix_caches.values())

    def _update_gauges(self):
        if not self.metrics.enabled:
            return
        super()._update_gauges()
        # _prefix.pages (an O(1) count of index-held pages), NOT
        # reclaimable_pages — that walks the whole radix tree and a per-tick
        # walk is exactly the overhead telemetry promises not to add
        self.metrics.set_pool(
            free=self.allocator.free_blocks,
            cached=sum(pc.pages for pc in self._all_prefixes()),
        )

    def _update_tier_shift(self):
        """Integrate page pressure into the serving-tier downshift (BEFORE
        ``_pre_decode`` can evict anyone — the controller spends capacity
        quality first, requests last). Index-only cached pages count as free:
        they are one ``reclaim`` away from the pool, so a cache-warm engine
        must not read as a starved one."""
        if self.tier_controller is None:
            return
        free_like = self.allocator.free_blocks + sum(
            pc.reclaimable_pages for pc in self._all_prefixes()
        )
        self._tier_shift = self.tier_controller.update(
            free_like / self.num_blocks
        )
        if self._tier_shift > 0:
            self.metrics.inc(self.metrics.downshift_ticks)

    # ------------------------------------------------------------ intake ---

    def _validate(self, prompt: list[int], max_new_tokens: int):
        super()._validate(prompt, max_new_tokens)
        need = -(-(len(prompt) + max_new_tokens) // self._bs)
        if need > self.num_blocks:
            raise RequestRejected(
                f"request needs {need} KV pages but the whole pool holds "
                f"{self.num_blocks}"
            )

    def _bucket(self, n: int) -> int:
        b = super()._bucket(n)
        return min(-(-max(b, self._bs) // self._bs) * self._bs, self._max_len)

    # ----------------------------------------------------- device programs ---

    def _prefill_fn(self, params, tokens, lengths, slot_ids, page_map, cache, step):
        self.prefill_traces += 1
        logits, kvs, _ = model_lib._forward(
            params, {"tokens": tokens}, self.cfg, collect_kv=True
        )
        cache = transformer_lib.scatter_prefill_pages(cache, kvs, page_map)
        new_len = cache.length.at[slot_ids].set(lengths, mode="drop")
        last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)
        first_tok = self._sample(last[:, 0], step, salt=1, slots=slot_ids)
        return first_tok, cache._replace(length=new_len)

    def _chunk_target(self, params, tokens, counts, slot_ids, starts, cache,
                      step):
        """Shared device body of a prefill chunk (the speculative engine's
        two-model chunk program reuses it for the target side, so the two
        engines cannot drift). Rows are slot-indexed (tokens[b] lands at
        positions starts[b]..starts[b]+counts[b]-1 of slot b), queries attend
        previously written pages plus the chunk itself. ``starts`` is the
        host-tracked prefill progress — rows with counts > 0 RESET their
        device length to it (a freshly admitted slot may inherit a stale
        length from the slot's previous occupant; chunk 1 must insert at 0,
        exactly as the one-shot prefill sets lengths outright). Rows with
        counts == 0 (decode-phase or stalled slots) keep their length frozen
        and write a junk row there — masked by the length and overwritten by
        the next real insert, exactly like inactive decode rows. Returns
        (sampled next token per row — meaningful only where a prompt ended —
        updated cache, pre-chunk lengths)."""
        n0 = jnp.where(counts > 0, starts, cache.length)
        cache = cache._replace(length=n0)
        logits, cache = model_lib.chunk_prefill_step(
            params, tokens, counts, cache, self.cfg
        )
        last = jnp.take_along_axis(
            logits, jnp.maximum(counts - 1, 0)[:, None, None], axis=1
        )
        tok = self._sample(last[:, 0], step, salt=1, slots=slot_ids)
        return tok, cache, n0

    def _chunk_fn(self, params, tokens, counts, slot_ids, starts, cache, step):
        self.chunk_traces += 1
        tok, cache, _ = self._chunk_target(
            params, tokens, counts, slot_ids, starts, cache, step
        )
        return tok, cache

    # ------------------------------------------------------------- steps ---

    def _admit(self, free: list[int], done: list[Request], step: int):
        """Admit every queued request that a free slot + free pages can cover
        (earliest deadline first — ``_order_queue``). One-shot mode prefills
        the whole prompt here; chunked mode only reserves the first chunk's
        pages and hands the slot to ``_prefill_progress``.

        With the prefix cache on, admission first walks the radix index:
        matching full pages attach READ-ONLY (``allocator.share``) and only
        the unmatched suffix is prefilled — through the chunk program, the
        one program that can start at an offset. Later writes never land in
        an attached page: the suffix starts at ``s0`` and all writes happen
        at positions >= s0, while attached pages only cover positions < s0 —
        EXCEPT when a fully-cached prompt resumes at ``plen - 1`` inside its
        final hit page, which is exactly the copy-on-write case handled
        below (the page is privatized via one batched device copy before any
        program runs)."""
        if not self._queue or not free:
            return
        self._order_queue()
        reserve = self.ecfg.decode_reserve or self._bs
        admitted: list[tuple[int, Request, list[int], int, int]] = []
        cow_pairs: list[tuple[int, int]] = []
        while self._queue and free:
            req = self._queue[0]
            astat, arow = self._adapter_admit(req, done)
            if astat == "gone":           # unregistered after submit:
                self._queue.pop(0)        # finished rejected, next request
                continue
            if astat == "busy":           # every pool row pinned by a
                break                     # streaming slot: retry next tick
            ptoks = req.prompt + req.out_tokens      # evicted requests resume
            plen = len(ptoks)
            hit: list[int] = []
            s0 = 0           # prefill resumes here; tokens < s0 are cached
            pc = self._prefix_of(req.adapter)
            if pc is not None:
                self.metrics.prefix_event("lookups")
                hit = pc.match(ptoks)
                if len(hit) < self.ecfg.prefix_min_hit_pages:
                    hit = []
                if hit:
                    # the LAST prompt position is always (re)computed — its
                    # logits seed the first sampled token — so a fully-cached
                    # prompt resumes at plen - 1 inside its final hit page
                    s0 = min(len(hit) * self._bs, plen - 1)
                    if self._chunk is not None and plen > self._chunk:
                        # chunk-aligned so the chunked state machine starts
                        # at the hit boundary (and never rewrites a hit page)
                        s0 = s0 // self._chunk * self._chunk
                    hit = hit[: -(-s0 // self._bs)]
                    if not hit:
                        s0 = 0
            if self._chunk is not None and plen - s0 > self._chunk:
                want = s0 + self._chunk              # first chunk only; the
                #                                      rest reserves chunk-by-
                #                                      chunk as prefill advances
            else:
                remaining = max(req.max_new_tokens - len(req.out_tokens), 1)
                want = plen + min(max(reserve, 1), remaining)
            blocks = min(-(-want // self._bs), self._nb_slot)
            cow = bool(hit) and s0 % self._bs != 0   # the suffix's first write
            #                                          lands inside hit[-1]
            fresh_n = max(blocks - len(hit), 0) + (1 if cow else 0)
            if hit:
                # pin the hit FIRST: _alloc may reclaim index-only pages and
                # must not cannibalize the chain being attached
                self.allocator.share(hit)
            fresh = self._alloc(fresh_n)
            if fresh is None:
                if hit:
                    self.allocator.release(hit)
                if self._adapters is not None:       # undo the admit pin —
                    self._adapters.unpin(req.adapter)  # the slot never took
                break                                # pool full: stay queued
            pages = list(hit)
            if cow:
                copy = fresh.pop()
                cow_pairs.append((pages[-1], copy))
                self.allocator.release([pages[-1]])  # drop the shared ref —
                pages[-1] = copy                     # the index keeps its own
                self.metrics.prefix_event("cow_copies")
            pages += fresh
            self._queue.pop(0)
            slot = free.pop()
            now = _now()
            # prefill_compute = the suffix this admission actually schedules
            # through a prefill/chunk program (the hit share never recomputes)
            self.metrics.on_admit(req, slot, now,
                                  prefill_tokens=plen - s0, hit_tokens=s0)
            req.admitted_at = now
            self._active[slot] = req
            self._slot_tier[slot] = self._effective_tier(req)
            if arow is not None:
                self._slot_pool[slot] = arow
                self._slot_adapter[slot] = req.adapter
            self._pages[slot] = pages
            self._table[slot, : len(pages)] = pages
            self._table_dirty = True
            tr = self.tracer
            if tr is not None:
                tr.request_begin(slot, req.uid, t=now, tier=req.tier,
                                 resume=bool(req.evictions))
                tr.begin_span(slot, "prefill", t=now, tokens=plen - s0)
                if hit:
                    tr.instant(slot, "prefix_hit", t=now, pages=len(hit),
                               tokens=s0)
                if cow:
                    tr.instant(slot, "cow", t=now)
            if hit:
                self.metrics.prefix_event("hits")
                if req.evictions:
                    self.metrics.prefix_event("reattached_pages", len(hit))
                # the slot's device length is stale (previous occupant) until
                # its first chunk program resets it; junk rows written by
                # other programs this tick must not land in attached pages
                self._len_reset[slot] = s0
            admitted.append((slot, req, pages, plen, s0))
        if not admitted:
            return
        if cow_pairs:
            self._cow_copy(cow_pairs)
        if self._chunk is not None:
            # chunked mode: no prefill program at admission — mark the slots
            # mid-prefill AT THE HIT BOUNDARY; this same tick's
            # _prefill_progress runs the first unmatched chunk
            for slot, req, _, plen, s0 in admitted:
                self._progress[slot] = s0
                self._ptarget[slot] = plen
            return

        s = self.ecfg.max_slots
        by_slot = {slot: (req, pages, plen)
                   for slot, req, pages, plen, s0 in admitted if s0 == 0}
        for key, slots in self._tier_groups(by_slot):
            group = [(slot, *by_slot[slot]) for slot in slots]
            bucket = self._bucket(max(plen for _, _, _, plen in group))
            nb_bucket = bucket // self._bs
            tokens = np.zeros((s, bucket), np.int32)
            lengths = np.ones((s,), np.int32)
            slot_ids = np.full((s,), s, np.int32)
            page_map = np.full((s, nb_bucket), self.num_blocks, np.int32)
            rows = np.zeros((s,), np.int32)      # GROUP-indexed pool rows
            for i, (slot, req, pages, plen) in enumerate(group):
                ptoks = req.prompt + req.out_tokens
                tokens[i, :plen] = ptoks
                lengths[i] = plen
                slot_ids[i] = slot
                rows[i] = self._slot_pool[slot]
                prompt_blocks = -(-plen // self._bs)
                page_map[i, :prompt_blocks] = pages[:prompt_blocks]
            firsts = self._prefill_admitted(
                tokens, lengths, slot_ids, page_map, step, key, rows
            )
            for i, (slot, req, _, _) in enumerate(group):
                req.prefill_emitted += 1
                self._record(slot, req, int(firsts[i]), free, done)
        # prefix hits prefill ONLY the unmatched suffix, through the chunk
        # program (slot-indexed rows starting at s0). The sample key
        # (step, salt=1, slot) matches the one-shot prefill's exactly, so a
        # hit admission's stream — greedy or sampled — is identical to what
        # a cache-off full prefill would have emitted this tick
        hits = {slot: (req, plen, s0)
                for slot, req, _, plen, s0 in admitted if s0 > 0}
        for key, slots in self._tier_groups(hits):
            width = self._bucket(max(hits[x][1] - hits[x][2] for x in slots))
            tokens = np.zeros((s, width), np.int32)
            counts = np.zeros((s,), np.int32)
            slot_ids = np.full((s,), s, np.int32)
            starts = np.zeros((s,), np.int32)
            for slot in slots:
                req, plen, s0 = hits[slot]
                ptoks = req.prompt + req.out_tokens
                tokens[slot, : plen - s0] = ptoks[s0:]
                counts[slot] = plen - s0
                slot_ids[slot] = slot
                starts[slot] = s0
            firsts = self._chunk_call(tokens, counts, slot_ids, starts, step,
                                      key)
            for slot in slots:
                req = hits[slot][0]
                req.prefill_emitted += 1
                self._record(slot, req, int(firsts[slot]), free, done)

    def _alloc(self, n: int) -> list[int] | None:
        """Pool allocation with the prefix cache as the reclaim tail: when
        the free list cannot cover ``n``, index-only cached pages are
        reclaimed LRU-first — BEFORE any caller resorts to evicting live
        slots. Multi-tenant serving reclaims across EVERY adapter's index
        (including indexes of since-unregistered adapters — that is how
        their orphaned pages drain)."""
        pages = self.allocator.alloc(n)
        if pages is None:
            for pc in self._all_prefixes():
                pc.reclaim(n - self.allocator.free_blocks)
                if self.allocator.free_blocks >= n:
                    break
            pages = self.allocator.alloc(n)
        return pages

    def _cow_copy(self, pairs: list[tuple[int, int]]):
        """ONE batched device page copy for this tick's CoW pairs (the block
        table was already remapped host-side). Pairs pad to a power of two
        with (0, 0) identity entries, so the program compiles O(log) shapes."""
        n = 1
        while n < len(pairs):
            n *= 2
        pad = pairs + [(0, 0)] * (n - len(pairs))
        src = jnp.asarray([p for p, _ in pad], jnp.int32)
        dst = jnp.asarray([q for _, q in pad], jnp.int32)
        with self.metrics.measure_program(f"page_copy[{n}]"):
            self._apply_cow(src, dst)

    def _apply_cow(self, src: jax.Array, dst: jax.Array):
        """Hook: the speculative engine also copies its draft pools here —
        they ride the target's block table and page ids, so the same pairs
        remap both caches."""
        self.cache = self._copy_prog(self.cache, src, dst)

    def _prefill_admitted(self, tokens, lengths, slot_ids, page_map, step,
                          tier: int = 0, rows=None):
        """Device portion of admission (hook: the speculative engine also
        prefills the draft page pools here). ``rows`` is the GROUP-indexed
        adapter-pool row map (batched adapter mode only; the prefill batch
        is group-indexed, unlike the slot-indexed decode/chunk programs).
        Returns first tokens (host)."""
        with self.metrics.measure_program(
            f"prefill[{tokens.shape[1]}]", tier,
            traces=lambda: self.prefill_traces,
        ):
            first, self.cache = self._prefill(
                self._call_params(tier, rows), jnp.asarray(tokens),
                jnp.asarray(lengths), jnp.asarray(slot_ids),
                jnp.asarray(page_map), self.cache,
                jnp.asarray(step, jnp.int32),
            )
            self.prefill_calls += 1
            return np.asarray(first)

    def _prefill_progress(self, free: list[int], done: list[Request],
                          step: int):
        """Advance every mid-prefill slot by ONE chunk (a single jitted call
        per active tier covers all of them). Per slot: reserve pages for the
        chunk (plus the decode headroom when it is the final chunk). Prefill
        growth never
        evicts — a slot whose chunk cannot get pages STALLS at its last
        completed chunk and resumes once decode-phase slots finish and free
        pages (eviction here would let two contending prefills ping-pong each
        other forever: each re-admits with one chunk's pages and evicts the
        other's progress — measured livelock, see tests). The one deadlock
        case — EVERY active slot is a stalled prefill, so nothing will ever
        free a page — evicts the least-progressed stalled slot and lets the
        SURVIVORS absorb the freed pages within this same tick (deferring to
        the next tick would hand them straight back to the evicted request at
        re-admission — its first chunk can need exactly what eviction freed,
        a measured ping-pong that starves everyone forever); survivor page
        counts therefore grow monotonically and some prefill always
        completes. Slots whose prompt completes emit their first token here
        (the chunked counterpart of the one-shot admission prefill)."""
        if not self._progress:
            return
        reserve = self.ecfg.decode_reserve or self._bs
        while True:
            ready: list[int] = []
            stalled: list[int] = []
            for slot in sorted(self._progress):
                req = self._active.get(slot)
                if req is None:
                    continue
                p = self._progress[slot]
                target = self._ptarget[slot]
                c = min(self._chunk, target - p)
                if p + c >= target:      # final chunk: also reserve headroom
                    remaining = max(req.max_new_tokens - len(req.out_tokens), 1)
                    want = target + min(max(reserve, 1), remaining)
                else:
                    want = p + c
                need = min(-(-want // self._bs), self._nb_slot)
                while len(self._pages[slot]) < need:
                    page = self._alloc(1)
                    if page is None:
                        break
                    idx = len(self._pages[slot])
                    self._pages[slot].append(page[0])
                    self._table[slot, idx] = page[0]
                    self._table_dirty = True
                (ready if len(self._pages[slot]) >= need
                 else stalled).append(slot)
            if ready or not stalled:
                break
            if not all(s in self._progress for s in self._active):
                return   # a decoder is still running: it is bounded by
                #          max_new_tokens and will free its pages — stall
            # total stall, nothing will free a page on its own: evict the
            # least-progressed slot and retry so the survivors take the
            # freed pages NOW (each pass removes one slot, so this loop is
            # bounded by max_slots; a lone survivor always fits by the
            # submit-time validation)
            self._evict(min(stalled, key=lambda s: (self._progress[s], s)),
                        free)
        if not ready:
            return
        s = self.ecfg.max_slots
        for tier, tier_slots in self._tier_groups(ready):
            tokens = np.zeros((s, self._chunk), np.int32)
            counts = np.zeros((s,), np.int32)
            slot_ids = np.full((s,), s, np.int32)
            starts = np.zeros((s,), np.int32)
            for slot in tier_slots:
                req = self._active[slot]
                p = self._progress[slot]
                c = min(self._chunk, self._ptarget[slot] - p)
                ptoks = req.prompt + req.out_tokens
                tokens[slot, :c] = ptoks[p : p + c]
                counts[slot] = c
                slot_ids[slot] = slot
                starts[slot] = p
            t0 = _now()
            firsts = self._chunk_call(tokens, counts, slot_ids, starts, step,
                                      tier)
            if self.tracer is not None:
                t1 = _now()
                for slot in tier_slots:
                    if slot in self._active:
                        self.tracer.begin_span(slot, "prefill_chunk", t=t0,
                                               start=int(starts[slot]),
                                               tokens=int(counts[slot]))
                        self.tracer.end_span(slot, "prefill_chunk", t=t1)
            for slot in tier_slots:
                req = self._active.get(slot)
                if req is None:
                    continue
                self._progress[slot] += int(counts[slot])
                if self._progress[slot] >= self._ptarget[slot]:
                    del self._progress[slot]
                    del self._ptarget[slot]
                    req.prefill_emitted += 1
                    self._record(slot, req, int(firsts[slot]), free, done)

    def _chunk_call(self, tokens, counts, slot_ids, starts, step,
                    tier: int = 0):
        """Device portion of a chunk tick (hook: the speculative engine also
        runs the draft's chunk here). Chunk rows are SLOT-indexed, so the
        batched adapter bind uses the slot→pool-row map directly. Returns
        sampled tokens (host)."""
        with self.metrics.measure_program(
            f"chunk[{tokens.shape[1]}]", tier,
            traces=lambda: self.chunk_traces,
        ):
            first, self.cache = self._chunk_prog(
                self._call_params(tier, self._slot_pool), jnp.asarray(tokens),
                jnp.asarray(counts), jnp.asarray(slot_ids),
                jnp.asarray(starts), self._device_cache(),
                jnp.asarray(step, jnp.int32),
            )
            self.chunk_calls += 1
            return np.asarray(first)

    def _pre_decode(self, free: list[int], done: list[Request]):
        """Grow each active slot's pages to cover this tick's KV writes; evict
        when the pool is dry. The next decode writes the KV of the latest
        sampled token at position len(prompt) + len(out) - 1; the speculative
        engine widens the window (``_write_window`` > 1) to cover all k draft
        positions. Writes past the table's capacity drop device-side, so the
        need is capped at the table width."""
        window = getattr(self, "_write_window", 1)
        for slot in list(self._active):
            req = self._active.get(slot)
            if req is None or slot in self._progress:
                continue                 # mid-prefill slots grow in their own
                #                          chunk scheduler, not here
            write_pos = len(req.prompt) + len(req.out_tokens) - 1 + (window - 1)
            need = min(write_pos // self._bs + 1, self._nb_slot)
            while slot in self._active and len(self._pages[slot]) < need:
                page = self._alloc(1)
                if page is not None:
                    idx = len(self._pages[slot])
                    self._pages[slot].append(page[0])
                    self._table[slot, idx] = page[0]
                    self._table_dirty = True
                    continue
                victim = self._choose_victim()
                if victim is None:
                    break
                self._evict(victim, free)

    def _choose_victim(self) -> int | None:
        """Pick an eviction victim for DECODE-phase page growth (prefill
        growth stalls instead of evicting — see ``_prefill_progress``). Under
        ``longest_remaining`` a mid-prefill slot counts its full
        ``max_new_tokens`` as remaining, so it is naturally preferred over a
        nearly-finished decoder — its pages stay pinned longest otherwise."""
        if not self._active:
            return None
        if self.ecfg.evict_policy == "lru":
            # least-recently admitted slot
            return min(self._active, key=lambda s: (self._active[s].admitted_at, s))
        # longest_remaining: its pages stay pinned for the longest otherwise
        return max(
            self._active,
            key=lambda s: (
                self._active[s].max_new_tokens - len(self._active[s].out_tokens), s
            ),
        )

    def _evict(self, slot: int, free: list[int]):
        """Return the slot's pages and re-queue its request; it re-prefills
        prompt + generated tokens on re-admission (resumed requests sort
        ahead of fresh ones with the same deadline — see ``_order_queue``)."""
        req = self._active.pop(slot)
        req.evictions += 1
        req.requeued_at = _now()
        self.metrics.on_evict()
        tr = self.tracer
        if tr is not None:
            now = req.requeued_at
            for name in ("decode", "prefill"):
                while tr.has_open(slot, name):
                    tr.end_span(slot, name, t=now, aborted=True)
            tr.instant(slot, "evicted", t=now, uid=req.uid)
            tr.end_span(slot, "request", t=now, uid=req.uid, evicted=True)
        self._retire(slot, req)
        self._release(slot)
        self._queue.append(req)
        free.append(slot)

    def _retire(self, slot: int, req: Request):
        """Publish the slot's FULL pages into the radix index — the KV that
        was actually written: a decode-phase slot has everything but the last
        sampled token's position, an evicted mid-prefill slot its chunk
        progress. The published pages' references TRANSFER to the index
        (``_release`` then only frees the exclusive tail), so finish and
        eviction both leave the prefix warm; eviction-resume reattaches these
        pages instead of chunked re-prefill. Multi-tenant serving publishes
        into the slot's ADAPTER's index — the KV is conditioned on that
        adapter's weights and must never serve another tenant."""
        pc = self._prefix_of(req.adapter)
        if pc is None:
            return
        pages = self._pages.get(slot)
        if not pages:
            return
        ptoks = req.prompt + req.out_tokens
        written = self._progress.get(slot, len(ptoks) - 1)
        n_full = min(written // self._bs, len(pages))
        if n_full <= 0:
            return
        pc.publish(ptoks, pages[:n_full])
        del pages[:n_full]

    def _release(self, slot: int):
        super()._release(slot)      # unpin the slot's adapter (base hook)
        pages = self._pages.pop(slot, None)
        if pages:
            # release, not free: attached pages fall back to their remaining
            # holders (the radix index), exclusive pages return to the pool
            self.allocator.release(pages)
        self._table[slot, :] = self.num_blocks
        self._table_dirty = True
        self._len_reset.pop(slot, None)
        self._progress.pop(slot, None)
        self._ptarget.pop(slot, None)

    def _device_cache(self):
        if self._table_dirty:
            # under a mesh the host table is pushed with an explicit
            # replicated placement — block ids are head-replicated, and a
            # committed single-device array would reshard (and retrace) the
            # decode program
            table = (
                jax.device_put(self._table, self.mesh.replicated())
                if self.mesh is not None else jnp.asarray(self._table)
            )
            self.cache = self.cache._replace(block_table=table)
            self._table_dirty = False
        if self._len_reset:
            # pending hit-admission length resets (see _admit): applied before
            # any length-addressed program can write a junk row via a stale
            # length into a page the slot only shares. Padded to a fixed
            # (max_slots,) shape with out-of-range indices (dropped by the
            # scatter) so the jitted program compiles exactly once
            s = self.ecfg.max_slots
            idx = np.full((s,), s, np.int32)
            val = np.zeros((s,), np.int32)
            for i, (slot, s0) in enumerate(self._len_reset.items()):
                idx[i], val[i] = slot, s0
            self.cache = self.cache._replace(
                length=self._len_prog(self.cache.length, idx, val)
            )
            self._len_reset.clear()
        return self.cache


# -------------------------------------------------------------- reference ---


class ReferenceEngine:
    """The seed per-slot, per-token engine (scalar-length cache, slot slicing
    with host write-back, one ``int()`` sync per slot per token).

    Kept as (a) the measured baseline for ``benchmarks/serve_throughput.py``
    and (b) the serving path for cache families without per-slot lengths.
    Implements the :class:`~repro.serving.elastic.Engine` protocol, including
    per-request bank tiers (each slot decodes with its requested tier's
    parameter tree — no pressure controller, there is no page pool to feel
    pressure from).
    """

    def __init__(self, model, params=None, ecfg: EngineConfig | None = None):
        bank, ecfg = _resolve_engine_args(type(self).__name__, model, params,
                                          ecfg)
        arch_cfg = bank.cfg
        missing = []
        if ecfg.kv_dtype != "float32":
            missing.append(f"kv_dtype={ecfg.kv_dtype!r}")
        if ecfg.spec_k:
            missing.append(f"speculative decoding (spec_k={ecfg.spec_k})")
        if ecfg.prefill_chunk is not None:
            missing.append(
                f"chunked prefill (prefill_chunk={ecfg.prefill_chunk})"
            )
        if ecfg.tier_policy == "pressure":
            missing.append(
                f"tier_policy={ecfg.tier_policy!r} (page-pressure controller)"
            )
        if ecfg.prefix_cache:
            missing.append("prefix_cache=True (radix prompt cache)")
        if ecfg.mesh is not None:
            missing.append(
                f"mesh={ecfg.mesh!r} (tensor-parallel serving needs the "
                "batched engines)"
            )
        if ecfg.adapters or isinstance(bank, AdapterBank):
            missing.append(
                "multi-tenant adapters (AdapterBank serving needs the "
                "batched engines)"
            )
        if missing:
            raise _capability_error(type(self), arch_cfg.family, missing)
        log.info(
            "ReferenceEngine serving family %r: per-slot per-token loop, "
            "contiguous float32 cache — no paged features (kv_dtype, "
            "speculation, eviction/resume)",
            arch_cfg.family,
        )
        self.cfg = arch_cfg
        self.ecfg = ecfg
        self.bank = bank
        self._tier_params, self._default_tier = _bank_tier_state(bank, ecfg)
        self.params = self._tier_params[self._default_tier]
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}   # slot -> request
        self._uid = 0
        self._steps = 0
        self._slot_len = [0] * ecfg.max_slots

        self.cache = model_lib.init_cache(
            arch_cfg, ecfg.max_slots, ecfg.max_len, dtype=jnp.float32
        )
        self.decode_calls = 0
        self.decode_traces = 0   # python side effect below: counts traces only

        def _decode_fn(p, tok, cache):
            self.decode_traces += 1
            return model_lib.decode_step(p, tok, cache, arch_cfg)

        self._decode = jax.jit(_decode_fn)
        # the same telemetry schema as the batched engines (the registry is
        # shared infrastructure, not a paged-engine feature)
        self.metrics = (EngineTelemetry if ecfg.telemetry
                        else NullTelemetry)(type(self).__name__)
        self.tracer: RequestTracer | None = None
        if ecfg.trace:
            self.start_trace()

    @classmethod
    def capabilities(cls) -> dict:
        return {
            "engine": cls.__name__,
            "families": ["dense", "moe", "vlm", "ssm", "hybrid", "encdec"],
            "kv": "contiguous",
            "features": {
                "kv_dtype": ["float32"],
                "continuous_batching": False,
                "deadlines_edf": False,
                "chunked_prefill": False,
                "eviction_resume": False,
                "speculative": False,
                "elastic_tiers": True,
                "tier_pressure_controller": False,
                "prefix_caching": False,
                "tensor_parallel": False,
                "multi_tenant_adapters": False,
            },
        }

    # ----------------------------------------------------- observability ---

    start_trace = ServingEngine.start_trace

    def stats_snapshot(self) -> dict:
        """Same shape as the batched engines' snapshot; the reference loop
        has no prefill program (prompts insert token-by-token through the
        decode step), so the prefill counters report 0."""
        return {
            "engine": type(self).__name__,
            "steps": self._steps,
            "decode_calls": self.decode_calls,
            "decode_traces": self.decode_traces,
            "prefill_calls": 0,
            "prefill_traces": 0,
            "jit_retraces": self.metrics.retraces(),
            "metrics": self.metrics.snapshot(),
        }

    # ------------------------------------------------------------ intake ---

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               deadline: float | None = None,
               tier: int | None = None,
               submitted_at: float | None = None,
               adapter: int | None = None) -> int:
        try:
            _validate_request(prompt, max_new_tokens, self.ecfg.max_len)
            t = _resolve_request_tier(self.bank, self._default_tier, tier)
            if adapter is not None:
                raise RequestRejected(
                    f"adapter={adapter}: ReferenceEngine serves no adapters "
                    "(AdapterBank needs the batched engines)"
                )
        except RequestRejected:
            self.metrics.on_reject()
            raise
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens,
                    submitted_at=_now() if submitted_at is None
                    else submitted_at,
                    deadline=deadline, tier=t)
        )
        self.metrics.on_submit()
        return self._uid

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self._active)

    # ------------------------------------------------------------- steps ---

    def _prefill_into_slot(self, slot: int, req: Request):
        """Per-token insertion into this slot's cache rows (the LAST prompt
        token is fed by the first decode step, so prefill stops one short)."""
        self._slot_len[slot] = 0
        for tok in req.prompt[:-1]:
            self._step_slot(slot, tok)

    def step(self) -> list[Request]:
        """One engine tick (Engine protocol): admit into free slots, then one
        token for every active slot (the seed per-slot loop — one device call
        and one host sync per slot)."""
        with self.metrics.measure_tick():
            done = self._step_inner()
            self.metrics.set_pool(queue=len(self._queue),
                                  active=len(self._active))
        return done

    def _step_inner(self) -> list[Request]:
        done: list[Request] = []
        self._steps += 1
        tr = self.tracer
        free = [s for s in range(self.ecfg.max_slots) if s not in self._active]
        while self._queue and free:
            slot = free.pop()
            req = self._queue.pop(0)
            now = _now()
            self.metrics.on_admit(req, slot, now,
                                  prefill_tokens=len(req.prompt))
            req.admitted_at = now
            self._active[slot] = req
            if tr is not None:
                tr.request_begin(slot, req.uid, t=now, tier=req.tier)
                tr.begin_span(slot, "prefill", t=now, tokens=len(req.prompt))
            self._prefill_into_slot(slot, req)
        for slot, req in list(self._active.items()):
            last = (req.out_tokens or req.prompt)[-1]
            nxt = self._step_slot(slot, last)
            req.out_tokens.append(int(nxt))
            now = _now()
            req.token_times.append(now)
            first = req.first_token_at == 0.0
            if first:
                req.first_token_at = now
            self.metrics.on_token(req, now, first)
            if tr is not None and tr.has_open(slot, "prefill"):
                tr.end_span(slot, "prefill", t=now)
                if first:
                    tr.instant(slot, "first_token", t=now, uid=req.uid)
                tr.begin_span(slot, "decode", t=now, uid=req.uid)
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or (self.ecfg.eos_token is not None and nxt == self.ecfg.eos_token)
            ):
                req.done = True
                req.finished_at = now
                self.metrics.on_finish()
                if tr is not None:
                    if tr.has_open(slot, "decode"):
                        tr.end_span(slot, "decode", t=now)
                    tr.request_end(slot, req.uid, t=now,
                                   tokens=len(req.out_tokens))
                done.append(req)
                del self._active[slot]
        return done

    def run(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        steps = 0
        while self.has_work and steps < max_steps:
            steps += 1
            done.extend(self.step())
        return done

    def _step_slot(self, slot: int, token: int) -> int:
        """One decode step for one slot (per-slot cache view + write-back),
        with the slot's REQUESTED tier's parameters — each tier's program
        traces once, like any other shape."""
        sub_cache = jax.tree.map(
            lambda x: x[:, slot : slot + 1] if x.ndim >= 2 and x.shape[1] == self.ecfg.max_slots else x,
            self.cache,
        )
        sub_cache = sub_cache._replace(length=jnp.asarray(self._slot_len[slot], jnp.int32))
        tok = jnp.asarray([[token]], jnp.int32)
        req = self._active[slot]
        params = self._tier_params[req.tier]
        with self.metrics.measure_program(
            "decode_ref", req.tier, traces=lambda: self.decode_traces
        ):
            logits, new_sub = self._decode(params, tok, sub_cache)
            self.decode_calls += 1

        def write_back(full, sub):
            if full.ndim >= 2 and full.shape[1] == self.ecfg.max_slots:
                return full.at[:, slot : slot + 1].set(sub)
            return full

        updated = jax.tree.map(write_back, self.cache, new_sub)
        self.cache = updated._replace(length=self.cache.length)
        self._slot_len[slot] += 1
        return int(jnp.argmax(logits[0, -1]))
