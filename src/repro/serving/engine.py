"""Batched serving engine: continuous batching over two jitted programs.

Slot-based scheduler: a fixed decode batch of ``max_slots`` sequences sharing
one KV cache whose ``length`` is a per-slot ``(max_slots,)`` vector. New
requests are admitted in groups, padded to a length bucket, and run through
the REAL batched ``model.prefill`` program; their KV rows and logits-derived
first tokens are scattered into free slots inside the same jitted call.
Decode then issues exactly ONE jitted step per engine tick covering all
active slots: sampling happens on device and a single ``(max_slots,)`` token
array is fetched per step — no per-slot Python loop, no per-slot cache
slicing/write-back, no per-slot host sync.

Device programs (all shapes static, so serving never recompiles):
  * ``prefill[bucket]`` — (params, tokens (S, bucket), lengths, slot_ids,
    cache, step) -> (first_tokens (S,), cache); one variant per length bucket
  * ``decode`` — (params, tokens (S, 1), cache, active (S,), step)
    -> (next_tokens (S,), cache)

Weights may be a raw param tree (dense) or a ``DeployedModel`` serving
SLR (L + S) weights in factored / block-CSR form — the programs are format-
agnostic because every linear site goes through ``models.layers.apply_weight``.

``ReferenceEngine`` preserves the seed per-slot/per-token path: it is the
baseline that ``benchmarks/serve_throughput.py`` measures against, and the
fallback for cache families without per-slot lengths (ssm/hybrid/encdec).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib

BATCHED_FAMILIES = ("dense", "moe", "vlm")  # cache families with per-slot lengths


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclass
class EngineConfig:
    max_slots: int = 4        # concurrent sequences (decode batch)
    max_len: int = 256        # cache capacity per slot
    greedy: bool = True
    temperature: float = 1.0  # used when greedy=False (on-device sampling)
    eos_token: int | None = None
    seed: int = 0
    min_bucket: int = 8       # smallest prefill length bucket


def _as_params(params_or_deployed):
    """Accept a raw param tree or a serving.deployed.DeployedModel."""
    return getattr(params_or_deployed, "params", None) \
        if hasattr(params_or_deployed, "fmt") else params_or_deployed


class ServingEngine:
    """Single-host batched engine; the multi-pod path swaps the jitted fns
    for their pjit'd versions (same signatures — see launch/serve.py)."""

    def __init__(self, arch_cfg, params, ecfg: EngineConfig = EngineConfig()):
        if arch_cfg.family not in BATCHED_FAMILIES:
            raise ValueError(
                f"batched engine needs a KV-cache family, got {arch_cfg.family!r};"
                " use ReferenceEngine for ssm/hybrid/encdec"
            )
        self.cfg = arch_cfg
        self.ecfg = ecfg
        deployed = _as_params(params)
        self.params = deployed if deployed is not None else params
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}   # slot -> request
        self._uid = 0
        self._last_token = np.zeros(ecfg.max_slots, np.int64)

        # one shared cache; per-slot valid-prefix lengths ride inside it
        cache = model_lib.init_cache(
            arch_cfg, ecfg.max_slots, ecfg.max_len, dtype=jnp.float32
        )
        self.cache = cache._replace(
            length=jnp.zeros((ecfg.max_slots,), jnp.int32)
        )
        self._base_key = jax.random.PRNGKey(ecfg.seed)

        # instrumentation: device calls vs (re)traces — tests assert the
        # decode loop is one device call per step and compiles exactly once
        self.decode_calls = 0
        self.decode_traces = 0
        self.prefill_calls = 0
        self.prefill_traces = 0

        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(4,))

    # ------------------------------------------------------------ intake ---

    def submit(self, prompt: list[int], max_new_tokens: int = 16) -> int:
        assert len(prompt) >= 1, "empty prompt"
        assert len(prompt) + max_new_tokens <= self.ecfg.max_len, (
            f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
            f"cache capacity {self.ecfg.max_len}"
        )
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens, submitted_at=time.time())
        )
        return self._uid

    # ----------------------------------------------------- device programs ---

    def _sample(self, logits: jax.Array, step: jax.Array, salt: int) -> jax.Array:
        """Greedy or temperature sampling, on device. logits: (S, vocab).

        ``salt`` separates the prefill and decode streams — both can sample
        within the same engine tick and must not share gumbel noise.
        """
        if self.ecfg.greedy or self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.fold_in(self._base_key, step), salt)
        g = jax.random.gumbel(key, logits.shape)
        return jnp.argmax(logits / self.ecfg.temperature + g, axis=-1).astype(jnp.int32)

    def _decode_fn(self, params, tokens, cache, active, step):
        self.decode_traces += 1  # python side effect: counts traces only
        logits, new_cache = model_lib.decode_step(params, tokens, cache, self.cfg)
        # only active slots advance their valid prefix; inactive slots wrote a
        # junk row at their frozen position — the next real token overwrites it
        new_len = jnp.where(active, new_cache.length, cache.length)
        next_tok = self._sample(logits[:, -1], step, salt=0)
        return next_tok, new_cache._replace(length=new_len)

    def _prefill_fn(self, params, tokens, lengths, slot_ids, cache, step):
        self.prefill_traces += 1
        logits, pcache = model_lib.prefill(
            params, {"tokens": tokens}, self.cfg, max_len=self.ecfg.max_len,
            cache_dtype=cache.k.dtype,
        )
        # scatter the prefilled KV rows / lengths into the target slots;
        # padded rows carry slot_id == max_slots and drop out of bounds
        k = cache.k.at[:, slot_ids].set(pcache.k, mode="drop")
        v = cache.v.at[:, slot_ids].set(pcache.v, mode="drop")
        new_len = cache.length.at[slot_ids].set(lengths, mode="drop")
        # the logits at the last prompt position yield the first generated token
        last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)
        first_tok = self._sample(last[:, 0], step, salt=1)
        return first_tok, cache._replace(k=k, v=v, length=new_len)

    # ------------------------------------------------------------- steps ---

    def _bucket(self, n: int) -> int:
        b = self.ecfg.min_bucket
        while b < n:
            b *= 2
        return min(b, self.ecfg.max_len)

    def _admit(self, free: list[int], done: list[Request], step: int):
        """Batch all admissible queued requests through one prefill call."""
        take = min(len(free), len(self._queue))
        if not take:
            return
        reqs = [self._queue.pop(0) for _ in range(take)]
        s = self.ecfg.max_slots
        bucket = self._bucket(max(len(r.prompt) for r in reqs))
        tokens = np.zeros((s, bucket), np.int32)
        lengths = np.ones((s,), np.int32)        # padded rows: 1 valid token
        slot_ids = np.full((s,), s, np.int32)    # out-of-range => dropped
        slots = []
        for i, req in enumerate(reqs):
            slot = free.pop()
            slots.append(slot)
            self._active[slot] = req
            tokens[i, : len(req.prompt)] = req.prompt
            lengths[i] = len(req.prompt)
            slot_ids[i] = slot
        first, self.cache = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(slot_ids), self.cache, jnp.asarray(step, jnp.int32),
        )
        self.prefill_calls += 1
        firsts = np.asarray(first)               # one fetch per admit batch
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            self._record(slot, req, int(firsts[i]), free, done)

    def _record(self, slot: int, req: Request, tok: int, free, done):
        req.out_tokens.append(tok)
        self._last_token[slot] = tok
        if len(req.out_tokens) >= req.max_new_tokens or (
            self.ecfg.eos_token is not None and tok == self.ecfg.eos_token
        ):
            req.done = True
            req.finished_at = time.time()
            done.append(req)
            del self._active[slot]
            free.append(slot)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive everything to completion (batch mode)."""
        done: list[Request] = []
        s = self.ecfg.max_slots
        free = [x for x in range(s) if x not in self._active]
        steps = 0
        while (self._queue or self._active) and steps < max_steps:
            steps += 1
            self._admit(free, done, steps)
            if not self._active:
                continue
            active = np.zeros((s,), bool)
            tokens = np.zeros((s, 1), np.int32)
            for slot in self._active:
                active[slot] = True
                tokens[slot, 0] = self._last_token[slot]
            nxt, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(active), jnp.asarray(steps, jnp.int32),
            )
            self.decode_calls += 1
            toks = np.asarray(nxt)               # ONE host sync per step
            for slot, req in list(self._active.items()):
                self._record(slot, req, int(toks[slot]), free, done)
        return done


class ReferenceEngine:
    """The seed per-slot, per-token engine (scalar-length cache, slot slicing
    with host write-back, one ``int()`` sync per slot per token).

    Kept as (a) the measured baseline for ``benchmarks/serve_throughput.py``
    and (b) the serving path for cache families without per-slot lengths.
    """

    def __init__(self, arch_cfg, params, ecfg: EngineConfig = EngineConfig()):
        self.cfg = arch_cfg
        self.ecfg = ecfg
        deployed = _as_params(params)
        self.params = deployed if deployed is not None else params
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}   # slot -> request
        self._uid = 0

        self.cache = model_lib.init_cache(
            arch_cfg, ecfg.max_slots, ecfg.max_len, dtype=jnp.float32
        )
        self._decode = jax.jit(
            lambda p, tok, cache: model_lib.decode_step(p, tok, cache, arch_cfg)
        )

    # ------------------------------------------------------------ intake ---

    def submit(self, prompt: list[int], max_new_tokens: int = 16) -> int:
        assert len(prompt) + max_new_tokens <= self.ecfg.max_len, (
            f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
            f"cache capacity {self.ecfg.max_len}"
        )
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens, submitted_at=time.time())
        )
        return self._uid

    # ------------------------------------------------------------- steps ---

    def _prefill_into_slot(self, slot: int, req: Request):
        """Per-token insertion into this slot's cache rows (the LAST prompt
        token is fed by the first decode step, so prefill stops one short)."""
        self._slot_len[slot] = 0
        for tok in req.prompt[:-1]:
            self._step_slot(slot, tok)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        self._slot_len = getattr(self, "_slot_len", [0] * self.ecfg.max_slots)
        done: list[Request] = []
        free = [s for s in range(self.ecfg.max_slots) if s not in self._active]
        steps = 0
        while (self._queue or self._active) and steps < max_steps:
            steps += 1
            while self._queue and free:
                slot = free.pop()
                req = self._queue.pop(0)
                self._active[slot] = req
                self._prefill_into_slot(slot, req)
            if not self._active:
                continue
            for slot, req in list(self._active.items()):
                last = (req.out_tokens or req.prompt)[-1]
                nxt = self._step_slot(slot, last)
                req.out_tokens.append(int(nxt))
                if (
                    len(req.out_tokens) >= req.max_new_tokens
                    or (self.ecfg.eos_token is not None and nxt == self.ecfg.eos_token)
                ):
                    req.done = True
                    req.finished_at = time.time()
                    done.append(req)
                    del self._active[slot]
                    free.append(slot)
        return done

    def _step_slot(self, slot: int, token: int) -> int:
        """One decode step for one slot (per-slot cache view + write-back)."""
        sub_cache = jax.tree.map(
            lambda x: x[:, slot : slot + 1] if x.ndim >= 2 and x.shape[1] == self.ecfg.max_slots else x,
            self.cache,
        )
        sub_cache = sub_cache._replace(length=jnp.asarray(self._slot_len[slot], jnp.int32))
        tok = jnp.asarray([[token]], jnp.int32)
        logits, new_sub = self._decode(self.params, tok, sub_cache)

        def write_back(full, sub):
            if full.ndim >= 2 and full.shape[1] == self.ecfg.max_slots:
                return full.at[:, slot : slot + 1].set(sub)
            return full

        updated = jax.tree.map(write_back, self.cache, new_sub)
        self.cache = updated._replace(length=self.cache.length)
        self._slot_len[slot] += 1
        return int(jnp.argmax(logits[0, -1]))
