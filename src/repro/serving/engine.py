"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

Slot-based scheduler: a fixed decode batch of ``max_slots`` sequences; new
requests prefill into free slots (padded to the slot's cache), finished
sequences free their slot. All device work goes through exactly two jitted
programs (prefill_step, decode_step) so serving never recompiles — the same
programs the dry-run lowers for the decode_32k / prefill_32k cells.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclass
class EngineConfig:
    max_slots: int = 4        # concurrent sequences (decode batch)
    max_len: int = 256        # cache capacity per slot
    greedy: bool = True
    eos_token: int | None = None


class ServingEngine:
    """Single-host reference engine; the multi-pod path swaps the jitted fns
    for their pjit'd versions (same signatures — see launch/serve.py)."""

    def __init__(self, arch_cfg, params, ecfg: EngineConfig = EngineConfig()):
        self.cfg = arch_cfg
        self.ecfg = ecfg
        self.params = params
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}   # slot -> request
        self._uid = 0

        # one cache for the whole slot batch
        self.cache = model_lib.init_cache(
            arch_cfg, ecfg.max_slots, ecfg.max_len, dtype=jnp.float32
        )
        self._decode = jax.jit(
            lambda p, tok, cache: model_lib.decode_step(p, tok, cache, arch_cfg)
        )
        self._token_buf = np.zeros((ecfg.max_slots, 1), np.int32)

    # ------------------------------------------------------------ intake ---

    def submit(self, prompt: list[int], max_new_tokens: int = 16) -> int:
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens, submitted_at=time.time())
        )
        return self._uid

    # ------------------------------------------------------------- steps ---

    def _prefill_into_slot(self, slot: int, req: Request):
        """Run the prompt through decode steps into this slot's cache rows.

        Reference implementation uses per-token insertion (slot-local prefill
        with a shared cache requires per-slot lengths; the production path
        batches same-length prompts through the prefill program). Correctness
        is what matters here — tests compare against full-forward logits.
        """
        # stale cache rows beyond _slot_len are masked by the decode attention,
        # so resetting the per-slot length is sufficient. The LAST prompt
        # token is fed by the first decode step (whose logits produce the
        # first generated token), so prefill stops one short.
        self._slot_len[slot] = 0
        for tok in req.prompt[:-1]:
            self._step_slot(slot, tok)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive everything to completion (batch mode)."""
        self._slot_len = getattr(self, "_slot_len", [0] * self.ecfg.max_slots)
        done: list[Request] = []
        free = [s for s in range(self.ecfg.max_slots) if s not in self._active]
        steps = 0
        while (self._queue or self._active) and steps < max_steps:
            steps += 1
            while self._queue and free:
                slot = free.pop()
                req = self._queue.pop(0)
                self._active[slot] = req
                self._prefill_into_slot(slot, req)
            # batched decode step over active slots
            if not self._active:
                continue
            for slot, req in list(self._active.items()):
                last = (req.out_tokens or req.prompt)[-1]
                nxt = self._step_slot(slot, last)
                req.out_tokens.append(int(nxt))
                if (
                    len(req.out_tokens) >= req.max_new_tokens
                    or (self.ecfg.eos_token is not None and nxt == self.ecfg.eos_token)
                ):
                    req.done = True
                    req.finished_at = time.time()
                    done.append(req)
                    del self._active[slot]
                    free.append(slot)
        return done

    def _step_slot(self, slot: int, token: int) -> int:
        """One decode step for one slot (reference path: per-slot cache view)."""
        sub_cache = jax.tree.map(
            lambda x: x[:, slot : slot + 1] if x.ndim >= 2 and x.shape[1] == self.ecfg.max_slots else x,
            self.cache,
        )
        sub_cache = sub_cache._replace(length=jnp.asarray(self._slot_len[slot], jnp.int32))
        tok = jnp.asarray([[token]], jnp.int32)
        logits, new_sub = self._decode(self.params, tok, sub_cache)

        def write_back(full, sub):
            if full.ndim >= 2 and full.shape[1] == self.ecfg.max_slots:
                return full.at[:, slot : slot + 1].set(sub)
            return full

        updated = jax.tree.map(write_back, self.cache, new_sub)
        self.cache = updated._replace(length=self.cache.length)
        self._slot_len[slot] += 1
        return int(jnp.argmax(logits[0, -1]))
