"""Elastic self-speculative decoding on the paged serving engine.

SALAAD's elasticity claim — one training run yields a continuous spectrum of
deployable capacities (HPA, §4.3) — means every deployment already ships its
own draft model: a low-HPA-budget truncation of the SAME SLR weights. This
module turns that spectrum into decode throughput. A cheap draft proposes
``k`` tokens per slot per tick; the full-budget target model scores all ``k``
positions of all active slots in ONE k-wide paged verify pass; exact
(rejection-sampled) acceptance keeps the emitted distribution identical to
the target model's own sampling. The entire tick — k draft decode steps, the
k-wide verify, acceptance, and KV rollback — is ONE jitted device program, so
an accepted burst of k tokens costs the same host/device round-trip budget as
a single non-speculative decode step.

Two draft schedules share the verify/rollback machinery (per tick, context
length n, last emitted token ``t_last``):

``parallel`` (greedy default) — both models run ONE k-wide forward over the
same guess window, so a tick costs ~2 forwards regardless of k:

  window:  [t_last, g_1, .., g_{k-1}] — g_i are the draft's predictions from
           the PREVIOUS tick (zeros on a fresh slot; they warm up in one tick)
  verify:  target forward over the window -> greedy chain t_0..t_{k-1};
           guess g_i is confirmed iff g_i == t_{i-1} (prefix-cumulative, so
           every confirmed token is conditioned on real context only);
           emitted = confirmed guesses + t_a (the target's own next token) —
           between 1 and k tokens from one target forward
  draft:   draft forward over the SAME window -> prediction chain d_0..d_{k-1};
           the host re-aligns it as next tick's guesses
  This is Jacobi-style lookahead with the elastic low-budget deployment as
  the guess generator: the draft's agreement with the target is exactly what
  makes guesses survive verification.

``sequential`` (sampled default) — the draft autoregresses k proposals
(k single-token decodes inlined in the same program), the target verifies
the proposal window k-wide, and exact rejection sampling
(:func:`rejection_sample`) preserves the target distribution token-for-token.

Either way, target KV for the k window positions lands at n..n+k-1 of the
target pools, draft KV in the draft pools, and both caches share ONE block
table + length vector — so rollback is a per-slot length reset to
n + emitted, and rejected positions are simply overwritten by the next
tick's k-wide insert (which starts exactly at the new length).

The draft KV lives in its own (smaller, ``spec_draft_kv_dtype``) page pools
but shares the target's block table and allocator, so admission, page growth,
eviction, and resume are inherited from :class:`PagedServingEngine`
unchanged — one allocation covers both caches.

Acceptance-rate feedback (``spec_adaptive``) reuses the integral-controller
style of ``core/controller.py``: the draft window k integrates the tracking
error between observed per-slot acceptance and a target rate, clamped to
[1, spec_k]. k is a static shape, so adaptation retraces at most
``spec_k`` distinct programs over an engine's lifetime.
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..models import model as model_lib
from ..models import transformer as transformer_lib
from .deployed import DeployedModel
from .elastic import ModelBank
from .engine import (
    EngineCapabilityError,
    EngineConfig,
    PagedServingEngine,
    Request,
    RequestRejected,
)

__all__ = [
    "SpeculativeEngine",
    "SpecController",
    "SpecControllerConfig",
    "rejection_sample",
]

_DRAFT_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "bf16": jnp.bfloat16,
}

_SLOT_EMA = 0.8   # per-slot acceptance smoothing (feeds the k controller)


def _copy_draft_pools(pools, src, dst):
    """Copy-on-write for the draft page pools: draft KV rides the target's
    block table AND page ids, so the same (src, dst) pairs that privatized a
    shared target page must privatize its draft twin — payload and, when the
    draft pool is int8, both scale pools."""
    k, v, k_s, v_s = pools
    k = ops.page_copy(k, src, dst)
    v = ops.page_copy(v, src, dst)
    if k_s is not None:
        k_s = ops.page_copy(k_s, src, dst)
        v_s = ops.page_copy(v_s, src, dst)
    return (k, v, k_s, v_s)


# ------------------------------------------------------- rejection sampling ---


def rejection_sample(
    key: jax.Array,
    drafts: jax.Array,        # (S, k) int32 — draft proposals d_1..d_k
    draft_probs: jax.Array,   # (S, k, V) — p_i: draft dist at each position
    target_probs: jax.Array,  # (S, k, V) — q_i: target dist at each position
) -> tuple[jax.Array, jax.Array]:
    """Exact speculative rejection sampling (Leviathan et al. '23 scheme).

    Position i accepts d_{i+1} with probability min(1, q_i(d)/p_i(d)); the
    first rejected position resamples from the residual norm(max(q_i - p_i,
    0)), which makes every emitted token exactly target-distributed. Returns
    ``(out, accepted)``: ``out[:, :a]`` are the accepted drafts, ``out[:, a]``
    the corrective token when ``a < k``; entries past that are padding. Each
    slot consumes its own PRNG stream (slot id folded into the key), so
    per-slot acceptance never correlates across the batch.

    When p == q the ratio is 1 and u < 1 always accepts — identical draft and
    target models accept all k tokens deterministically.
    """
    s, k = drafts.shape
    p_tok = jnp.take_along_axis(draft_probs, drafts[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(target_probs, drafts[..., None], axis=-1)[..., 0]
    ku, kr = jax.random.split(key)
    slot_ids = jnp.arange(s)
    u = jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(ku, i), (k,))
    )(slot_ids)
    accept = u < jnp.minimum(q_tok / jnp.maximum(p_tok, 1e-30), 1.0)
    acc = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    a = jnp.sum(acc, axis=1)                                   # (S,) in 0..k

    # residual distribution at the first rejected position (clamped index is
    # only read when a < k); p == q everywhere degenerates the residual to 0 —
    # fall back to q itself (any sample there is already target-distributed)
    ai = jnp.minimum(a, k - 1)
    q_a = jnp.take_along_axis(target_probs, ai[:, None, None], axis=1)[:, 0]
    p_a = jnp.take_along_axis(draft_probs, ai[:, None, None], axis=1)[:, 0]
    resid = jnp.maximum(q_a - p_a, 0.0)
    tot = jnp.sum(resid, axis=-1, keepdims=True)
    resid = jnp.where(tot > 0, resid / jnp.where(tot > 0, tot, 1.0), q_a)
    corrective = jax.vmap(
        lambda i, pr: jax.random.categorical(
            jax.random.fold_in(kr, i), jnp.log(jnp.maximum(pr, 1e-38))
        )
    )(slot_ids, resid)

    idx = jnp.arange(k)[None, :]
    out = jnp.where(
        idx < a[:, None],
        drafts,
        jnp.where(idx == a[:, None], corrective[:, None].astype(drafts.dtype), 0),
    )
    return out.astype(jnp.int32), a.astype(jnp.int32)


# --------------------------------------------------------------- controller ---


@dataclass(frozen=True)
class SpecControllerConfig:
    target_accept: float = 0.7  # per-draft-token acceptance the window aims at
    gain: float = 2.0           # integral gain: tokens of k per unit error
    ema: float = 0.8            # smoothing of the observed acceptance rate


class SpecController:
    """I-controller over the draft window k (``core/controller.py`` style).

    Integrates the tracking error between the observed (EMA-smoothed)
    acceptance rate and the target:  k_f <- clip(k_f + gain * (acc - target),
    1, k_max).  High acceptance grows the window (each verify amortizes more
    tokens); low acceptance shrinks it (rejected drafts are wasted draft AND
    verify compute). The float state is quantized to an int k at read time so
    the engine compiles at most ``k_max`` distinct programs.
    """

    def __init__(self, k_init: int, k_max: int, k_min: int = 1,
                 cfg: SpecControllerConfig = SpecControllerConfig()):
        self.cfg = cfg
        self.k_min = int(k_min)     # parallel schedule floors at 2: a k=1
        #                             window has no verifiable guess, so the
        #                             acceptance signal would latch at 0
        self.k_max = int(k_max)
        self.k_f = float(k_init)
        self.accept_ema = cfg.target_accept     # neutral start: no transient

    @property
    def k(self) -> int:
        return int(round(self.k_f))

    def update(self, accept_rate: float) -> int:
        c = self.cfg
        self.accept_ema = c.ema * self.accept_ema + (1.0 - c.ema) * accept_rate
        self.k_f = float(
            np.clip(self.k_f + c.gain * (self.accept_ema - c.target_accept),
                    self.k_min, self.k_max)
        )
        return self.k


# -------------------------------------------------------------------- engine ---


class SpeculativeEngine(PagedServingEngine):
    """Paged engine with elastic self-speculation: a low-budget draft of the
    same SLR weights proposes k tokens per slot, the full-budget target
    verifies them all in one jitted k-wide paged step.

    The draft/target pair is TWO TIERS of one :class:`~repro.serving.elastic.
    ModelBank` — the elastic spectrum's two ends: ``ecfg.spec_target_tier``
    (default 0, the largest capacity) verifies, ``ecfg.spec_draft_tier``
    (default -1, the cheapest) drafts. Both tiers share the architecture
    config, so the draft KV pages have identical geometry and ride the
    target's block table. Greedy decoding emits token streams identical to
    the non-speculative paged engine; sampled decoding preserves the target
    distribution exactly via :func:`rejection_sample`.
    """

    _speculative = True

    def __init__(self, model, params=None, draft_params=None,
                 ecfg: EngineConfig | None = None):
        if isinstance(model, (ModelBank, DeployedModel)):
            if draft_params is not None or (
                params is not None and ecfg is not None
            ):
                raise TypeError(
                    "SpeculativeEngine(bank, ecfg): the draft comes from the "
                    "bank (ecfg.spec_draft_tier), not a separate argument"
                )
            cfg_arg = params if params is not None else ecfg
            if cfg_arg is not None and not isinstance(cfg_arg, EngineConfig):
                raise TypeError(
                    "SpeculativeEngine(bank, ecfg): second argument must be "
                    f"an EngineConfig, got {type(cfg_arg).__name__}"
                )
            bank = model if isinstance(model, ModelBank) \
                else ModelBank.single(model.cfg, model)
            ecfg = cfg_arg if cfg_arg is not None else EngineConfig()
        else:
            raise TypeError(
                "SpeculativeEngine(arch_cfg, target_params, draft_params, "
                "ecfg) was removed: build a ModelBank (serving/elastic.py) "
                "whose tiers carry the target and draft budgets and "
                "construct SpeculativeEngine(bank, ecfg)"
            )
        if ecfg.spec_k < 1:
            raise ValueError(
                f"SpeculativeEngine needs spec_k >= 1, got {ecfg.spec_k}"
            )
        if ecfg.tier_policy == "pressure":
            # every slot is pinned to the target tier (_effective_tier), so
            # the inherited controller's downshift would be a silent no-op —
            # fail loudly instead of reporting downshifts that never happen
            raise EngineCapabilityError(
                "SpeculativeEngine serves every slot at its target tier; the "
                "page-pressure tier controller (tier_policy='pressure') "
                "needs PagedServingEngine. Engine capabilities: "
                f"{json.dumps(self.capabilities(), sort_keys=True)}"
            )
        greedy = ecfg.greedy or ecfg.temperature <= 0
        if ecfg.spec_draft_mode == "auto":
            # a k=1 parallel window carries no verifiable guess (two forwards
            # per tick to emit one token) — degenerate; sequential k=1 at
            # least verifies one real proposal
            self._parallel = greedy and ecfg.spec_k >= 2
        elif ecfg.spec_draft_mode in ("parallel", "sequential"):
            self._parallel = ecfg.spec_draft_mode == "parallel"
        else:
            raise ValueError(
                f"unknown spec_draft_mode {ecfg.spec_draft_mode!r}"
            )
        if self._parallel and not greedy:
            raise ValueError(
                "the parallel draft schedule verifies greedy guess chains; "
                "temperature sampling needs spec_draft_mode='sequential' "
                "(exact rejection sampling over autoregressive proposals)"
            )
        if self._parallel and ecfg.spec_k < 2:
            raise ValueError(
                "the parallel draft schedule needs spec_k >= 2 (a k=1 window "
                "has no verifiable guess); use spec_draft_mode='sequential'"
            )
        super().__init__(bank, ecfg)
        try:
            self._target_tier = bank.resolve(ecfg.spec_target_tier)
            self._draft_tier = bank.resolve(ecfg.spec_draft_tier)
        except ValueError as e:
            raise ValueError(f"spec tier: {e}") from None
        # every slot serves at the target tier; the bank's cheap end drafts
        self._default_tier = self._target_tier
        self.params = self._tier_params[self._target_tier]
        self.draft_params = self._tier_params[self._draft_tier]

        quantized = ecfg.spec_draft_kv_dtype == "int8"
        if not quantized and ecfg.spec_draft_kv_dtype not in _DRAFT_DTYPES:
            raise ValueError(
                f"unknown spec_draft_kv_dtype {ecfg.spec_draft_kv_dtype!r}"
            )
        dcache = model_lib.init_paged_cache(
            self.cfg, ecfg.max_slots, self.num_blocks, self._bs, self._nb_slot,
            dtype=jnp.float32 if quantized
            else _DRAFT_DTYPES[ecfg.spec_draft_kv_dtype],
            quantized=quantized,
        )
        # draft pools share the target's block table + lengths; only the
        # payload (and scale) pools persist host-side between ticks
        dpools = (dcache.k, dcache.v, dcache.k_scale, dcache.v_scale)
        if self.mesh is not None:
            # draft pools shard the head axis over 'model' exactly like the
            # target's pools (same block ids, head-replicated bookkeeping)
            dpools = jax.device_put(dpools, self.mesh.cache_shardings(dpools))
        self._dpools = dpools

        self._k = ecfg.spec_k
        self._write_window = self._k          # _pre_decode covers k positions
        self.controller = (
            SpecController(
                k_init=ecfg.spec_k, k_max=ecfg.spec_k,
                k_min=2 if self._parallel and ecfg.spec_k >= 2 else 1,
            )
            if ecfg.spec_adaptive else None
        )

        # acceptance accounting (benchmarks + adaptive feedback): drafted /
        # accepted live in the metrics registry (see the properties below);
        # the ADAPTIVE feedback reads only the plain host-side _accept_ema,
        # so telemetry=False never changes scheduling behavior
        self.spec_ticks = 0
        self._accept_ema = np.full((ecfg.max_slots,), np.nan)
        # parallel schedule: per-slot guess window for the NEXT tick (host
        # mirror of the draft's latest prediction chain; zeros = no guess)
        self._guess = np.zeros((ecfg.max_slots, max(ecfg.spec_k - 1, 0)), np.int32)

        self._spec = jax.jit(
            self._spec_seq_fn, static_argnames=("k",), donate_argnums=(3, 4),
        )
        self._spec_par = jax.jit(
            self._spec_parallel_fn, donate_argnums=(3, 4),
        )
        self._prefill2 = jax.jit(self._prefill2_fn, donate_argnums=(6, 7))
        self._chunk2 = jax.jit(self._chunk2_fn, donate_argnums=(6, 7))
        self._dcopy = jax.jit(_copy_draft_pools, donate_argnums=(0,))

    @classmethod
    def capabilities(cls) -> dict:
        caps = PagedServingEngine.capabilities.__func__(cls)
        caps["kv"] = "paged + draft pools"
        caps["features"].update(
            speculative=True,
            # the bank's tiers ARE in play — as the fixed target/draft pair —
            # but per-REQUEST tier pinning and the pressure controller are
            # not: every slot verifies at spec_target_tier
            elastic_tiers=False,
            tier_pressure_controller=False,
            multi_tenant_adapters=False,
        )
        return caps

    def _resolve_tier(self, tier: int | None) -> int:
        """Every slot serves at the verify target's tier; a request pinned
        elsewhere would silently verify at the wrong capacity — fail loudly
        instead (the 'never silently drop a requested feature' convention).
        Like every engine, submit-time tier errors are RequestRejected."""
        if tier is None:
            return self._target_tier
        try:
            t = self.bank.resolve(tier)
        except ValueError as e:
            raise RequestRejected(str(e)) from None
        if t == self._target_tier:
            return t
        raise EngineCapabilityError(
            f"SpeculativeEngine serves every slot at its target tier "
            f"{self._target_tier} (spec_target_tier); per-request tiers need "
            f"PagedServingEngine. Requested tier: {tier}"
        )

    def _effective_tier(self, req: Request) -> int:
        return self._target_tier

    # ------------------------------------------------------------- metrics ---

    @property
    def drafted_tokens(self) -> int:
        """Draft proposals offered to the verifier, lifetime (registry-backed
        view over serve_spec_tokens_total{kind="drafted"})."""
        return int(self.metrics.counter_value(self.metrics.spec_tokens,
                                              "drafted"))

    @property
    def accepted_tokens(self) -> int:
        """Draft proposals the verifier accepted, lifetime."""
        return int(self.metrics.counter_value(self.metrics.spec_tokens,
                                              "accepted"))

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens accepted by the verifier, lifetime."""
        return self.accepted_tokens / max(self.drafted_tokens, 1)

    @property
    def slot_acceptance(self) -> np.ndarray:
        """Per-slot EMA acceptance rate (nan = slot never speculated)."""
        return self._accept_ema.copy()

    # ----------------------------------------------------- device programs ---

    def _prefill2_fn(self, tparams, dparams, tokens, lengths, slot_ids,
                     page_map, cache, dpools, step):
        """Admission prefill for BOTH caches in one program: the prompt runs
        through the target (yielding the first sampled token, exactly like
        the non-speculative engine) and through the draft, each scattering
        whole prompt blocks into its own page pools."""
        self.prefill_traces += 1
        logits, kvs, _ = model_lib._forward(
            tparams, {"tokens": tokens}, self.cfg, collect_kv=True
        )
        cache = transformer_lib.scatter_prefill_pages(cache, kvs, page_map)
        new_len = cache.length.at[slot_ids].set(lengths, mode="drop")
        last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)
        first_tok = self._sample(last[:, 0], step, salt=1, slots=slot_ids)
        cache = cache._replace(length=new_len)

        _, dkvs, _ = model_lib._forward(
            dparams, {"tokens": tokens}, self.cfg, collect_kv=True
        )
        dcache = transformer_lib.PagedKVCache(
            dpools[0], dpools[1], cache.block_table, new_len,
            dpools[2], dpools[3],
        )
        dcache = transformer_lib.scatter_prefill_pages(dcache, dkvs, page_map)
        return first_tok, cache, (dcache.k, dcache.v, dcache.k_scale, dcache.v_scale)

    def _chunk2_fn(self, tparams, dparams, tokens, counts, slot_ids, starts,
                   cache, dpools, step):
        """Chunked-prefill tick for BOTH caches in one program: the target
        side is the base engine's ``_chunk_target`` verbatim (sampling the
        next token where a prompt ends, exactly like the one-shot
        ``_prefill2_fn``), then the draft runs the same chunk at the SAME
        pre-chunk lengths through the shared block table, so the two caches
        stay position-aligned chunk by chunk."""
        self.chunk_traces += 1
        tok, cache, n0 = self._chunk_target(
            tparams, tokens, counts, slot_ids, starts, cache, step
        )
        dcache = transformer_lib.PagedKVCache(
            dpools[0], dpools[1], cache.block_table, n0, dpools[2], dpools[3]
        )
        _, dcache = model_lib.chunk_prefill_step(
            dparams, tokens, counts, dcache, self.cfg
        )
        return tok, cache, (dcache.k, dcache.v, dcache.k_scale, dcache.v_scale)

    def _spec_parallel_fn(self, tparams, dparams, window, cache, dpools,
                          active, step):
        """ONE parallel-schedule tick: a k-wide target verify of the guess
        window plus a k-wide draft forward that produces next tick's guesses —
        ~2 forwards per tick however large k is. Greedy only (the emitted
        chain is the target's own argmax chain by construction). Returns
        (out (S, k), guesses (S, k), emitted (S,), confirmed (S,), cache,
        dpools)."""
        self.decode_traces += 1
        k = window.shape[1]
        n0 = cache.length
        dcache = transformer_lib.PagedKVCache(
            dpools[0], dpools[1], cache.block_table, n0, dpools[2], dpools[3]
        )

        # target verify over [t_last, g_1..g_{k-1}]: position i's greedy token
        # t_i is the target's prediction for position n+i+1
        logits, cache = model_lib.decode_step(tparams, window, cache, self.cfg)
        t_chain = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (S, k)
        # guess g_{i+1} is confirmed iff it equals t_i AND every earlier guess
        # was confirmed — so each confirmed token saw only real context
        conf = (window[:, 1:] == t_chain[:, :-1]).astype(jnp.int32)  # (S, k-1)
        a = jnp.sum(jnp.cumprod(conf, axis=1), axis=1) if k > 1 else \
            jnp.zeros((window.shape[0],), jnp.int32)
        # emitted = confirmed guesses + the target's own next token t_a; the
        # confirmed guesses ARE t_0..t_{a-1}, so the output is just t_chain
        m = jnp.where(active, a + 1, 0).astype(jnp.int32)

        # draft forward over the REFINED window [t_last, t_0..t_{k-2}]: the
        # verify chain is real for all confirmed positions, so the draft's
        # prediction d_m (the first guess the next tick needs) is conditioned
        # on the full accepted prefix including the corrective token t_a
        d_window = jnp.concatenate([window[:, :1], t_chain[:, :-1]], axis=1)
        dlogits, dcache = model_lib.decode_step(dparams, d_window, dcache, self.cfg)
        d_chain = jnp.argmax(dlogits, axis=-1).astype(jnp.int32)     # (S, k)

        cache = cache._replace(length=n0 + m)       # rollback = length reset
        return t_chain, d_chain, m, a, cache, (
            dcache.k, dcache.v, dcache.k_scale, dcache.v_scale
        )

    def _spec_seq_fn(self, tparams, dparams, tokens, cache, dpools, active, step, *, k):
        """ONE sequential-schedule tick on device: k draft steps, one k-wide
        verify, acceptance, rollback. Returns (out (S, k), emitted (S,),
        accepted (S,), cache, dpools)."""
        self.decode_traces += 1
        greedy = self.ecfg.greedy or self.ecfg.temperature <= 0
        n0 = cache.length
        dcache = transformer_lib.PagedKVCache(
            dpools[0], dpools[1], cache.block_table, n0, dpools[2], dpools[3]
        )

        # ---- draft: k sequential single-token decodes (inlined in-program) --
        def draft_step(carry, i):
            tok, dc = carry
            logits, dc = model_lib.decode_step(dparams, tok, dc, self.cfg)
            lg = logits[:, -1]
            nxt = self._sample(lg, step, salt=2 + i)
            probs = (
                None if greedy
                else jax.nn.softmax(
                    lg.astype(jnp.float32) / self.ecfg.temperature, axis=-1
                )
            )
            return (nxt[:, None], dc), (nxt, probs)

        (_, dcache), (drafts_k, dprobs_k) = jax.lax.scan(
            draft_step, (tokens, dcache), jnp.arange(k)
        )
        drafts = drafts_k.T                                     # (S, k)

        # ---- verify: ONE k-wide paged forward through the target ----------
        vtoks = jnp.concatenate([tokens, drafts[:, : k - 1]], axis=1)  # (S, k)
        logits, cache = model_lib.decode_step(tparams, vtoks, cache, self.cfg)

        if greedy:
            out = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (S, k)
            acc = jnp.cumprod((drafts == out).astype(jnp.int32), axis=1)
            a = jnp.sum(acc, axis=1)
        else:
            qprobs = jax.nn.softmax(
                logits.astype(jnp.float32) / self.ecfg.temperature, axis=-1
            )
            dprobs = jnp.transpose(dprobs_k, (1, 0, 2))          # (S, k, V)
            key = jax.random.fold_in(
                jax.random.fold_in(self._base_key, step), 2 + k
            )
            out, a = rejection_sample(key, drafts, dprobs, qprobs)

        # a < k: a accepted drafts + 1 corrective token; a == k: all k drafts
        # (no bonus position — the k-th draft's KV is nowhere yet, it simply
        # becomes the next tick's t_last, keeping both caches exactly aligned)
        m = jnp.where(a < k, a + 1, k).astype(jnp.int32)
        m = jnp.where(active, m, 0)
        new_len = jnp.where(active, n0 + m, n0)     # rollback = length reset
        cache = cache._replace(length=new_len)
        return out, m, a, cache, (dcache.k, dcache.v, dcache.k_scale, dcache.v_scale)

    # ------------------------------------------------------------- steps ---

    def _prefill_admitted(self, tokens, lengths, slot_ids, page_map, step,
                          tier: int = 0, rows=None):
        # `tier` is the base engine's grouping hook; here it is always the
        # target tier (the draft prefills alongside in the same program).
        # `rows` is the adapter-pool map — always None here: _init_common
        # rejects AdapterBanks on speculative engines
        del rows
        with self.metrics.measure_program(
            f"prefill[{tokens.shape[1]}]", tier,
            traces=lambda: self.prefill_traces,
        ):
            first, self.cache, self._dpools = self._prefill2(
                self.params, self.draft_params, jnp.asarray(tokens),
                jnp.asarray(lengths), jnp.asarray(slot_ids),
                jnp.asarray(page_map), self.cache, self._dpools,
                jnp.asarray(step, jnp.int32),
            )
            self.prefill_calls += 1
            return np.asarray(first)

    def _chunk_call(self, tokens, counts, slot_ids, starts, step,
                    tier: int = 0):
        with self.metrics.measure_program(
            f"chunk[{tokens.shape[1]}]", tier,
            traces=lambda: self.chunk_traces,
        ):
            first, self.cache, self._dpools = self._chunk2(
                self.params, self.draft_params, jnp.asarray(tokens),
                jnp.asarray(counts), jnp.asarray(slot_ids),
                jnp.asarray(starts), self._device_cache(), self._dpools,
                jnp.asarray(step, jnp.int32),
            )
            self.chunk_calls += 1
            return np.asarray(first)

    def _release(self, slot: int):
        super()._release(slot)
        self._guess[slot, :] = 0        # fresh/resumed slots restart guessing
        self._accept_ema[slot] = np.nan  # ... and restart their rate estimate

    def _apply_cow(self, src, dst):
        super()._apply_cow(src, dst)
        self._dpools = self._dcopy(self._dpools, src, dst)

    def _decode_tick(self, active, free, done):
        """ONE speculative tick's device portion: the jitted draft + k-wide
        verify over all slots — up to k tokens per slot for a single
        host/device round trip. (Admission/page growth ran in the shared
        ``step()`` skeleton; ``_write_window`` sized growth for k writes.)"""
        s = self.ecfg.max_slots
        k = self._k
        tokens = np.zeros((s, k if self._parallel else 1), np.int32)
        for slot in self._active:
            if slot in self._progress:   # mid-prefill slots don't decode
                continue
            tokens[slot, 0] = self._last_token[slot]
            if self._parallel:
                tokens[slot, 1:] = self._guess[slot, : k - 1]
        step_arr = jnp.asarray(self._steps, jnp.int32)
        with self.metrics.measure_program(
            f"spec_decode[k={k}]", self._target_tier,
            traces=lambda: self.decode_traces,
        ):
            if self._parallel:
                out, guesses, emitted, accepted, self.cache, self._dpools = \
                    self._spec_par(
                        self.params, self.draft_params, jnp.asarray(tokens),
                        self._device_cache(), self._dpools,
                        jnp.asarray(active), step_arr,
                    )
                guess_np = np.asarray(guesses)
                drafted = max(k - 1, 1)  # k-1 verifiable guesses per window
            else:
                out, emitted, accepted, self.cache, self._dpools = self._spec(
                    self.params, self.draft_params, jnp.asarray(tokens),
                    self._device_cache(), self._dpools, jnp.asarray(active),
                    step_arr, k=k,
                )
                guess_np = None
                drafted = k
            self.decode_calls += 1
            out_np = np.asarray(out)                # ONE host sync per tick
            emitted_np = np.asarray(emitted)
            accepted_np = np.asarray(accepted)

        ema_sum = 0.0
        n_active = 0
        tick_drafted = 0
        tick_accepted = 0
        tr = self.tracer
        for slot, req in list(self._active.items()):
            if slot in self._progress:   # drafted nothing this tick
                continue
            n_active += 1
            m = int(emitted_np[slot])
            rate = float(accepted_np[slot]) / drafted
            prev = self._accept_ema[slot]
            self._accept_ema[slot] = (
                rate if np.isnan(prev)
                else _SLOT_EMA * prev + (1.0 - _SLOT_EMA) * rate
            )
            ema_sum += self._accept_ema[slot]
            tick_drafted += drafted
            tick_accepted += int(accepted_np[slot])
            if tr is not None:
                tr.instant(slot, "spec_accept", uid=req.uid, drafted=drafted,
                           accepted=int(accepted_np[slot]), emitted=m)
            if guess_np is not None:
                # d_chain[i] predicts position n+i+1; next window starts at
                # n+m, so its guesses are d_chain[m:]; the tail (positions the
                # draft has not seen yet) falls back to no-guess zeros
                tail = guess_np[slot, m : m + k - 1]
                self._guess[slot, : len(tail)] = tail
                self._guess[slot, len(tail):] = 0
            for j in range(m):
                if req.done:
                    break                           # max_new/eos mid-burst
                self._record(slot, req, int(out_np[slot, j]), free, done)
        self.spec_ticks += 1
        if n_active:
            self.metrics.on_spec_tick(tick_drafted, tick_accepted,
                                      ema_sum / n_active, self._k)
        if self.controller is not None and n_active:
            # the window integrates the observed PER-SLOT acceptance (EMA per
            # slot, mean over currently-active slots)
            self._k = self.controller.update(ema_sum / n_active)
            self._write_window = self._k
