"""Multi-tenant adapter serving: one shared base, N registered (L+S) adapters.

SALAAD's factored deployment form is structurally a LoRA-style delta over a
shared dense base — ``W ~= W_base``-preserving leaves plus per-tenant
``(P, Vt, S)`` tables at the selected linear sites. One pool of serving
hardware should therefore serve *many* fine-tuned adapters from one base,
with per-request adapter selection, not just budget tiers of one model.

``AdapterRegistry``
    Host-side bookkeeping: ``register``/``unregister`` of deployed adapter
    models over one base :class:`~repro.serving.deployed.DeployedModel`. The
    base defines the *site schema* — which param-tree paths are per-adapter
    (the SLR sites) — and every registered adapter must match the base
    everywhere else (the shared pytree is stored ONCE).

``AdapterizedLinear``
    One pooled linear site as a registered pytree: every resident adapter's
    padded tables stacked over a leading adapter axis (rank padded to a
    common MAXR, sparse tables to a common MAXB/cap — padding is exact:
    zero rank columns and dead BSR slots contribute nothing), plus a ``sel``
    leaf the bank re-binds per program call. Two modes:

      * ``batched`` (fused format): ``sel`` is a per-slot ``(S,)`` row map
        and ONE ``kernels.ops.slr_matmul_multi`` call serves slots running
        different adapters — the adapter gather lives in the kernel's
        scalar-prefetched DMA index maps, one compiled program for any
        slot→adapter assignment.
      * ``grouped`` (dense/factored fallback, and any shape the batched
        kernel rejects): ``sel`` is a scalar pool row and the scheduler runs
        one program per distinct resident adapter — op-for-op identical to
        the single-tenant tier path, so a single-adapter bank is
        bitwise-indistinguishable from a plain ``ModelBank`` tier.

``AdapterBank``
    A single-tier :class:`~repro.serving.elastic.ModelBank` whose tier params
    are the pooled tree. It owns a fixed-capacity on-device adapter pool
    (``max_resident`` rows) with LRU residency: ``acquire`` swaps a
    non-resident adapter's host tables into a pool row (a pure ``.at[].set``
    — shapes are frozen at :meth:`materialize`, so swaps never retrace),
    ``pin``/``unpin`` track streaming slots so LRU never evicts an adapter
    mid-request, and ``bind`` stamps ``sel`` into every pooled site for one
    program call (a data-only rebind: zero retraces across adapter switches).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import sparse
from ..kernels.slr_matmul import BsrStack
from .deployed import DeployedModel, _LINEAR_KEYS
from .elastic import ModelBank, Tier
from .slr_params import SLRLinear

__all__ = [
    "AdapterBank",
    "AdapterError",
    "AdapterRegistry",
    "AdapterizedLinear",
    "adapterize",
]


class AdapterError(RuntimeError):
    """Adapter lifecycle violation (unknown id, unregister-while-streaming,
    post-freeze registration that exceeds the pool's padded dims)."""


# ------------------------------------------------------------ pooled site ---


@dataclass
class AdapterizedLinear:
    """One pooled linear site: per-adapter tables stacked over a leading
    adapter axis + the ``sel`` leaf the bank binds per program call.

    Stacked fused sites flatten (adapter, layer) into one leading axis of
    size ``A * L`` — the kernels stay adapter/layer-agnostic and callers
    index row ``sel * L + layer``.
    """

    w: jax.Array | None            # dense fmt: (A, [L,] n, m)
    p: jax.Array | None            # (A*L | A, n, MAXR) fused; (A, [L,] n, MAXR) factored
    vt: jax.Array | None
    s_coo: sparse.CooMatrix | None  # factored fmt: values/idx (A, [L,] cap)
    s_stack: BsrStack | None       # fused fmt: leading A*L (stacked) or A
    sel: jax.Array | None          # (S,) batched / () grouped — bound per call
    shape: tuple[int, int]
    fmt: str                       # 'dense' | 'factored' | 'fused'
    mode: str                      # 'batched' | 'grouped'
    stacked: bool                  # site lives inside the layer scan
    layers: int                    # L (1 for unstacked sites)

    # ---- transformer integration: duck-typed like SLRLinear ----

    @property
    def scan_by_index(self) -> bool:
        """Stacked sites must not be sliced as scan xs (that would copy the
        whole pool out of HBM per layer) — the forward scans layer indices
        and takes :meth:`at_layer` views, exactly like fused SLRLinears."""
        return self.stacked

    def at_layer(self, layer) -> "_AdapterLayerView":
        assert self.stacked
        return _AdapterLayerView(self, layer)

    def apply(self, x: jax.Array, kernel: bool | None = None) -> jax.Array:
        """Unstacked sites (e.g. a selected LM head) apply directly."""
        return self._apply(x, None)

    @property
    def dtype(self):
        for part in (self.w, self.p,
                     self.s_coo and self.s_coo.values,
                     self.s_stack and self.s_stack.vals):
            if part is not None:
                return part.dtype
        return jnp.float32

    def with_sel(self, sel) -> "AdapterizedLinear":
        return replace(self, sel=sel)

    # ---- apply paths ----

    def _apply(self, x: jax.Array, layer) -> jax.Array:
        assert self.sel is not None, "AdapterBank.bind() must run per call"
        if self.mode == "grouped":
            return self._apply_grouped(x, layer)
        return self._apply_batched(x, layer)

    def _apply_batched(self, x: jax.Array, layer) -> jax.Array:
        # fused only: one multi-adapter kernel pass, slots pick their adapter
        from ..kernels.ops import slr_matmul_multi

        assert x.ndim == 3, x.shape
        ids = self.sel
        if self.stacked:
            ids = ids * self.layers + layer
        return slr_matmul_multi(x, self.p, self.vt, self.s_stack, ids)

    def _apply_grouped(self, x: jax.Array, layer) -> jax.Array:
        # every program call serves ONE adapter (scalar sel): index the pool
        # and run the exact single-tenant ops — bitwise-parity path
        a = self.sel

        def idx(t):
            return jax.lax.dynamic_index_in_dim(t, a, keepdims=False)

        def idx_l(t):
            out = idx(t)
            if layer is not None:
                out = jax.lax.dynamic_index_in_dim(out, layer, keepdims=False)
            return out

        if self.fmt == "dense":
            return x @ idx_l(self.w)
        if self.fmt == "factored":
            y = None
            if self.p is not None:
                y = (x @ idx_l(self.p)) @ idx_l(self.vt)
            if self.s_coo is not None:
                coo = sparse.CooMatrix(
                    idx_l(self.s_coo.values), idx_l(self.s_coo.idx),
                    self.s_coo.shape,
                )
                s_dense = sparse.to_dense(coo).astype(x.dtype)
                y = x @ s_dense if y is None else y + x @ s_dense
            if y is None:
                y = jnp.zeros((*x.shape[:-1], self.shape[1]), x.dtype)
            return y
        # fused
        from ..kernels.ops import slr_matmul, slr_matmul_stacked

        flat = x.reshape(-1, x.shape[-1])
        if self.stacked:
            lid = a * self.layers + layer
            y = slr_matmul_stacked(flat, self.p, self.vt, self.s_stack, lid)
        else:
            p = None if self.p is None else idx(self.p)
            vt = None if self.vt is None else idx(self.vt)
            bsr = None if self.s_stack is None else self.s_stack.at_layer(a)
            y = slr_matmul(flat, p, vt, bsr)
        return y.reshape(*x.shape[:-1], self.shape[1])


jax.tree_util.register_dataclass(
    AdapterizedLinear,
    data_fields=["w", "p", "vt", "s_coo", "s_stack", "sel"],
    meta_fields=["shape", "fmt", "mode", "stacked", "layers"],
)


class _AdapterLayerView:
    """Layer ``l`` of a stacked pooled site — deliberately NOT a pytree,
    built inside the layer-scan body like ``SLRLayerView``."""

    __slots__ = ("lin", "layer")

    def __init__(self, lin: AdapterizedLinear, layer):
        self.lin = lin
        self.layer = layer

    def apply(self, x: jax.Array) -> jax.Array:
        return self.lin._apply(x, self.layer)

    @property
    def dtype(self):
        return self.lin.dtype


def adapterize(base: DeployedModel, model: DeployedModel) -> DeployedModel:
    """Normalize a fine-tuned deployment into a registrable adapter: the
    model's tree with every NON-SITE leaf replaced by the base's leaf.

    SALAAD selection may cover non-linear blocks (e.g. the embedding), which
    deploy as materialized dense leaves that differ per fine-tune; a
    multi-tenant bank shares those with the base — only the linear sites
    carry per-adapter tables. Parity is defined against the RETURNED model
    (it is what the bank actually serves), so single-tenant references in
    tests/benchmarks must use it too.
    """
    if model.fmt != base.fmt:
        raise AdapterError(
            f"adapter fmt {model.fmt!r} != base fmt {base.fmt!r}"
        )
    is_slr = lambda x: isinstance(x, SLRLinear)  # noqa: E731

    def pick(path, b, m):
        if base.fmt == "dense":
            return m if _is_pool_path(path) else b
        return m if isinstance(m, SLRLinear) else b

    tree = jax.tree_util.tree_map_with_path(
        pick, base.params, model.params, is_leaf=is_slr
    )
    return DeployedModel(model.cfg, tree, model.fmt)


# -------------------------------------------------------------- site spec ---


def _is_pool_path(path) -> bool:
    key = path[-1]
    name = getattr(key, "key", getattr(key, "name", None))
    return name in _LINEAR_KEYS


def _leaves_equal(a, b) -> bool:
    if a is b:
        return True
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.dtype == b.dtype and np.array_equal(a, b)


class _Site:
    """Padding schema + pool builder for ONE per-adapter site.

    ``observe`` accumulates the max padded dims (rank, BSR MAXB, COO cap)
    over pre-freeze registrations; ``freeze`` fixes them; ``pad`` turns one
    adapter's leaf into pool-row tables; ``build``/``set_row`` create and
    update the device-side :class:`AdapterizedLinear`.
    """

    def __init__(self, path_key: str, base_leaf, fmt: str, mode: str):
        self.key = path_key
        self.fmt = fmt
        self.mode = mode
        if fmt == "dense":
            self.shape = tuple(base_leaf.shape[-2:])
            self.stacked = base_leaf.ndim == 3
            self.layers = base_leaf.shape[0] if self.stacked else 1
            self.dtype = base_leaf.dtype
        else:
            assert isinstance(base_leaf, SLRLinear), type(base_leaf)
            self.shape = base_leaf.shape
            self.stacked = base_leaf.ndim == 3
            self.layers = (
                _leading_dim(base_leaf) if self.stacked else 1
            )
            self.dtype = base_leaf.dtype
        self.maxr = 0
        self.maxb = 0
        self.cap = 0
        self.block_size = None
        self.any_sparse = False
        self.frozen = False
        # pool dtypes track the adapters' own table dtypes: an upcast would
        # change matmul numerics vs the single-tenant path
        self.p_dtype = np.dtype(np.float32)
        self.s_dtype = np.dtype(np.float32)

    # ------------------------------------------------------------ observe --

    def check(self, leaf):
        """Validate one adapter's leaf against the schema; post-freeze, its
        padded dims must fit the frozen pool."""
        if self.fmt == "dense":
            want = (self.layers, *self.shape) if self.stacked else self.shape
            if tuple(leaf.shape) != want:
                raise AdapterError(
                    f"site {self.key}: shape {tuple(leaf.shape)} != {want}"
                )
            return
        if not isinstance(leaf, SLRLinear):
            raise AdapterError(
                f"site {self.key}: expected an SLRLinear, got {type(leaf).__name__}"
            )
        if leaf.shape != self.shape or (leaf.ndim == 3) != self.stacked:
            raise AdapterError(
                f"site {self.key}: shape {leaf.shape} != {self.shape}"
            )
        r, maxb, cap, bs = _leaf_dims(leaf)
        if bs is not None and self.block_size is not None and bs != self.block_size:
            raise AdapterError(
                f"site {self.key}: BSR block size {bs} != {self.block_size}"
            )
        if self.frozen and (r > self.maxr or maxb > self.maxb or cap > self.cap):
            raise AdapterError(
                f"site {self.key}: adapter dims (r={r}, maxb={maxb}, cap={cap}) "
                f"exceed the frozen pool (r={self.maxr}, maxb={self.maxb}, "
                f"cap={self.cap}); build the AdapterBank with this adapter "
                "registered up front"
            )

    def observe(self, leaf):
        self.check(leaf)
        if self.fmt == "dense":
            return
        r, maxb, cap, bs = _leaf_dims(leaf)
        self.maxr = max(self.maxr, r)
        self.maxb = max(self.maxb, maxb)
        self.cap = max(self.cap, cap)
        if bs is not None:
            self.block_size = bs
        self.any_sparse = self.any_sparse or maxb > 0 or cap > 0
        if leaf.p is not None:
            self.p_dtype = np.dtype(leaf.p.dtype)
        tab = leaf.s_stack if leaf.s_stack is not None else leaf.s_bsr
        if tab is not None:
            self.s_dtype = np.dtype(tab.vals.dtype)
        elif leaf.s_coo is not None:
            self.s_dtype = np.dtype(leaf.s_coo.values.dtype)

    def freeze(self):
        self.frozen = True

    # ---------------------------------------------------------------- pad --

    def pad(self, leaf) -> dict[str, np.ndarray]:
        """One adapter's leaf → zero-padded pool-row tables (host arrays).
        Padding is exact: zero rank columns and dead sparse slots add 0."""
        self.check(leaf)
        L, (n, m) = self.layers, self.shape
        lead = (L,) if self.stacked else ()
        out = {}
        if self.fmt == "dense":
            out["w"] = np.asarray(leaf, self.dtype)
            return out
        if self.maxr:
            p = np.zeros((*lead, n, self.maxr), self.p_dtype)
            vt = np.zeros((*lead, self.maxr, m), self.p_dtype)
            if leaf.p is not None:
                r = leaf.p.shape[-1]
                p[..., :r] = np.asarray(leaf.p, self.p_dtype)
                vt[..., :r, :] = np.asarray(leaf.vt, self.p_dtype)
            out["p"], out["vt"] = p, vt
        if self.fmt == "factored" and self.any_sparse:
            vals = np.zeros((*lead, self.cap), self.s_dtype)
            idx = np.full((*lead, self.cap), -1, np.int32)
            if leaf.s_coo is not None:
                c = leaf.s_coo.values.shape[-1]
                vals[..., :c] = np.asarray(leaf.s_coo.values, self.s_dtype)
                idx[..., :c] = np.asarray(leaf.s_coo.idx, np.int32)
            out["coo_vals"], out["coo_idx"] = vals, idx
        if self.fmt == "fused" and self.any_sparse:
            bs = self.block_size
            jb = -(-m // bs)
            counts = np.zeros((*lead, jb), np.int32)
            rows = np.zeros((*lead, jb, self.maxb), np.int32)
            vals = np.zeros((*lead, jb, self.maxb, bs, bs), self.s_dtype)
            tab = leaf.s_stack if self.stacked else leaf.s_bsr
            if tab is not None:
                b = tab.rows.shape[-1]
                counts[...] = np.asarray(tab.counts, np.int32)
                rows[..., :b] = np.asarray(tab.rows, np.int32)
                vals[..., :b, :, :] = np.asarray(tab.vals, self.s_dtype)
            out["counts"], out["rows"], out["vals"] = counts, rows, vals
        return out

    def _zero_tables(self) -> dict[str, np.ndarray]:
        """Tables of an unoccupied pool row (never selected by any request)."""
        L, (n, m) = self.layers, self.shape
        lead = (L,) if self.stacked else ()
        if self.fmt == "dense":
            return {"w": np.zeros((*lead, n, m), self.dtype)}
        out = {}
        if self.maxr:
            out["p"] = np.zeros((*lead, n, self.maxr), self.p_dtype)
            out["vt"] = np.zeros((*lead, self.maxr, m), self.p_dtype)
        if self.fmt == "factored" and self.any_sparse:
            out["coo_vals"] = np.zeros((*lead, self.cap), self.s_dtype)
            out["coo_idx"] = np.full((*lead, self.cap), -1, np.int32)
        if self.fmt == "fused" and self.any_sparse:
            bs = self.block_size
            jb = -(-m // bs)
            out["counts"] = np.zeros((*lead, jb), np.int32)
            out["rows"] = np.zeros((*lead, jb, self.maxb), np.int32)
            out["vals"] = np.zeros((*lead, jb, self.maxb, bs, bs), self.s_dtype)
        return out

    # --------------------------------------------------------- pool build --

    @property
    def _flat(self) -> bool:
        # fused pools flatten (adapter, layer) -> one leading A*L axis so
        # the stacked/multi kernels index row sel*L + layer directly
        return self.fmt == "fused" and self.stacked

    def build(self, row_leaves: list) -> AdapterizedLinear:
        """Stack ``capacity`` pool rows (``None`` rows = zero tables) into
        the device-side pooled site."""
        tables = [
            self.pad(leaf) if leaf is not None else self._zero_tables()
            for leaf in row_leaves
        ]

        def pool(field):
            if field not in tables[0]:
                return None
            stackd = np.stack([t[field] for t in tables])
            if self._flat:
                stackd = stackd.reshape(-1, *stackd.shape[2:])
            return jnp.asarray(stackd)

        kw = dict(w=None, p=None, vt=None, s_coo=None, s_stack=None, sel=None,
                  shape=self.shape, fmt=self.fmt, mode=self.mode,
                  stacked=self.stacked, layers=self.layers)
        if self.fmt == "dense":
            kw["w"] = pool("w")
        else:
            kw["p"] = pool("p")
            kw["vt"] = pool("vt")
            if self.fmt == "factored" and self.any_sparse:
                kw["s_coo"] = sparse.CooMatrix(
                    pool("coo_vals"), pool("coo_idx"), self.shape
                )
            if self.fmt == "fused" and self.any_sparse:
                kw["s_stack"] = BsrStack(
                    pool("counts"), pool("rows"), pool("vals"),
                    self.shape, self.block_size, empty=False,
                )
        return AdapterizedLinear(**kw)

    def set_row(self, lin: AdapterizedLinear, row: int, leaf) -> AdapterizedLinear:
        """Swap one adapter's tables into pool row ``row`` (pure .at[].set —
        same shapes, so jitted programs never retrace)."""
        t = self.pad(leaf)
        L = self.layers

        def put(pool, field):
            if pool is None:
                return None
            v = jnp.asarray(t[field])
            if self._flat:
                return pool.at[row * L:(row + 1) * L].set(v)
            return pool.at[row].set(v)

        kw = {
            "w": put(lin.w, "w"),
            "p": put(lin.p, "p"),
            "vt": put(lin.vt, "vt"),
        }
        if lin.s_coo is not None:
            kw["s_coo"] = sparse.CooMatrix(
                put(lin.s_coo.values, "coo_vals"),
                put(lin.s_coo.idx, "coo_idx"), lin.s_coo.shape,
            )
        if lin.s_stack is not None:
            st = lin.s_stack
            kw["s_stack"] = BsrStack(
                put(st.counts, "counts"), put(st.rows, "rows"),
                put(st.vals, "vals"), st.shape, st.block_size,
                empty=st.empty,
            )
        return replace(lin, **kw)


def _leading_dim(lin: SLRLinear) -> int:
    for part in (lin.p, lin.s_coo and lin.s_coo.values, lin.s_stack and lin.s_stack.counts):
        if part is not None:
            return part.shape[0]
    raise AdapterError(f"cannot infer layer count of {lin}")


def _leaf_dims(lin: SLRLinear):
    """(live rank, BSR MAXB, COO cap, block size) of one SLRLinear."""
    r = 0 if lin.p is None else lin.p.shape[-1]
    maxb, bs = 0, None
    tab = lin.s_stack if lin.s_stack is not None else lin.s_bsr
    if tab is not None:
        maxb, bs = tab.rows.shape[-1], tab.block_size
    cap = 0 if lin.s_coo is None else lin.s_coo.values.shape[-1]
    return r, maxb, cap, bs


# --------------------------------------------------------------- registry ---


class AdapterRegistry:
    """Host-side adapter lifecycle over one shared base ``DeployedModel``.

    The base's param tree defines the site schema: for ``factored``/``fused``
    formats the per-adapter sites are exactly the ``SLRLinear`` leaves; for
    ``dense`` they are the matmul-consumed leaves (``q/k/v/o/gate/up/down/w``).
    Registered adapters must match the base at every OTHER leaf — the shared
    base is stored once, adapters contribute only their site tables.
    """

    def __init__(self, base: DeployedModel):
        if not isinstance(base, DeployedModel):
            raise TypeError(f"base must be a DeployedModel, got {type(base)!r}")
        if base.fmt not in ("dense", "factored", "fused"):
            raise AdapterError(
                f"AdapterRegistry does not support fmt={base.fmt!r} (the "
                "'bsr' unrolled format has per-matrix tables that cannot "
                "be pooled; deploy adapters as 'fused' instead)"
            )
        self.base = base
        self.fmt = base.fmt
        self._site_paths = self._find_sites(base.params)
        self._adapters: OrderedDict[int, dict[str, Any]] = OrderedDict()
        self._names: dict[int, str] = {}
        self._next = 0

    def _find_sites(self, params) -> list[str]:
        paths = []
        for path, leaf in jax.tree_util.tree_leaves_with_path(
                params, is_leaf=lambda x: isinstance(x, SLRLinear)):
            if self.fmt == "dense":
                if _is_pool_path(path):
                    paths.append(jax.tree_util.keystr(path))
            elif isinstance(leaf, SLRLinear):
                paths.append(jax.tree_util.keystr(path))
        if not paths:
            raise AdapterError("base model has no per-adapter sites")
        return paths

    def _extract(self, model: DeployedModel) -> dict[str, Any]:
        """Split one adapter into site tables, validating the shared rest."""
        if model.fmt != self.fmt:
            raise AdapterError(
                f"adapter fmt {model.fmt!r} != bank fmt {self.fmt!r}"
            )
        sites = {}
        is_slr = lambda x: isinstance(x, SLRLinear)  # noqa: E731
        base_by_key = {
            jax.tree_util.keystr(p): v
            for p, v in jax.tree_util.tree_leaves_with_path(
                self.base.params, is_leaf=is_slr)
        }
        for path, leaf in jax.tree_util.tree_leaves_with_path(
                model.params, is_leaf=is_slr):
            key = jax.tree_util.keystr(path)
            if key in self._site_paths:
                sites[key] = leaf
            else:
                ref = base_by_key.get(key)
                if (ref is None or isinstance(leaf, SLRLinear)
                        or not _leaves_equal(leaf, ref)):
                    raise AdapterError(
                        f"adapter differs from the base at non-site leaf "
                        f"{key} — only the SLR linear sites may vary per "
                        "adapter (same block selection as the base)"
                    )
        missing = [k for k in self._site_paths if k not in sites]
        if missing:
            raise AdapterError(f"adapter missing site leaves: {missing}")
        return sites

    # ---------------------------------------------------------- lifecycle --

    def register(self, model: DeployedModel, name: str | None = None) -> int:
        sites = self._extract(model)
        aid = self._next
        self._next += 1
        self._adapters[aid] = sites
        self._names[aid] = name or f"adapter{aid}"
        return aid

    def unregister(self, aid: int):
        if aid not in self._adapters:
            raise AdapterError(f"unknown adapter id {aid}")
        del self._adapters[aid]
        del self._names[aid]

    def __contains__(self, aid) -> bool:
        return aid in self._adapters

    def __len__(self) -> int:
        return len(self._adapters)

    @property
    def ids(self) -> list[int]:
        return list(self._adapters)

    def name(self, aid: int) -> str:
        return self._names[aid]

    def sites(self, aid: int) -> dict[str, Any]:
        return self._adapters[aid]

    @property
    def site_paths(self) -> list[str]:
        return list(self._site_paths)


# ------------------------------------------------------------------- bank ---


class AdapterBank(ModelBank):
    """N registered (L+S) adapters over one shared base, served as ONE
    single-tier bank: engines read the pooled param tree as tier 0 and bind
    the per-call adapter selection through :meth:`bind`.

    ``max_resident`` caps the on-device pool; the rest of the registry lives
    host-side and swaps in LRU-style on demand (``acquire``). Pool shapes
    freeze at :meth:`materialize` (the engine calls it with
    ``EngineConfig.max_resident_adapters``), so residency swaps and ``sel``
    rebinds are data-only — zero retraces across adapter switches.
    """

    def __init__(self, base: DeployedModel, adapters=(), names=None, *,
                 max_resident: int | None = None, mode: str | None = None):
        self.registry = AdapterRegistry(base)
        names = list(names) if names is not None else [None] * len(adapters)
        if len(names) != len(adapters):
            raise ValueError(
                f"{len(adapters)} adapter(s) but {len(names)} name(s)"
            )
        for model, name in zip(adapters, names):
            self.registry.register(model, name=name)
        if mode is None:
            mode = "batched" if base.fmt == "fused" else "grouped"
        if mode not in ("batched", "grouped"):
            raise ValueError(f"unknown adapter mode {mode!r}")
        if mode == "batched" and base.fmt != "fused":
            raise AdapterError(
                f"batched adapter mode needs the 'fused' format (one "
                f"multi-adapter kernel); fmt={base.fmt!r} serves grouped"
            )
        self.mode = mode
        self._max_resident = max_resident
        self._sites: list[_Site] = []
        self._device = None
        self._rows: list[int | None] = []
        self._row_of: dict[int, int] = {}
        self._pins: dict[int, int] = {}
        self._lru: OrderedDict[int, None] = OrderedDict()
        self.swaps = 0
        super().__init__(base.cfg, [base])

    # ------------------------------------------------------------ access ---

    @property
    def materialized(self) -> bool:
        return self._device is not None

    @property
    def capacity(self) -> int:
        return len(self._rows)

    @property
    def default_adapter(self) -> int:
        ids = self.registry.ids
        if not ids:
            raise AdapterError("no adapters registered")
        return ids[0]

    @property
    def resident(self) -> list[int]:
        return [aid for aid in self._rows if aid is not None]

    # ------------------------------------------------------- materialize ---

    def materialize(self, max_resident: int | None = None) -> "AdapterBank":
        """Freeze padded pool shapes and build the on-device pool. Idempotent
        for a matching capacity; engines call this before first use."""
        cap = max_resident or self._max_resident or len(self.registry)
        if self.materialized:
            if cap != self.capacity:
                raise AdapterError(
                    f"bank already materialized with max_resident="
                    f"{self.capacity}, re-requested {cap}"
                )
            return self
        if len(self.registry) == 0:
            raise AdapterError("register at least one adapter first")
        if cap < 1:
            raise ValueError(f"max_resident must be >= 1, got {cap}")
        self._max_resident = cap

        base_by_key = {
            jax.tree_util.keystr(p): v
            for p, v in jax.tree_util.tree_leaves_with_path(
                self.registry.base.params,
                is_leaf=lambda x: isinstance(x, SLRLinear))
        }
        for key in self.registry.site_paths:
            site = _Site(key, base_by_key[key], self.registry.fmt, self.mode)
            for aid in self.registry.ids:
                site.observe(self.registry.sites(aid)[key])
            site.freeze()
            if site.fmt != "dense" and not site.maxr and not site.any_sparse:
                # every registered adapter's tables here are empty (e.g. an
                # untrained all-zero SLR state): the site is identically
                # zero for all tenants, so keep the base's own leaf — an
                # AdapterizedLinear whose only array leaf is ``sel`` would
                # be sliced per layer by the scan and serves nothing
                continue
            self._sites.append(site)

        residents = self.registry.ids[:cap]
        self._rows = residents + [None] * (cap - len(residents))
        self._row_of = {aid: i for i, aid in enumerate(residents)}
        self._lru = OrderedDict((aid, None) for aid in residents)

        site_by_key = {s.key: s for s in self._sites}

        def build_leaf(path, leaf):
            key = jax.tree_util.keystr(path)
            site = site_by_key.get(key)
            if site is None:
                return leaf
            return site.build([
                None if aid is None else self.registry.sites(aid)[key]
                for aid in self._rows
            ])

        tree = jax.tree_util.tree_map_with_path(
            build_leaf, self.registry.base.params,
            is_leaf=lambda x: isinstance(x, SLRLinear))
        self._device = jax.device_put(tree)
        model = DeployedModel(self.cfg, self._device, fmt=self.registry.fmt)
        self._tiers = [Tier(index=0, name="adapters", keep=None, model=model,
                            param_bytes=self._pool_bytes())]
        return self

    def _pool_bytes(self) -> int:
        return sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(self._device)
        )

    # --------------------------------------------------------- residency ---

    def acquire(self, aid: int) -> tuple[int | None, bool]:
        """Pool row of ``aid``, swapping its host tables in if non-resident
        (LRU victim among unpinned rows). Returns ``(row, swapped)``;
        ``(None, False)`` means every row is pinned — the caller should keep
        the request queued and retry next tick."""
        if aid not in self.registry:
            raise AdapterError(f"unknown adapter id {aid}")
        assert self.materialized, "materialize() the bank first"
        row = self._row_of.get(aid)
        if row is not None:
            self._lru.move_to_end(aid)
            return row, False
        row = self._victim_row()
        if row is None:
            return None, False
        old = self._rows[row]
        if old is not None:
            del self._row_of[old]
            self._lru.pop(old, None)
        self._install(row, aid)
        self._rows[row] = aid
        self._row_of[aid] = row
        self._lru[aid] = None
        self.swaps += 1
        return row, True

    def _victim_row(self) -> int | None:
        for i, aid in enumerate(self._rows):
            if aid is None:
                return i
        for aid in self._lru:  # least-recent first
            if not self._pins.get(aid):
                return self._row_of[aid]
        return None

    def _install(self, row: int, aid: int):
        sites = self.registry.sites(aid)
        leaves, treedef = jax.tree_util.tree_flatten(
            self._device, is_leaf=lambda x: isinstance(x, AdapterizedLinear))
        out, si = [], 0
        for leaf in leaves:
            if isinstance(leaf, AdapterizedLinear):
                site = self._sites[si]
                out.append(site.set_row(leaf, row, sites[site.key]))
                si += 1
            else:
                out.append(leaf)
        assert si == len(self._sites)
        self._device = jax.tree_util.tree_unflatten(treedef, out)
        self._tiers[0].model.params = self._device

    def pin(self, aid: int):
        self._pins[aid] = self._pins.get(aid, 0) + 1

    def unpin(self, aid: int):
        n = self._pins.get(aid, 0) - 1
        if n <= 0:
            self._pins.pop(aid, None)
        else:
            self._pins[aid] = n

    def pinned(self, aid: int) -> int:
        return self._pins.get(aid, 0)

    # --------------------------------------------------------- lifecycle ---

    def register(self, model: DeployedModel, name: str | None = None) -> int:
        """Register a new adapter (host-side; becomes resident on demand).
        After materialize, its padded dims must fit the frozen pool."""
        if self.materialized:
            sites = self.registry._extract(model)
            for site in self._sites:
                site.check(sites[site.key])
        return self.registry.register(model, name=name)

    def unregister(self, aid: int):
        """Remove an adapter. Raises ``AdapterError`` while any slot streams
        with it (unregister-while-streaming rejection)."""
        if self._pins.get(aid):
            raise AdapterError(
                f"adapter {aid} is streaming on {self._pins[aid]} slot(s); "
                "drain it before unregistering"
            )
        self.registry.unregister(aid)
        row = self._row_of.pop(aid, None)
        if row is not None:
            self._rows[row] = None
            self._lru.pop(aid, None)

    # -------------------------------------------------------------- bind ---

    def bind(self, sel) -> Any:
        """The pooled param tree with ``sel`` stamped into every site: a
        ``(S,)`` slot→pool-row map (batched) or a scalar row (grouped).
        Data-only — every call yields the same treedef and shapes."""
        sel = jnp.asarray(sel, jnp.int32)
        return jax.tree_util.tree_map(
            lambda x: x.with_sel(sel) if isinstance(x, AdapterizedLinear) else x,
            self._device,
            is_leaf=lambda x: isinstance(x, AdapterizedLinear))

    # -------------------------------------------------------- accounting ---

    def adapter_report(self) -> dict:
        return {
            "fmt": self.registry.fmt,
            "mode": self.mode,
            "registered": len(self.registry),
            "capacity": self.capacity,
            "resident": self.resident,
            "swaps": self.swaps,
            "pool_bytes": self._pool_bytes() if self.materialized else 0,
            "sites": len(self._sites),
        }

    def report(self) -> dict:
        out = super().report()
        out["adapters"] = self.adapter_report()
        return out
