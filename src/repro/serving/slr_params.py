"""Deployment-time SLR parameters: the paper's point is that L + S is what
ships. Four deployment formats, increasing TPU-specialization:

  * ``dense``    — materialize X_hat = L + S (baseline; no memory savings,
                   used for perplexity parity checks)
  * ``factored`` — keep (p, vt) + COO S; linears run as x@p@vt + sparse part
                   via dense scatter per call (XLA path, shards under GSPMD)
  * ``bsr``      — factored L + 128x128 block-CSR S for the Pallas kernels
                   (single-core TPU hot path; DESIGN.md §3 hardware adaptation)
  * ``fused``    — ONE Pallas pass per linear site (x @ P @ Vt + x @ S in a
                   shared accumulator, ``kernels/slr_matmul.py``) with
                   layer-STACKED block-CSC tables, so the transformer layer
                   stack stays ``lax.scan``-able (no per-layer unrolling)

``deployment_report`` accounts bytes for each format — the numbers behind
EXPERIMENTS.md's memory-reduction table (paper Table 1 PRM columns).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import sparse
from ..core.admm import SLRState, surrogate_params
from ..core.selection import BlockInfo
from ..kernels.bsr_matmul import BsrMatrix, bsr_from_dense
from ..kernels.slr_matmul import BsrStack, stack_bsr


@dataclass
class SLRLinear:
    """One deployed SLR weight.

    Registered as a jax pytree so it can live *inside* a model parameter tree
    and flow through jit / scan: ``models.layers.apply_weight`` dispatches to
    ``apply`` wherever a dense weight is expected. Stacked blocks (leading
    layer axis on p/vt/s_coo) slice correctly under ``lax.scan``. ``use_kernel``
    is static metadata choosing the Pallas hot path at trace time.
    """

    p: jax.Array | None          # (n, r_live) — or (L, n, r_live) stacked
    vt: jax.Array | None         # (r_live, m) — or (L, r_live, m) stacked
    s_coo: sparse.CooMatrix | None
    s_bsr: BsrMatrix | None
    shape: tuple[int, int]
    use_kernel: bool = False     # static: route apply() through Pallas kernels
    s_stack: BsrStack | None = None  # layer-stacked block-CSC (fused format)
    fuse: bool = False           # static: one fused Pallas pass per apply

    def apply(self, x: jax.Array, kernel: bool | None = None) -> jax.Array:
        """y = x @ (L + S)."""
        if kernel is None:
            kernel = self.use_kernel
        if (self.p is None and self.s_coo is None and self.s_bsr is None
                and self.s_stack is None):
            # fully-truncated block (extreme HPA budgets): y = x @ 0
            return jnp.zeros((*x.shape[:-1], self.shape[1]), x.dtype)
        if self.fuse and kernel:
            assert self.s_stack is None, (
                "stacked fused weights are applied per layer: the forward "
                "scans layer indices and calls at_layer(l) (scan_by_index)"
            )
            from ..kernels.ops import slr_matmul

            flat = x.reshape(-1, x.shape[-1])
            y = slr_matmul(flat, self.p, self.vt, self.s_bsr)
            return y.reshape(*x.shape[:-1], self.shape[1])
        y = 0.0
        if self.p is not None:
            if kernel:
                from ..kernels.ops import lowrank_matmul

                flat = x.reshape(-1, x.shape[-1])
                y = lowrank_matmul(flat, self.p, self.vt).reshape(*x.shape[:-1], -1)
            else:
                y = (x @ self.p) @ self.vt
        if self.s_bsr is not None and kernel:
            from ..kernels.ops import bsr_matmul

            flat = x.reshape(-1, x.shape[-1])
            y = y + bsr_matmul(flat, self.s_bsr).reshape(*x.shape[:-1], self.shape[1])
        elif self.s_coo is not None:
            s_dense = sparse.to_dense(self.s_coo).astype(x.dtype)
            y = y + x @ s_dense
        return y

    @property
    def scan_by_index(self) -> bool:
        """Stacked fused weight: the layer scan must NOT slice this leaf as
        scan xs (that would copy the whole BSR table out of HBM every layer
        of every tick) — it scans ``jnp.arange(L)`` instead and takes
        :meth:`at_layer` views, which select the layer inside the kernel's
        scalar-prefetched DMA index maps."""
        return self.fuse and self.s_stack is not None

    def at_layer(self, layer) -> "SLRLayerView":
        """View of layer ``layer`` of a stacked fused weight (traced index)."""
        assert self.scan_by_index
        return SLRLayerView(self, layer)

    @property
    def dtype(self):
        for part in (self.p, self.s_coo and self.s_coo.values,
                     self.s_bsr and self.s_bsr.vals,
                     self.s_stack and self.s_stack.vals):
            if part is not None:
                return part.dtype
        return jnp.float32

    @property
    def ndim(self) -> int:
        """Logical ndim of the dense weight this object replaces (stack-aware)."""
        if self.p is not None:
            return self.p.ndim
        if self.s_coo is not None:
            return self.s_coo.values.ndim + 1
        if self.s_stack is not None:
            return 3  # stacked by construction
        return 2  # only s_bsr left, and block-CSR is per-matrix by construction

    @property
    def param_bytes(self) -> int:
        total = 0
        if self.p is not None:
            total += self.p.size * self.p.dtype.itemsize
            total += self.vt.size * self.vt.dtype.itemsize
        if self.s_stack is not None:
            total += self.s_stack.vals.size * self.s_stack.vals.dtype.itemsize
            total += self.s_stack.rows.size * 4 + self.s_stack.counts.size * 4
        elif self.s_bsr is not None:
            total += self.s_bsr.vals.size * self.s_bsr.vals.dtype.itemsize
            total += self.s_bsr.rows.size * 4 + self.s_bsr.counts.size * 4
        elif self.s_coo is not None:
            nnz = int(np.sum(np.asarray(self.s_coo.idx) >= 0))
            total += nnz * (self.s_coo.values.dtype.itemsize + 4)
        return total


# `shape`/`use_kernel`/`fuse` are static metadata; everything else traces.
jax.tree_util.register_dataclass(
    SLRLinear,
    data_fields=["p", "vt", "s_coo", "s_bsr", "s_stack"],
    meta_fields=["shape", "use_kernel", "fuse"],
)


class SLRLayerView:
    """Layer ``l`` of a stacked fused :class:`SLRLinear` — deliberately NOT a
    pytree. It is built *inside* the layer-scan body
    (``models.transformer.layer_view``) with a traced layer index, and
    ``models.layers.apply_weight`` duck-dispatches on its ``apply``. The
    stacked tables stay captured whole; only the layer id varies per step.
    """

    __slots__ = ("lin", "layer")

    def __init__(self, lin: SLRLinear, layer):
        self.lin = lin
        self.layer = layer

    def apply(self, x: jax.Array) -> jax.Array:
        from ..kernels.ops import slr_matmul_stacked

        lin = self.lin
        flat = x.reshape(-1, x.shape[-1])
        y = slr_matmul_stacked(flat, lin.p, lin.vt, lin.s_stack, self.layer)
        return y.reshape(*x.shape[:-1], lin.shape[1])

    @property
    def dtype(self):
        return self.lin.dtype


def _fit_block(n: int, m: int, bsr_block: int) -> int:
    """Halve the block size while it divides neither dim (floor 8) — keeps
    tile granularity useful on small matrices. A size that still doesn't
    divide is fine: ``bsr_from_dense`` zero-pads trailing partial blocks."""
    bs = bsr_block
    while (n % bs or m % bs) and bs > 8:
        bs //= 2
    return bs


def coo_to_bsr(s_coo: sparse.CooMatrix, bsr_block: int) -> BsrMatrix:
    """Dense-ify an unstacked COO matrix and re-tile as block-CSC.

    Ragged shapes zero-pad the trailing partial blocks (the padding tiles
    are all-zero so they are never stored) — every shape converts.
    """
    dense_s = np.asarray(sparse.to_dense(s_coo), np.float32)
    n, m = dense_s.shape
    return bsr_from_dense(dense_s, _fit_block(n, m, bsr_block))


def coo_to_bsr_stack(s_coo: sparse.CooMatrix, bsr_block: int) -> BsrStack:
    """Dense-ify a layer-STACKED COO matrix and re-tile every layer as
    block-CSC with one shared (block size, MAXB) layout — the table shapes
    the stacked fused kernel scans over."""
    dense_s = np.asarray(sparse.to_dense(s_coo), np.float32)
    num_l, n, m = dense_s.shape
    bs = _fit_block(n, m, bsr_block)
    return stack_bsr([bsr_from_dense(dense_s[l], bs) for l in range(num_l)])


def _live_rank_slice(blk, info: BlockInfo):
    """Trim factored L to live singular values (per slice; stacked blocks keep
    the max live rank across slices so shapes stay static)."""
    s_vals = np.asarray(blk.s_vals)
    live = s_vals > 0
    r_live = int(live.sum(axis=-1).max()) if live.size else 0
    if r_live == 0:
        return None, None
    order = np.argsort(-s_vals, axis=-1)[..., :r_live]
    p = np.take_along_axis(np.asarray(blk.p), order[..., None, :], axis=-1)
    vt = np.take_along_axis(np.asarray(blk.vt), order[..., :, None], axis=-2)
    return jnp.asarray(p), jnp.asarray(vt)


def build_slr_linears(
    state: SLRState,
    blocks: list[BlockInfo],
    fmt: str = "factored",
    bsr_block: int = 128,
) -> dict[str, Any]:
    """Per-block deployed representation. Stacked blocks are kept stacked for
    'factored'; 'bsr' unstacks (the Pallas kernel is per-matrix)."""
    out = {}
    for info in blocks:
        blk = state[info.name]
        p, vt = _live_rank_slice(blk, info)
        # an HPA budget that removed every sparse entry (e.g. kappa -> 0, the
        # pure-low-rank end of the spectrum) must not keep paying the dense
        # COO scatter at every apply — drop the empty S at build time
        s_coo = blk.s_coo
        if int(np.sum(np.asarray(s_coo.idx) >= 0)) == 0:
            s_coo = None
        if fmt == "bsr" and not info.stack_dims:
            s_bsr = coo_to_bsr(blk.s_coo, bsr_block) if s_coo is not None else None
            # keep the COO view too: apply(kernel=False) is the XLA/GSPMD
            # fallback and must include the sparse part
            out[info.name] = SLRLinear(
                p=p, vt=vt, s_coo=s_coo, s_bsr=s_bsr, shape=(info.n, info.m)
            )
        else:
            out[info.name] = SLRLinear(
                p=p, vt=vt, s_coo=s_coo, s_bsr=None, shape=(info.n, info.m)
            )
    return out


def deploy_params(params: Any, state: SLRState, blocks: list[BlockInfo], fmt: str = "dense"):
    """For fmt='dense': params with X replaced by X_hat = L + S (architecture-
    preserving — the model code runs unchanged, paper §4.3)."""
    assert fmt == "dense"
    return surrogate_params(params, state, blocks)


def deployment_report(params: Any, state: SLRState, blocks: list[BlockInfo]) -> dict:
    """Bytes by format vs the dense original (per block + totals)."""
    report: dict[str, Any] = {"blocks": {}}
    dense_total = 0
    slr_total = 0
    for info in blocks:
        blk = state[info.name]
        dense_b = int(np.prod(info.shape)) * 2  # bf16 deploy baseline
        nnz = int(np.sum(np.asarray(blk.s_coo.idx) >= 0))
        live = int(np.sum(np.asarray(blk.s_vals) > 0))
        slr_b = live * (info.n + info.m) * 2 + nnz * (2 + 4)
        report["blocks"][info.name] = {
            "dense_bytes": dense_b, "slr_bytes": slr_b,
            "rank_live": live, "nnz": nnz,
        }
        dense_total += dense_b
        slr_total += slr_b
    unselected = 0
    sel = {info.name for info in blocks}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        from ..core.selection import path_str

        if path_str(path) not in sel:
            unselected += int(np.prod(leaf.shape)) * 2
    report["dense_total_bytes"] = dense_total + unselected
    report["slr_total_bytes"] = slr_total + unselected
    report["compression"] = (
        (dense_total + unselected) / max(slr_total + unselected, 1)
    )
    return report
