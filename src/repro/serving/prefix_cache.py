"""Radix prompt cache: a host-side index over token prefixes at KV-page
granularity.

The paged engine decouples logical positions from physical KV through its
block table, so two requests whose prompts share a prefix can share the
PHYSICAL pages that hold it. This module is the host half of that sharing
(the device half is ``kernels/page_copy.py`` — copy-on-write):

  * the index is a radix trie whose edges are ``block_size``-token tuples;
    each node owns exactly one page (one reference in the engine's
    :class:`~repro.serving.engine.BlockAllocator`) holding the KV of that
    block, conditioned on the full chain of blocks above it;
  * ``match`` walks a prompt down the trie and returns the longest chain of
    cached full pages — admission attaches them read-only (``share``) and
    prefills only the unmatched suffix;
  * ``publish`` adopts a retired slot's full pages, one chain node per block.
    Blocks the index already holds keep their existing page and the caller's
    duplicate reference is dropped — so N slots retiring the same prefix
    converge on one physical copy;
  * ``reclaim`` walks the LRU tail: LEAF nodes nobody else references
    (refcount 1 — index-only) are released oldest-first, cascading upward as
    parents become leaves. Interior nodes and attached pages are never
    touched, so reclaim can never free KV a live slot still reads.

The index is TIER-AGNOSTIC, like the pages themselves (serving/elastic.py):
a prefix prefilled by one bank tier serves admissions pinned to any tier,
the same approximation elastic mid-stream tier switches already make.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # engine.py imports this module; annotation only, no cycle
    from .engine import BlockAllocator

__all__ = ["PrefixCache"]


class _Node:
    __slots__ = ("page", "children", "tick", "parent", "key")

    def __init__(self, page, parent, key):
        self.page = page          # pool page id (None only on the root)
        self.children: dict[tuple, _Node] = {}
        self.tick = 0             # last match/publish touch (LRU order)
        self.parent = parent
        self.key = key            # the block-token tuple edge from parent


class PrefixCache:
    """Radix index over token prefixes; one :class:`BlockAllocator` reference
    held per indexed page."""

    def __init__(self, allocator: BlockAllocator, block_size: int):
        self._alloc = allocator
        self._bs = block_size
        self._root = _Node(None, None, None)
        self._tick = 0
        self._size = 0

    @property
    def pages(self) -> int:
        """Pages the index currently holds a reference to."""
        return self._size

    @property
    def reclaimable_pages(self) -> int:
        """Pages ``reclaim`` could eventually free: nodes whose whole subtree
        is index-only (refcount 1). Feeds the page-pressure signal so cached
        tail pages do not read as scarcity."""
        def walk(node) -> tuple[int, bool]:
            count, clean = 0, True
            for child in node.children.values():
                c, ok = walk(child)
                count += c
                clean = clean and ok
            ok = clean and self._alloc.refcount(node.page) == 1
            return count + (1 if ok else 0), ok

        total = 0
        for child in self._root.children.values():
            total += walk(child)[0]
        return total

    def match(self, tokens: list[int]) -> list[int]:
        """Longest chain of cached full pages prefixing ``tokens`` (page ids,
        root-first). Touches every node on the chain for LRU."""
        self._tick += 1
        node = self._root
        out: list[int] = []
        for i in range(len(tokens) // self._bs):
            child = node.children.get(tuple(tokens[i * self._bs:(i + 1) * self._bs]))
            if child is None:
                break
            child.tick = self._tick
            out.append(child.page)
            node = child
        return out

    def publish(self, tokens: list[int], pages: list[int]):
        """Adopt ``pages`` (page i holds the KV of token block i) into the
        index. The caller TRANSFERS one allocator reference per page: new
        blocks keep it, blocks the index already holds release the duplicate
        (the index's existing page wins — concurrent sharers converge)."""
        self._tick += 1
        node = self._root
        for i, page in enumerate(pages):
            key = tuple(tokens[i * self._bs:(i + 1) * self._bs])
            child = node.children.get(key)
            if child is None:
                child = _Node(page, node, key)
                node.children[key] = child
                self._size += 1
            else:
                # the index already holds this block (possibly this very page,
                # when the caller had attached it): its existing reference
                # stands, the caller's transferred one is a duplicate — drop it
                self._alloc.release([page])
            child.tick = self._tick
            node = child

    def reclaim(self, n: int) -> int:
        """Free up to ``n`` pages from the LRU tail: repeatedly release the
        least-recently-touched LEAF whose page nobody else holds. Returns the
        pages actually freed (0 when every leaf is still attached somewhere)."""
        freed = 0
        while freed < n:
            victim = None
            stack = list(self._root.children.values())
            while stack:
                node = stack.pop()
                if node.children:
                    stack.extend(node.children.values())
                elif self._alloc.refcount(node.page) == 1 and (
                    victim is None or node.tick < victim.tick
                ):
                    victim = node
            if victim is None:
                return freed
            self._alloc.release([victim.page])
            del victim.parent.children[victim.key]
            self._size -= 1
            freed += 1
        return freed
