"""DeployedModel: run the transformer forward on SLR (L + S) weights directly.

The paper's headline claim is that one SALAAD run yields a *spectrum* of
deployable capacities — but that only pays off if inference consumes the
deployed representation instead of re-materializing dense weights. This
module builds a model parameter tree in which every SALAAD-selected matmul
weight is replaced by a :class:`~repro.serving.slr_params.SLRLinear` (a
registered pytree), so the unchanged model code — via
``models.layers.apply_weight`` — runs ``x @ P @ Vt + x @ S`` at every linear
site. Three formats, increasing TPU specialization:

  * ``dense``    — X_hat = L + S materialized (parity baseline; scan path)
  * ``factored`` — (p, vt) + COO S as pytree leaves; XLA path, scan-stacked,
                   shards under GSPMD exactly like dense weights
  * ``bsr``      — factored L + block-CSR S through the Pallas kernels; the
                   per-matrix kernels cannot ride a scan, so the layer stack
                   is *unrolled* into per-layer param dicts
                   (``models.transformer._forward_unrolled``)
  * ``fused``    — ONE fused Pallas pass per linear site
                   (``kernels/slr_matmul.py``: x @ P @ Vt + x @ S into a
                   shared accumulator, x read once / y written once) with
                   layer-STACKED block-CSC tables. The layer stack stays
                   scan-stacked: the forward scans layer *indices* and the
                   kernel selects the layer in its scalar-prefetched DMA
                   index maps (``SLRLinear.scan_by_index``), so trace and
                   compile time stay depth-independent

Only matmul-applied sites are structured: attention q/k/v/o, MLP gate/up/down
and (if selected) the LM head. Embedding tables are gather sites and MoE
experts are einsum-dispatched, so those blocks are served dense-materialized;
``param_bytes`` accounts for both honestly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import sparse
from ..core.admm import SLRState, surrogate_params
from ..core.selection import BlockInfo, path_str
from ..models import model as model_lib
from .slr_params import SLRLinear, build_slr_linears, coo_to_bsr, coo_to_bsr_stack

__all__ = ["DeployedModel", "is_linear_site"]

# Param-dict keys that are consumed via apply_weight (plain x @ w sites).
_LINEAR_KEYS = frozenset({"q", "k", "v", "o", "gate", "up", "down", "w"})


def is_linear_site(info: BlockInfo) -> bool:
    """Can this block be served structured (its use site is a plain matmul)?"""
    last = info.name.split("/")[-1]
    return last in _LINEAR_KEYS and "moe" not in info.name and not info.is_embedding


def _materialize_dense(blk, leaf_dtype) -> jax.Array:
    """X_hat = L + S for blocks that cannot be served structured."""
    dense = blk.p @ blk.vt + sparse.to_dense(blk.s_coo).astype(blk.p.dtype)
    return dense.astype(leaf_dtype)


def _coo_slice_to_bsr(lin: SLRLinear, bsr_block: int) -> SLRLinear:
    """Convert one unstacked SLRLinear's COO part to block-CSR (eager)."""
    if lin.s_coo is None:
        return lin
    s_bsr = coo_to_bsr(lin.s_coo, bsr_block)
    return SLRLinear(
        p=lin.p, vt=lin.vt, s_coo=None, s_bsr=s_bsr, shape=lin.shape,
        use_kernel=True,
    )


def _fuse_linear(lin: SLRLinear, bsr_block: int) -> SLRLinear:
    """One SLRLinear → fused format: stacked slices keep the layer axis as a
    ``BsrStack`` (scan-by-index), unstacked ones get a per-matrix block-CSC.
    Empty-S sites (s_coo already dropped at build) carry no sparse table at
    all — ``ops.slr_matmul`` statically skips the sparse epilogue."""
    if lin.ndim == 3:
        s_stack = (
            coo_to_bsr_stack(lin.s_coo, bsr_block) if lin.s_coo is not None else None
        )
        return SLRLinear(
            p=lin.p, vt=lin.vt, s_coo=None, s_bsr=None, s_stack=s_stack,
            shape=lin.shape, use_kernel=True, fuse=True,
        )
    s_bsr = coo_to_bsr(lin.s_coo, bsr_block) if lin.s_coo is not None else None
    return SLRLinear(
        p=lin.p, vt=lin.vt, s_coo=None, s_bsr=s_bsr, shape=lin.shape,
        use_kernel=True, fuse=True,
    )


class DeployedModel:
    """A servable model: arch config + a param tree in a deployment format.

    ``params`` is consumed by the ordinary ``models.model`` API (loss_fn /
    prefill / decode_step) — the format is invisible to model code.
    """

    def __init__(self, cfg, params: Any, fmt: str = "dense"):
        self.cfg = cfg
        self.params = params
        self.fmt = fmt

    # ------------------------------------------------------------- build ---

    @classmethod
    def build(
        cls,
        cfg,
        params: Any,
        state: SLRState,
        blocks: list[BlockInfo],
        fmt: str = "factored",
        bsr_block: int = 128,
    ) -> "DeployedModel":
        """Deploy (params, SLR state) at format ``fmt``."""
        if fmt == "dense":
            return cls(cfg, surrogate_params(params, state, blocks), fmt)
        if fmt not in ("factored", "bsr", "fused"):
            raise ValueError(f"unknown deployment format {fmt!r}")

        by_name = {info.name: info for info in blocks}
        # factored build keeps stacked blocks stacked — scan-compatible; the
        # COO part rides along for the XLA fallback and for bsr conversion
        linears = build_slr_linears(state, blocks, fmt="factored")

        def replace_leaf(path, leaf):
            name = path_str(path)
            info = by_name.get(name)
            if info is None or name not in state:
                return leaf
            if is_linear_site(info):
                return linears[name]
            return _materialize_dense(state[name], leaf.dtype)

        serving = jax.tree_util.tree_map_with_path(replace_leaf, params)

        if fmt == "bsr":
            serving = cls._unroll_layers(cfg, serving, bsr_block)
            # unstacked blocks outside the layer stack also get the kernel path
            serving = jax.tree_util.tree_map(
                lambda x: _coo_slice_to_bsr(x, bsr_block)
                if isinstance(x, SLRLinear) and x.ndim == 2 else x,
                serving,
                is_leaf=lambda x: isinstance(x, SLRLinear),
            )
        elif fmt == "fused":
            # layer stack STAYS stacked — stacked sites become scan-by-index
            # fused weights, unstacked ones per-matrix fused weights
            serving = jax.tree_util.tree_map(
                lambda x: _fuse_linear(x, bsr_block) if isinstance(x, SLRLinear) else x,
                serving,
                is_leaf=lambda x: isinstance(x, SLRLinear),
            )
        return cls(cfg, serving, fmt)

    @staticmethod
    def _unroll_layers(cfg, serving: Any, bsr_block: int) -> Any:
        """Split the scan-stacked layer tree into a per-layer list and convert
        each layer's SLR weights to block-CSR (Pallas kernels are per-matrix)."""
        layers = serving.get("layers") if isinstance(serving, dict) else None
        if layers is None:
            return serving
        unrolled = []
        for l in range(cfg.num_layers):
            is_slr = lambda x: isinstance(x, SLRLinear)  # noqa: E731
            layer = jax.tree_util.tree_map(lambda a: a[l], layers)
            layer = jax.tree_util.tree_map(
                lambda x: _coo_slice_to_bsr(x, bsr_block) if isinstance(x, SLRLinear) else x,
                layer, is_leaf=is_slr,
            )
            unrolled.append(layer)
        out = dict(serving)
        out["layers"] = unrolled
        return out

    # ----------------------------------------------------------- forward ---

    def forward(self, tokens: jax.Array) -> jax.Array:
        """Full no-cache forward → logits (parity checks / eval)."""
        logits, _, _ = model_lib._forward(self.params, {"tokens": tokens}, self.cfg)
        return logits

    def loss(self, batch: dict) -> float:
        loss, _ = model_lib.loss_fn(self.params, batch, self.cfg)
        return float(loss)

    # -------------------------------------------------------- accounting ---

    def param_bytes(self) -> dict:
        """Served memory by leaf kind (structured vs dense), in bytes."""
        structured = 0
        dense = 0
        is_slr = lambda x: isinstance(x, SLRLinear)  # noqa: E731
        for leaf in jax.tree_util.tree_leaves(self.params, is_leaf=is_slr):
            if isinstance(leaf, SLRLinear):
                structured += leaf.param_bytes
            else:
                structured_or_dense = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                dense += structured_or_dense
        return {
            "structured_bytes": structured,
            "dense_bytes": dense,
            "total_bytes": structured + dense,
            "format": self.fmt,
        }
