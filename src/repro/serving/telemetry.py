"""Serving telemetry: ONE metrics substrate for every engine.

The serving stack grew a control loop per PR — the pressure
:class:`~repro.serving.elastic.TierController` downshifts tiers, the
``SpecController`` adapts the draft window, the prefix cache trades pages
against TTFT — but their signals lived as ad-hoc ``self.counter += 1``
attributes scattered per engine, and every benchmark hand-rolled its own
percentile code. This module is the shared substrate those loops (and the
operator watching them) read from:

``MetricsRegistry``
    Counters, gauges, and fixed-bucket histograms, each with a name, type,
    help string, and label names — the Prometheus data model, stdlib-only.
    One registry per engine; every metric carries an ``engine`` label so
    fleet-level aggregation stays possible. ``snapshot()`` returns a plain
    dict (BENCH provenance payloads), ``prometheus_text()`` the text
    exposition format (scraped via :func:`start_metrics_server`).

``Histogram``
    Fixed log-spaced buckets (Prometheus cumulative-bucket export) PLUS a
    bounded raw-sample window for EXACT percentiles — the single definition
    of TTFT/ITL/tick-time the ``serve_*`` benchmarks consume instead of
    private ``np.percentile`` code. When the window overflows, ``percentile``
    falls back to bucket interpolation (and says so in the snapshot).

``EngineTelemetry``
    The standard serving metric set (the catalog in
    ``docs/observability.md``), declared ONCE, with the host-side hooks the
    engines call: token/request accounting, per-program wall-clock timing,
    and the **retrace detector** — every jitted call site is timed and
    compile-cache misses are counted per ``(engine, program, tier)``; a
    trace on a (program, tier) pair that already compiled counts as a
    *retrace* (``serve_jit_retraces_total``), the metric the SLO benchmarks
    and the CI telemetry smoke assert stays 0 in steady state.

Telemetry is zero-cost on the DEVICE path by construction: every hook runs
on the host, reads only host state, and adds no device fetches — greedy
token streams are bitwise-identical with telemetry on or off
(tests/test_telemetry.py).

Token accounting contract (the exactly-once audit): ``tokens_total{kind=
"emitted"}`` counts every token a request emits exactly once — eviction
re-prefill and prefix-hit admissions re-PROCESS tokens (visible as
``kind="prefill_compute"`` / ``kind="reprefill"``) but never re-EMIT them,
so throughput summaries derived from ``emitted`` never double-count.

    python -m repro.serving.telemetry validate metrics.txt   # exposition check
"""
from __future__ import annotations

import bisect
import http.server
import json
import re
import threading
import time
from contextlib import contextmanager
from dataclasses import asdict

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "EngineTelemetry",
    "NullTelemetry",
    "engine_provenance",
    "request_ttft",
    "request_itls",
    "start_metrics_server",
    "validate_prometheus_text",
    "LATENCY_BUCKETS_S",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Default latency buckets (seconds, Prometheus base unit): sub-millisecond
# host ticks on the reduced CPU model up through multi-second cold prefills.
LATENCY_BUCKETS_S = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

# Raw-sample window per histogram: exact percentiles for benchmark-scale
# runs; production-scale streams overflow into bucket interpolation.
_SAMPLE_CAP = 65536


def _fmt_value(v: float) -> str:
    """Prometheus sample value: integers render bare, floats as repr."""
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _label_str(names: tuple[str, ...], values: tuple[str, ...]) -> str:
    if not names:
        return ""
    esc = lambda s: s.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")  # noqa: E731
    return "{" + ",".join(
        f'{n}="{esc(str(v))}"' for n, v in zip(names, values)
    ) + "}"


class _Metric:
    """Shared base: a named, typed, labeled family of samples."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: tuple[str, ...] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for l in labels:
            if not _LABEL_RE.match(l):
                raise ValueError(f"invalid label name {l!r} on {name}")
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        # label-value tuple -> per-series state; () for unlabeled metrics
        self._series: dict[tuple[str, ...], float] = {}
        # raw label values -> stringified key; the hot path (on_token etc.)
        # passes the same few tuples millions of times
        self._key_cache: dict[tuple, tuple[str, ...]] = {}

    def _key(self, values: tuple) -> tuple[str, ...]:
        k = self._key_cache.get(values)
        if k is None:
            if len(values) != len(self.labels):
                raise ValueError(
                    f"{self.name} takes labels {self.labels}, got {values!r}"
                )
            k = self._key_cache[values] = tuple(str(v) for v in values)
        return k

    # --- export -----------------------------------------------------------

    def header(self) -> list[str]:
        return [
            f"# HELP {self.name} {self.help}",
            f"# TYPE {self.name} {self.kind}",
        ]

    def samples(self) -> list[str]:
        return [
            f"{self.name}{_label_str(self.labels, k)} {_fmt_value(v)}"
            for k, v in sorted(self._series.items())
        ]

    def snapshot(self):
        if not self.labels:
            return self._series.get((), 0)
        return {",".join(k): v for k, v in sorted(self._series.items())}


class Counter(_Metric):
    """Monotone counter. ``inc`` only ever adds a non-negative amount."""

    kind = "counter"

    def inc(self, amount: float = 1, *labels):
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({amount})")
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0) + amount

    def incrementer(self, *labels):
        """Pre-bound single-series increment: resolves the label key ONCE so
        per-token hooks skip the varargs + key-cache work on every call."""
        k = self._key(labels)
        series = self._series

        def inc(n: float = 1):
            series[k] = series.get(k, 0) + n

        return inc

    def value(self, *labels) -> float:
        return self._series.get(self._key(labels), 0)

    def total(self) -> float:
        """Sum across every label combination."""
        return sum(self._series.values())


class Gauge(_Metric):
    """Point-in-time value (pool occupancy, controller state, EMAs)."""

    kind = "gauge"

    def set(self, value: float, *labels):
        self._series[self._key(labels)] = float(value)

    def setter(self, *labels):
        """Pre-bound single-series set — the per-tick pool-gauge fast path."""
        k = self._key(labels)
        series = self._series

        def set_(value: float):
            series[k] = float(value)

        return set_

    def value(self, *labels) -> float:
        return self._series.get(self._key(labels), 0.0)


class _HistSeries:
    __slots__ = ("bucket_counts", "count", "sum", "samples", "overflowed")

    def __init__(self, n_buckets: int):
        self.bucket_counts = [0] * (n_buckets + 1)   # +Inf bucket last
        self.count = 0
        self.sum = 0.0
        self.samples: list[float] = []
        self.overflowed = False


class Histogram(_Metric):
    """Fixed-bucket histogram with an exact-percentile sample window.

    The bucket layout is frozen at declaration (Prometheus cumulative
    ``_bucket`` export); a bounded raw-sample list rides alongside so that
    benchmark-scale runs get EXACT percentiles — the one definition of
    TTFT/ITL every harness shares. Past ``_SAMPLE_CAP`` observations the
    window stops growing and ``percentile`` interpolates from the buckets
    (upper-bound linear interpolation), which is what a production scrape
    would do anyway.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, labels: tuple[str, ...] = (),
                 buckets: tuple[float, ...] = LATENCY_BUCKETS_S):
        super().__init__(name, help, labels)
        b = tuple(float(x) for x in buckets)
        if not b or list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError(
                f"histogram {name} buckets must be a sorted, unique, "
                f"non-empty sequence, got {buckets!r}"
            )
        self.buckets = b
        self._series: dict[tuple[str, ...], _HistSeries] = {}

    def _get(self, labels: tuple) -> _HistSeries:
        k = self._key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = _HistSeries(len(self.buckets))
        return s

    def observe(self, value: float, *labels):
        s = self._get(labels)
        s.bucket_counts[bisect.bisect_left(self.buckets, value)] += 1
        s.count += 1
        s.sum += value
        if len(s.samples) < _SAMPLE_CAP:
            s.samples.append(value)
        else:
            s.overflowed = True

    def observer(self, *labels):
        """Pre-bound single-series observe: the per-token hot path. Resolves
        the series ONCE and closes over it, skipping the varargs build and
        two dict lookups that ``observe`` pays on every call. Safe across
        ``reset`` because reset zeroes series IN PLACE."""
        s = self._get(labels)
        buckets = self.buckets
        bl = bisect.bisect_left
        cap = _SAMPLE_CAP

        def obs(value: float):
            s.bucket_counts[bl(buckets, value)] += 1
            s.count += 1
            s.sum += value
            if len(s.samples) < cap:
                s.samples.append(value)
            else:
                s.overflowed = True

        return obs

    def reset(self):
        """Zero every series — benchmarks call this between warmup and the
        measured window so compilation-time observations never pollute a
        reported percentile. Zeroes IN PLACE (rather than dropping series)
        so the pre-bound ``observer`` closures engines hold stay live."""
        n = len(self.buckets) + 1
        for s in self._series.values():
            s.bucket_counts = [0] * n
            s.count = 0
            s.sum = 0.0
            s.samples = []
            s.overflowed = False

    # --- reads ------------------------------------------------------------

    def count(self, *labels) -> int:
        k = self._key(labels)
        return self._series[k].count if k in self._series else 0

    def sum_(self, *labels) -> float:
        k = self._key(labels)
        return self._series[k].sum if k in self._series else 0.0

    def percentile(self, p: float, *labels) -> float:
        """p in [0, 100]. Exact over the raw-sample window; bucket-
        interpolated once the window has overflowed. nan when empty."""
        k = self._key(labels)
        s = self._series.get(k)
        if s is None or s.count == 0:
            return float("nan")
        if not s.overflowed:
            xs = sorted(s.samples)
            # linear interpolation between closest ranks (numpy default)
            pos = (len(xs) - 1) * p / 100.0
            lo = int(pos)
            hi = min(lo + 1, len(xs) - 1)
            return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
        target = s.count * p / 100.0
        cum = 0
        lower = 0.0
        for i, c in enumerate(s.bucket_counts):
            if c:
                upper = (self.buckets[i] if i < len(self.buckets)
                         else self.buckets[-1])
                if cum + c >= target:
                    frac = (target - cum) / c
                    return lower + (upper - lower) * frac
                cum += c
                lower = upper
        return lower

    # --- export -----------------------------------------------------------

    def samples(self) -> list[str]:
        out = []
        for k, s in sorted(self._series.items()):
            cum = 0
            for i, le in enumerate(self.buckets):
                cum += s.bucket_counts[i]
                lbl = _label_str(self.labels + ("le",), k + (repr(float(le)),))
                out.append(f"{self.name}_bucket{lbl} {cum}")
            lbl = _label_str(self.labels + ("le",), k + ("+Inf",))
            out.append(f"{self.name}_bucket{lbl} {s.count}")
            base = _label_str(self.labels, k)
            out.append(f"{self.name}_sum{base} {repr(s.sum)}")
            out.append(f"{self.name}_count{base} {s.count}")
        return out

    def snapshot(self):
        def one(s: _HistSeries) -> dict:
            return {
                "count": s.count,
                "sum": round(s.sum, 9),
                "p50": self._pct_of(s, 50),
                "p90": self._pct_of(s, 90),
                "p99": self._pct_of(s, 99),
                "exact": not s.overflowed,
            }
        if not self.labels:
            s = self._series.get(())
            return one(s) if s is not None else {"count": 0}
        return {",".join(k): one(s) for k, s in sorted(self._series.items())}

    def _pct_of(self, s: _HistSeries, p: float):
        key = next(k for k, v in self._series.items() if v is s)
        v = self.percentile(p, *key)
        return None if v != v else round(v, 9)   # nan -> null in JSON


class MetricsRegistry:
    """A named collection of metrics with idempotent declaration.

    Declaring the same (name, kind, labels) twice returns the existing
    metric; a conflicting redeclaration raises — two call sites can never
    silently split one logical metric."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _declare(self, cls, name, help, labels, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls or m.labels != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already declared as {type(m).__name__}"
                    f"{m.labels}, redeclared as {cls.__name__}{tuple(labels)}"
                )
            return m
        m = cls(name, help, tuple(labels), **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self._declare(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self._declare(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=LATENCY_BUCKETS_S) -> Histogram:
        return self._declare(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def __iter__(self):
        return iter(self._metrics.values())

    def snapshot(self) -> dict:
        """Plain-dict view of every metric — the registry half of
        ``engine_provenance`` and ``stats_snapshot`` payloads."""
        return {
            m.name: {"type": m.kind, "values": m.snapshot()}
            for m in self._metrics.values()
        }

    def prometheus_text(self) -> str:
        """The text exposition format (version 0.0.4)."""
        lines = []
        for m in self._metrics.values():
            lines.extend(m.header())
            lines.extend(m.samples())
        return "\n".join(lines) + "\n"


# --------------------------------------------------------- engine telemetry ---


class EngineTelemetry:
    """The standard serving metric set + the host-side hooks engines call.

    One instance per engine. Every metric carries the ``engine`` label (the
    concrete class name) so several engines can be scraped side by side.
    The full catalog — names, types, labels, semantics — is documented in
    ``docs/observability.md``; this class is its single point of truth.
    """

    # engines consult this before computing EXPENSIVE hook arguments (e.g. a
    # radix-tree walk for a gauge); the hooks themselves are called
    # unconditionally so the scheduler keeps one code path
    enabled = True

    def __init__(self, engine: str, registry: MetricsRegistry | None = None,
                 tracer=None):
        self.engine = engine
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer          # serving.trace.RequestTracer or None
        r = self.registry
        e = ("engine",)
        self.requests = r.counter(
            "serve_requests_total",
            "Request lifecycle events (submitted/admitted/finished/evicted/"
            "rejected; admissions of a previously evicted request also count "
            "'resumed')", e + ("event",))
        self.tokens = r.counter(
            "serve_tokens_total",
            "Token accounting: 'emitted' counts every generated token exactly "
            "once; 'prefill_compute' counts prompt tokens run through a "
            "prefill/chunk program (eviction re-prefill re-counts here, never "
            "in 'emitted'); 'reprefill' is the re-admission share of that "
            "compute; 'prefix_hit' tokens were served from cached pages",
            e + ("kind",))
        self.ttft = r.histogram(
            "serve_ttft_seconds",
            "Time to first token, measured from Request.submitted_at (open-"
            "loop harnesses backdate it to the scheduled arrival)", e)
        self.itl = r.histogram(
            "serve_itl_seconds", "Inter-token latency between consecutive "
            "emitted tokens of one request", e)
        self.admission_wait = r.histogram(
            "serve_admission_wait_seconds",
            "submit-to-admit queue wait per admission (re-admissions count "
            "from eviction-time re-queue)", e)
        self.tick = r.histogram(
            "serve_tick_seconds", "Wall time of one engine step()", e)
        self.program = r.histogram(
            "serve_program_seconds",
            "Wall time per jitted program call, including the host fetch of "
            "its outputs (count = device calls)", e + ("program", "tier"))
        self.jit_compiles = r.counter(
            "serve_jit_compiles_total",
            "Compilation-cache misses per (program, tier): first-use "
            "compiles land here", e + ("program", "tier"))
        self.jit_retraces = r.counter(
            "serve_jit_retraces_total",
            "Compilation-cache misses on a (program, tier) that had already "
            "compiled — steady-state recompiles; SLO benchmarks assert 0",
            e + ("program", "tier"))
        self.evictions = r.counter(
            "serve_evictions_total", "Slots evicted back to the queue", e)
        self.prefix = r.counter(
            "serve_prefix_events_total",
            "Radix prompt-cache events: lookups / hits / cow_copies / "
            "reattached_pages", e + ("event",))
        self.tier_switches = r.counter(
            "serve_tier_switches_total",
            "Mid-stream effective-tier changes across all slots", e)
        self.downshift_ticks = r.counter(
            "serve_downshift_ticks_total",
            "Ticks served with a positive pressure-controller shift", e)
        self.spec_tokens = r.counter(
            "serve_spec_tokens_total",
            "Speculative decoding: 'drafted' proposals vs 'accepted' by the "
            "verifier", e + ("kind",))
        self.adapter_swaps = r.counter(
            "serve_adapter_swaps_total",
            "Adapter-pool residency misses: host tables swapped into a "
            "device pool row (multi-tenant AdapterBank serving)", e)
        self.adapter_tokens = r.counter(
            "serve_adapter_tokens_total",
            "Tokens emitted per adapter id (multi-tenant serving)",
            e + ("adapter",))
        self.queue_depth = r.gauge(
            "serve_queue_depth", "Requests waiting for admission", e)
        self.active_slots = r.gauge(
            "serve_active_slots", "Slots holding an active request", e)
        self.free_pages = r.gauge(
            "serve_free_pages", "Free pages in the KV pool (paged engines)", e)
        self.cached_pages = r.gauge(
            "serve_cached_pages",
            "Pages the prefix-cache radix index holds a reference to", e)
        self.tier_shift = r.gauge(
            "serve_tier_shift", "Current pressure-controller downshift", e)
        self.spec_accept_ema = r.gauge(
            "serve_spec_accept_ema",
            "Mean per-slot EMA acceptance rate over active slots", e)
        self.spec_k = r.gauge(
            "serve_spec_k", "Current draft window (adaptive k)", e)
        self.resident_adapters = r.gauge(
            "serve_resident_adapters",
            "Adapters resident in the device pool (AdapterBank capacity "
            "minus unoccupied rows)", e)
        self.kv_pool_device_bytes = r.gauge(
            "serve_kv_pool_device_bytes",
            "KV payload bytes resident per device (pools + int8 scales); "
            "under a ServingMesh each device holds its head-axis shard, so "
            "this shrinks ~1/N with the model-axis size", e + ("device",))
        # (program, tier) pairs whose first call already happened: a compile
        # observed later is a RETRACE (the generalized retraces_on_switch)
        self._seen_programs: set[tuple[str, str]] = set()
        # pre-bound fast paths for the hooks that fire every token / tick;
        # everything colder goes through the generic label-resolving calls
        eng = self.engine
        self._obs_ttft = self.ttft.observer(eng)
        self._obs_itl = self.itl.observer(eng)
        self._obs_tick = self.tick.observer(eng)
        self._inc_emitted = self.tokens.incrementer(eng, "emitted")
        self._set_free = self.free_pages.setter(eng)
        self._set_cached = self.cached_pages.setter(eng)
        self._set_queue = self.queue_depth.setter(eng)
        self._set_active = self.active_slots.setter(eng)
        self._set_shift = self.tier_shift.setter(eng)
        self._prog_obs: dict[tuple[str, str], object] = {}

    # ---------------------------------------------------------- low level --

    def counter_value(self, metric: Counter, *rest) -> float:
        return metric.value(self.engine, *rest)

    def inc(self, metric: Counter, n: float = 1, *rest):
        """Engine-labeled increment that respects the on/off switch — engine
        code goes through THIS (or a named hook), never ``metric.inc``
        directly, so NullTelemetry can make 'off' actually free."""
        metric.inc(n, self.engine, *rest)

    # ------------------------------------------------------------- hooks ---

    def on_submit(self):
        self.requests.inc(1, self.engine, "submitted")

    def on_reject(self):
        self.requests.inc(1, self.engine, "rejected")

    def on_admit(self, req, slot: int, now: float, prefill_tokens: int,
                 hit_tokens: int = 0):
        """One admission: queue-wait histogram + the prefill-compute /
        reprefill token split (``prefill_tokens`` is what this admission
        schedules through a prefill or chunk program — the prefix-cache hit
        share is already excluded by the caller)."""
        e = self.engine
        self.requests.inc(1, e, "admitted")
        # a re-admission waited since its eviction RE-QUEUED it, not since
        # the original submit
        since = req.requeued_at if req.evictions else req.submitted_at
        self.admission_wait.observe(max(now - since, 0.0), e)
        if prefill_tokens > 0:
            self.tokens.inc(prefill_tokens, e, "prefill_compute")
            if req.evictions:
                self.requests.inc(1, e, "resumed")
                self.tokens.inc(prefill_tokens, e, "reprefill")
        if hit_tokens > 0:
            self.tokens.inc(hit_tokens, e, "prefix_hit")

    def on_token(self, req, now: float, first: bool):
        """EXACTLY-ONCE emission accounting: called once per token appended
        to ``req.out_tokens`` — never from a re-prefill path."""
        self._inc_emitted(1)
        if first:
            self._obs_ttft(max(now - req.submitted_at, 0.0))
        else:
            self._obs_itl(max(now - req.token_times[-2], 0.0))

    def on_finish(self):
        self.requests.inc(1, self.engine, "finished")

    def on_evict(self):
        self.requests.inc(1, self.engine, "evicted")
        self.evictions.inc(1, self.engine)

    def prefix_event(self, event: str, n: int = 1):
        if n:
            self.prefix.inc(n, self.engine, event)

    def on_spec_tick(self, drafted: int, accepted: int, ema: float, k: int):
        e = self.engine
        self.spec_tokens.inc(drafted, e, "drafted")
        self.spec_tokens.inc(accepted, e, "accepted")
        self.spec_accept_ema.set(ema, e)
        self.spec_k.set(k, e)

    def set_resident_adapters(self, n: int):
        self.resident_adapters.set(n, self.engine)

    def set_pool(self, free: int | None = None, cached: int | None = None,
                 queue: int | None = None, active: int | None = None,
                 shift: int | None = None):
        if free is not None:
            self._set_free(free)
        if cached is not None:
            self._set_cached(cached)
        if queue is not None:
            self._set_queue(queue)
        if active is not None:
            self._set_active(active)
        if shift is not None:
            self._set_shift(shift)

    def set_pool_device_bytes(self, bytes_by_device: dict):
        """Per-device KV pool residency (label: device). Called once at cache
        placement — pool shapes and shardings are static for an engine's
        lifetime, so this is NOT a per-tick hook."""
        for device, nbytes in sorted(bytes_by_device.items()):
            self.kv_pool_device_bytes.set(nbytes, self.engine, device)

    @contextmanager
    def measure_tick(self):
        t0 = time.monotonic()
        try:
            yield
        finally:
            self._obs_tick(time.monotonic() - t0)

    @contextmanager
    def measure_program(self, program: str, tier: int = 0, traces=None):
        """Time one jitted call (call + host fetch of its outputs) and run
        the retrace detector: ``traces`` is a zero-arg callable reading the
        engine's python-side trace counter for this program; a positive
        delta on a (program, tier) pair that already ran is a RETRACE."""
        t0 = time.monotonic()
        before = traces() if traces is not None else None
        try:
            yield
        finally:
            dt = time.monotonic() - t0
            e = self.engine
            ts = str(tier)
            obs = self._prog_obs.get((program, ts))
            if obs is None:
                obs = self._prog_obs[(program, ts)] = \
                    self.program.observer(e, program, ts)
            obs(dt)
            if traces is not None:
                delta = traces() - before
                key = (program, ts)
                if delta > 0:
                    self.jit_compiles.inc(delta, e, program, ts)
                    if key in self._seen_programs:
                        self.jit_retraces.inc(delta, e, program, ts)
                self._seen_programs.add(key)
            if self.tracer is not None:
                self.tracer.program_span(program, tier, t0, dt)

    # ------------------------------------------------------------- reads ---

    def retraces(self) -> int:
        """Total steady-state recompiles across every (program, tier)."""
        return int(self.jit_retraces.total())

    def reset_histograms(self):
        """Benchmark seam: drop histogram state after warmup so the measured
        window's percentiles are clean (counters stay cumulative)."""
        for m in self.registry:
            if isinstance(m, Histogram):
                m.reset()

    def snapshot(self) -> dict:
        return self.registry.snapshot()


class NullTelemetry(EngineTelemetry):
    """Telemetry OFF: every hook is a no-op and the timing context managers
    yield without reading the clock. The engines call hooks unconditionally —
    keeping ONE scheduler code path — and this class makes 'off' actually
    free. The registry still exists (declared but never written), so
    ``snapshot()``/``prometheus_text()`` stay callable and simply read empty.
    """

    enabled = False

    def inc(self, metric, n=1, *rest):
        pass

    def on_submit(self):
        pass

    def on_reject(self):
        pass

    def on_admit(self, req, slot, now, prefill_tokens, hit_tokens=0):
        pass

    def on_token(self, req, now, first):
        pass

    def on_finish(self):
        pass

    def on_evict(self):
        pass

    def prefix_event(self, event, n=1):
        pass

    def on_spec_tick(self, drafted, accepted, ema, k):
        pass

    def set_resident_adapters(self, n):
        pass

    def set_pool(self, free=None, cached=None, queue=None, active=None,
                 shift=None):
        pass

    def set_pool_device_bytes(self, bytes_by_device):
        pass

    @contextmanager
    def measure_tick(self):
        yield

    @contextmanager
    def measure_program(self, program, tier=0, traces=None):
        yield


# ----------------------------------------------------- request-level helpers ---


def request_ttft(req) -> float:
    """THE definition of a request's TTFT: first token relative to
    ``submitted_at`` (monotonic). Open-loop harnesses backdate
    ``submitted_at`` to the scheduled arrival via ``submit(...,
    submitted_at=...)``, so queue time the driver loop induces counts."""
    return req.first_token_at - req.submitted_at


def request_itls(req) -> list[float]:
    """THE definition of a request's inter-token latencies: consecutive
    ``token_times`` gaps (eviction gaps included — the resume cost is real
    latency the client observed)."""
    return [b - a for a, b in zip(req.token_times, req.token_times[1:])]


# ---------------------------------------------------------------- provenance ---


def engine_provenance(engine) -> dict:
    """Engine provenance for BENCH_*.json payloads, generated CENTRALLY from
    the ``EngineConfig`` dataclass plus the telemetry-registry snapshot — so
    every benchmark's payload carries IDENTICAL keys and a new config field
    or counter appears everywhere at once instead of per-script."""
    ecfg = engine.ecfg
    mesh = getattr(engine, "mesh", None)
    out = {
        "engine": type(engine).__name__,
        "config": asdict(ecfg),
        "num_blocks": getattr(engine, "num_blocks", None),
        # device topology: BENCH_*.json from sharded and unsharded runs must
        # be distinguishable (None = single-device / no ServingMesh)
        "mesh": mesh.describe() if mesh is not None else None,
    }
    bank = getattr(engine, "bank", None)
    if bank is not None:
        out["bank"] = {
            "num_tiers": len(bank),
            "names": [t.name for t in bank],
        }
    tel = getattr(engine, "metrics", None)
    if tel is not None:
        snap = tel.snapshot()
        # counters + gauges only: histograms are measurement, not provenance
        out["telemetry"] = {
            name: m["values"] for name, m in sorted(snap.items())
            if m["type"] in ("counter", "gauge")
        }
    return out


# ----------------------------------------------------------------- HTTP ---


class _MetricsHandler(http.server.BaseHTTPRequestHandler):
    registries: list[MetricsRegistry] = []

    def do_GET(self):  # noqa: N802 (stdlib API)
        if self.path.split("?")[0] not in ("/metrics", "/"):
            self.send_error(404)
            return
        body = "".join(r.prometheus_text() for r in self.registries).encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *a):   # keep scrapes out of stderr
        pass


def start_metrics_server(registries, port: int = 0, host: str = "127.0.0.1"):
    """Serve the Prometheus text exposition of one or more registries on a
    daemon thread. Returns the live ``ThreadingHTTPServer`` (``server.
    server_address[1]`` is the bound port — pass ``port=0`` for ephemeral);
    call ``server.shutdown()`` when done."""
    if isinstance(registries, MetricsRegistry):
        registries = [registries]
    handler = type("Handler", (_MetricsHandler,),
                   {"registries": list(registries)})
    server = http.server.ThreadingHTTPServer((host, port), handler)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    return server


# ------------------------------------------------------------- validation ---


_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})?\s+[-+0-9.eEinfa]+$"
)


def validate_prometheus_text(text: str) -> dict:
    """Light structural validation of the text exposition format: every
    non-comment line parses as a sample, every TYPE is legal, histogram
    series carry _bucket/_sum/_count. Returns {families, samples} counts;
    raises ValueError on malformed input (the CI telemetry smoke gate)."""
    families: dict[str, str] = {}
    samples = 0
    for ln in text.splitlines():
        if not ln.strip():
            continue
        if ln.startswith("# TYPE "):
            _, _, name, kind = ln.split(None, 3)
            if kind not in ("counter", "gauge", "histogram", "summary",
                            "untyped"):
                raise ValueError(f"illegal TYPE {kind!r} for {name}")
            families[name] = kind
            continue
        if ln.startswith("#"):
            continue
        if not _SAMPLE_RE.match(ln):
            raise ValueError(f"malformed sample line: {ln!r}")
        samples += 1
    for name, kind in families.items():
        if kind == "histogram":
            for suffix in ("_bucket", "_sum", "_count"):
                if not re.search(rf"^{re.escape(name)}{suffix}[{{ ]", text,
                                 re.M):
                    raise ValueError(
                        f"histogram {name} missing {name}{suffix} series"
                    )
    if not families:
        raise ValueError("no metric families found")
    return {"families": len(families), "samples": samples}


def _main(argv=None) -> int:
    import argparse
    import pathlib
    import sys

    ap = argparse.ArgumentParser(
        description="validate a Prometheus text exposition file"
    )
    ap.add_argument("cmd", choices=["validate"])
    ap.add_argument("path")
    a = ap.parse_args(argv)
    try:
        rep = validate_prometheus_text(pathlib.Path(a.path).read_text())
    except ValueError as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 1
    print(json.dumps({"ok": True, **rep}))
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
