"""int8 KV-cache quantization — the serving-memory lever the roofline found.

The decode cells are memory/collective-bound with the KV cache as the
dominant resident tensor (e.g. gemma decode_32k: 1.9 TB global at bf16).
Per-(head, position) symmetric int8 quantization halves it vs bf16 with
attention-quality error bounded by scale/127 per element — and it composes
with the paper's SLR weight compression: weights shrink via SALAAD+HPA, the
cache shrinks here, both feed the same deployment-memory budget.

Layout mirrors LMCache: q8 payload (L, B, H, S, D) int8 + scales
(L, B, H, S, 1) f32 (per-token-per-head scales make appends exact: one new
token never re-scales history).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantKVCache(NamedTuple):
    k_q: jax.Array       # (L, B, H, S, D) int8
    k_scale: jax.Array   # (L, B, H, S, 1) f32
    v_q: jax.Array
    v_scale: jax.Array
    length: jax.Array


def quantize_kv(k: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 over ``axis`` (head_dim). Returns (q, scale)."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=axis, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_cache(cache) -> QuantKVCache:
    """LMCache -> QuantKVCache."""
    k_q, k_s = quantize_kv(cache.k)
    v_q, v_s = quantize_kv(cache.v)
    return QuantKVCache(k_q, k_s, v_q, v_s, cache.length)


def dequantize_cache(qc: QuantKVCache, dtype=jnp.bfloat16):
    from ..models.transformer import LMCache

    return LMCache(
        k=dequantize_kv(qc.k_q, qc.k_scale, dtype),
        v=dequantize_kv(qc.v_q, qc.v_scale, dtype),
        length=qc.length,
    )


def append_token(qc: QuantKVCache, k_new: jax.Array, v_new: jax.Array) -> QuantKVCache:
    """Insert one (L, B, H, 1, D) step at position ``length`` — history is
    untouched (per-token scales), so repeated appends are drift-free."""
    k_q, k_s = quantize_kv(k_new)
    v_q, v_s = quantize_kv(v_new)
    at = (0, 0, 0, qc.length, 0)
    return QuantKVCache(
        k_q=jax.lax.dynamic_update_slice(qc.k_q, k_q, at),
        k_scale=jax.lax.dynamic_update_slice(qc.k_scale, k_s, at),
        v_q=jax.lax.dynamic_update_slice(qc.v_q, v_q, at),
        v_scale=jax.lax.dynamic_update_slice(qc.v_scale, v_s, at),
        length=qc.length + k_new.shape[3],
    )


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache) if hasattr(x, "size"))
