"""Adam optimizer (paper's base optimizer, zero weight decay by default).

Custom implementation (no optax in the container): moments are stored in f32
regardless of param dtype (mixed-precision training at scale), and the tree
layout is plain dicts so moment leaves inherit the weight's NamedSharding
(ZeRO-style sharded optimizer state for free under GSPMD).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0   # paper §5.1: zero weight decay
    grad_clip: float = 1.0      # global-norm clip; 0 disables


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def init_adam(params: Any) -> AdamState:
    f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamState(
        mu=jax.tree.map(f32zeros, params),
        nu=jax.tree.map(f32zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(
    grads: Any, state: AdamState, params: Any, cfg: AdamConfig, lr_scale: jax.Array | float = 1.0
) -> tuple[Any, AdamState]:
    """Returns (new_params, new_state)."""
    if cfg.grad_clip:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    count = state.count + 1
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(new_mu, new_nu, count)
