"""Learning-rate schedules (warmup + cosine, the LLaMA/GaLore standard)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10000, floor: float = 0.1):
    """Multiplicative LR scale in [floor, 1]."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos


def constant(step, **_):
    return jnp.ones_like(jnp.asarray(step, jnp.float32))
