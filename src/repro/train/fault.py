"""Fault tolerance & straggler mitigation utilities.

On a real multi-host pod the failure modes are: host crash (handled by
checkpoint/restart — the coordinator restarts the job and every host calls
``restore``), hung collective (handled by the watchdog timeout below), and
persistent stragglers (handled by step-time anomaly detection feeding the
operator/autoscaler decision to evict a host and resume on a smaller mesh —
which our elastic checkpoint restore supports directly).

Everything here is host-side control plane: pure Python, no jax state, fully
unit-testable without hardware.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    """EWMA step-time anomaly detector.

    ``update`` returns True when the current step is ``threshold`` x slower
    than the running mean — the trainer logs it, and after ``evict_after``
    consecutive anomalies recommends eviction/rescale (the decision is
    surfaced, not auto-executed: on TPU pods the reconfiguration is the
    platform's job; ours is to detect and to be restartable at any step).
    """

    alpha: float = 0.1
    threshold: float = 2.0
    evict_after: int = 5
    _mean: float = 0.0
    _n: int = 0
    _consecutive: int = 0

    def update(self, step_seconds: float) -> bool:
        self._n += 1
        if self._n <= 3:  # warmup: compile steps are slow
            self._mean = step_seconds if self._mean == 0 else self._mean
            return False
        slow = step_seconds > self.threshold * max(self._mean, 1e-9)
        self._mean = (1 - self.alpha) * self._mean + self.alpha * step_seconds
        self._consecutive = self._consecutive + 1 if slow else 0
        return slow

    @property
    def should_evict(self) -> bool:
        return self._consecutive >= self.evict_after


class Watchdog:
    """Deadline watchdog around device computations.

    A hung collective never returns; ``arm`` starts a timer that fires
    ``on_timeout`` (default: raises in the main thread via a flag the train
    loop checks) unless ``disarm`` is called first.
    """

    def __init__(self, timeout_s: float, on_timeout=None):
        self.timeout_s = timeout_s
        self.expired = False
        self._timer: threading.Timer | None = None
        self._on_timeout = on_timeout

    def _fire(self):
        self.expired = True
        if self._on_timeout:
            self._on_timeout()

    def arm(self):
        self.disarm()
        self.expired = False
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def __enter__(self):
        self.arm()
        return self

    def __exit__(self, *exc):
        self.disarm()
        return False


@dataclass
class RetryPolicy:
    """Retry transient failures (preemption notices, flaky interconnect)."""

    max_retries: int = 3
    backoff_s: float = 1.0

    def run(self, fn, *args, is_transient=lambda e: True, on_retry=None, **kw):
        last = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args, **kw)
            except Exception as e:  # noqa: BLE001
                last = e
                if attempt == self.max_retries or not is_transient(e):
                    raise
                if on_retry:
                    on_retry(attempt, e)
                time.sleep(self.backoff_s * (2 ** attempt))
        raise last  # unreachable
