"""The SALAAD trainer: Algorithm 1 end to end, with checkpointing, fault
tolerance, and optional vanilla/baseline modes (used by benchmarks).

Loop structure (paper Algorithm 1):
    for each outer phase:
        K x  train_step   (stage 1: coupled loss, any optimizer)
        1 x  admm_step    (stage 2: proximal sweep + I-controller)

Deterministic restart: data batches and rSVD sketches are pure functions of
the step counter, so (restore at step s) replays bit-identically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from ..core.admm import SalaadConfig
from ..core.selection import select_blocks
from ..models import model as model_lib
from ..optim.adam import AdamConfig
from ..optim.schedule import constant, warmup_cosine
from . import checkpoint
from .fault import StragglerDetector, Watchdog
from .state import TrainState, init_train_state
from .steps import make_admm_step, make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 200
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep_ckpts: int = 3
    log_every: int = 20
    salaad: SalaadConfig | None = field(default_factory=SalaadConfig)
    adam: AdamConfig = field(default_factory=AdamConfig)
    schedule: Callable = warmup_cosine
    accum_steps: int = 1
    step_timeout_s: float = 0.0   # 0 = watchdog off (CPU tests are slow)
    donate: bool = True


class Trainer:
    def __init__(self, arch_cfg, tcfg: TrainerConfig, mesh=None):
        self.arch_cfg = arch_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.straggler = StragglerDetector()
        self.metrics_log: list[dict] = []
        self.events: list[str] = []

    # ------------------------------------------------------------ setup ---

    def init(self, key) -> TrainState:
        params = model_lib.init_params(self.arch_cfg, key)
        state, self.blocks = init_train_state(params, self.tcfg.salaad)
        self._train_step = jax.jit(
            make_train_step(
                self.arch_cfg,
                self.blocks,
                self.tcfg.adam,
                self.tcfg.schedule,
                self.tcfg.accum_steps,
            ),
            donate_argnums=(0,) if self.tcfg.donate else (),
        )
        if self.tcfg.salaad is not None and self.blocks:
            self._admm_step = jax.jit(
                make_admm_step(self.tcfg.salaad, self.blocks), donate_argnums=()
            )
        else:
            self._admm_step = None
        return state

    def maybe_restore(self, state: TrainState) -> TrainState:
        if self.tcfg.ckpt_dir:
            step = checkpoint.latest_step(self.tcfg.ckpt_dir)
            if step is not None:
                self.events.append(f"restored step {step}")
                return checkpoint.restore(self.tcfg.ckpt_dir, state)
        return state

    # ------------------------------------------------------------- loop ---

    def fit(self, state: TrainState, data, steps: int | None = None) -> TrainState:
        steps = steps or self.tcfg.total_steps
        k_every = self.tcfg.salaad.update_every if self.tcfg.salaad else 0
        start = int(state.step)
        wd = Watchdog(self.tcfg.step_timeout_s) if self.tcfg.step_timeout_s else None

        for step in range(start, steps):
            batch = data.batch(step) if hasattr(data, "batch") else next(data)
            t0 = time.time()
            if wd:
                wd.arm()
            state, metrics = self._train_step(state, batch)
            loss = float(metrics["loss"])  # blocks until step finishes
            if wd:
                wd.disarm()
                if wd.expired:
                    self.events.append(f"watchdog expired at step {step}")
            dt = time.time() - t0
            if self.straggler.update(dt):
                self.events.append(f"straggler: step {step} took {dt:.2f}s")

            if self._admm_step and k_every and (step + 1) % k_every == 0:
                state, admm_stats = self._admm_step(state)
                self.metrics_log.append(
                    {
                        "step": step,
                        "admm_recon_err": float(admm_stats["_mean_recon_err"]),
                    }
                )

            if step % self.tcfg.log_every == 0 or step == steps - 1:
                self.metrics_log.append({"step": step, "loss": loss, "sec": dt})

            if (
                self.tcfg.ckpt_dir
                and self.tcfg.ckpt_every
                and (step + 1) % self.tcfg.ckpt_every == 0
            ):
                checkpoint.save(
                    self.tcfg.ckpt_dir, step + 1, state, keep=self.tcfg.keep_ckpts
                )
        return state

    # ------------------------------------------------------- deployment ---

    def surrogate(self, state: TrainState):
        """Structured surrogate X_hat = L + S (the deployed model)."""
        from ..core.admm import surrogate_params

        return surrogate_params(state.params, state.slr, self.blocks)

    def compress(self, state: TrainState, remove_budget: int, kappa: float):
        from ..core.hpa import hpa_compress

        return hpa_compress(state.slr, self.blocks, remove_budget, kappa)
