"""Jit-able step functions: stage-1 train step (coupled loss), stage-2 ADMM
step, and serving steps. Shared by the trainer, the dry-run, and benchmarks —
what gets lowered for the roofline IS what the trainer runs.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.admm import SalaadConfig, admm_update, penalty
from ..core.selection import BlockInfo
from ..models import model
from ..optim.adam import AdamConfig, adam_update
from ..optim.schedule import warmup_cosine
from .state import TrainState


def make_train_step(
    arch_cfg,
    blocks: list[BlockInfo],
    adam_cfg: AdamConfig = AdamConfig(),
    schedule: Callable = warmup_cosine,
    accum_steps: int = 1,
    aux_weight: float = 0.01,
    pre_split: bool = False,
):
    """Stage-1 guided learning step: l_c = task + SALAAD penalty, Adam update.

    ``accum_steps > 1`` splits the batch into microbatches and accumulates
    grads with lax.scan — trades peak activation memory for sequential steps
    and lets XLA overlap the per-microbatch reduce-scatter with compute.
    ``pre_split``: the batch already carries a leading (accum_steps,) axis
    (the SPMD launcher pre-splits on the host — reshaping a data-sharded
    batch inside the program trips an XLA SPMD verifier bug, observed on
    dbrx train_4k with accum=4).
    """

    def loss_fn(params, slr, batch):
        task, metrics = model.loss_fn(params, batch, arch_cfg, aux_weight=aux_weight)
        pen = penalty(params, slr, blocks) if blocks else jnp.zeros((), jnp.float32)
        return task + pen, {**metrics, "penalty": pen, "task_loss": task}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(state.params, state.slr, batch)
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = grad_fn(state.params, state.slr, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            mbs = batch if pre_split else jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                batch,
            )
            (grads, loss), _ = jax.lax.scan(micro, (zeros, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {}
        lr_scale = schedule(state.step)
        new_params, new_opt = adam_update(grads, state.opt, state.params, adam_cfg, lr_scale)
        new_state = TrainState(
            params=new_params, opt=new_opt, slr=state.slr, step=state.step + 1
        )
        return new_state, {"loss": loss, **metrics}

    return train_step


def make_admm_step(salaad_cfg: SalaadConfig, blocks: list[BlockInfo]):
    """Stage-2: proximal sweep + I-controller over every block."""

    def admm_step(state: TrainState) -> tuple[TrainState, dict]:
        new_slr, stats = admm_update(state.params, state.slr, blocks, salaad_cfg, state.step)
        return state._replace(slr=new_slr), stats

    return admm_step


def make_prefill_step(arch_cfg, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, arch_cfg, max_len)

    return prefill_step


def make_decode_step(arch_cfg):
    def decode_step(params, token, cache):
        return model.decode_step(params, token, cache, arch_cfg)

    return decode_step
