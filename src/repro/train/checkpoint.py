"""Fault-tolerant checkpointing: atomic writes, manifest, keep-last-k,
and ELASTIC restore (a checkpoint saved on one mesh restores onto any other).

Format: one .npz per checkpoint holding every leaf (flattened by path key)
plus a JSON manifest with step, tree structure and SALAAD static metadata.
Writes go to ``<dir>/tmp.<step>`` then ``os.replace`` into place — a crashed
writer can never corrupt the latest checkpoint (restart-safety invariant,
tested by killing a writer mid-stream in tests/test_checkpoint.py).

Elastic restore: leaves are saved as full (unsharded) host arrays; loading
calls ``jax.device_put`` with the TARGET mesh's shardings, so a run can
resume on a different device count / mesh shape (tested 8 -> 4 -> 8 devices
in a subprocess with forced host devices).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any

import jax
import numpy as np

from ..core.selection import path_str

MANIFEST = "manifest.json"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}

    def rec(path, leaf):
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't round-trip ml_dtypes
            arr = arr.astype(np.float32)
        flat[path_str(path)] = arr
        return leaf

    jax.tree_util.tree_map_with_path(rec, tree)
    return flat


def save(ckpt_dir: str, step: int, state: Any, keep: int = 3, extra: dict | None = None):
    """Atomic checkpoint write. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    tmp = os.path.join(ckpt_dir, f".tmp.{step}.{os.getpid()}")
    os.makedirs(tmp, exist_ok=True)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": int(step), "time": time.time(), **(extra or {})}, f)
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    _update_manifest(ckpt_dir, step)
    _gc(ckpt_dir, keep)
    return os.path.join(ckpt_dir, f"step_{step:010d}")


def _update_manifest(ckpt_dir: str, step: int):
    path = os.path.join(ckpt_dir, MANIFEST)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        json.dump({"latest_step": int(step)}, f)
    os.replace(tmp, path)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, name)):
            if os.path.exists(os.path.join(ckpt_dir, name, "arrays.npz")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> int | None:
    man = os.path.join(ckpt_dir, MANIFEST)
    if os.path.exists(man):
        with open(man) as f:
            step = json.load(f).get("latest_step")
        if step is not None and step in all_steps(ckpt_dir):
            return step
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_state: Any, step: int | None = None, shardings: Any = None) -> Any:
    """Restore into the structure of ``target_state`` (elastic across meshes).

    ``shardings``: optional matching pytree of NamedSharding for the TARGET
    mesh; when given, each leaf is device_put with its new sharding.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "arrays.npz")
    arrays = np.load(path)

    flat_shardings = {}
    if shardings is not None:
        def rec_s(p, leaf):
            flat_shardings[path_str(p)] = leaf
            return leaf

        jax.tree_util.tree_map_with_path(rec_s, shardings)

    def rebuild(p, leaf):
        key = path_str(p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        dtype = leaf.dtype if hasattr(leaf, "dtype") else None
        val = arr.astype(dtype) if dtype is not None and arr.dtype != dtype else arr
        sh = flat_shardings.get(key)
        return jax.device_put(val, sh) if sh is not None else jax.numpy.asarray(val)

    return jax.tree_util.tree_map_with_path(rebuild, target_state)
