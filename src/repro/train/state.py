"""TrainState: everything a training step touches, as one pytree.

SALAAD surrogate state rides along (``slr``); the stage-1 step only *reads*
it (the penalty target Z is derived in-graph from the compact (p, vt, coo, y)
storage), the stage-2 ``admm_step`` replaces it every K steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core.admm import SalaadConfig, SLRState, init_slr_state
from ..core.selection import BlockInfo
from ..optim.adam import AdamConfig, AdamState, init_adam


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    slr: SLRState           # {} when SALAAD is disabled (vanilla baseline)
    step: jax.Array


def init_train_state(
    params: Any, salaad_cfg: SalaadConfig | None
) -> tuple[TrainState, list[BlockInfo]]:
    if salaad_cfg is None:
        slr, blocks = {}, []
    else:
        slr, blocks = init_slr_state(params, salaad_cfg)
    return (
        TrainState(params=params, opt=init_adam(params), slr=slr, step=jnp.zeros((), jnp.int32)),
        blocks,
    )


def abstract_train_state(params_abstract: Any, salaad_cfg: SalaadConfig | None) -> TrainState:
    """ShapeDtypeStruct TrainState for the dry-run (no allocation)."""

    def mk(p):
        state, _ = init_train_state(p, salaad_cfg)
        return state

    return jax.eval_shape(mk, params_abstract)
