"""Serving launcher: load a checkpoint (or fresh init), deploy the SLR model
across one or more HPA budgets, and serve batched requests through the
SLR-native engine — the elastic-deployment spectrum through the fast path.
Default engine is the block-paged continuously-batched one; size its KV pool
with --block-size/--num-blocks and (optionally) quantize it with --kv-dtype.

  python -m repro.launch.serve --arch salaad_llama_60m --reduced \
      --keep-ratios 1.0,0.6,0.3 --fmt factored --kappa 0.7 --requests 8 \
      --block-size 16 --slo-ms 2000
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, init_slr_state
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.models import model as model_lib
from repro.serving.deployed import DeployedModel
from repro.serving.engine import (
    BATCHED_FAMILIES,
    EngineConfig,
    PagedServingEngine,
    ReferenceEngine,
    ServingEngine,
    decode_emitted_tokens,
)
from repro.serving.slr_params import deployment_report
from repro.serving.speculative import SpeculativeEngine

ENGINES = {
    "paged": PagedServingEngine,
    "batched": ServingEngine,
    "reference": ReferenceEngine,
    "speculative": SpeculativeEngine,
}


def serve_batch(engine, vocab: int, requests: int, max_new: int, seed: int,
                slo_ms: float | None = None) -> dict:
    rng = np.random.RandomState(seed)
    submitted = time.time()          # deadlines are a wall-clock contract
    for _ in range(requests):
        prompt = rng.randint(0, vocab, size=rng.randint(2, 8)).tolist()
        engine.submit(
            prompt, max_new_tokens=max_new,
            deadline=None if slo_ms is None else submitted + slo_ms / 1e3,
        )
    # engine timestamps (first_token_at etc.) are time.monotonic() values, so
    # latency math must use the same clock — an NTP step mid-run would
    # otherwise produce negative TTFT
    t0 = time.monotonic()
    done = engine.run()
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    stats = {
        "requests": len(done),
        "tokens": total_tokens,
        "tok_per_s": round(total_tokens / max(dt, 1e-9), 2),
        "sample": done[0].out_tokens if done else [],
    }
    ttft = [r.first_token_at - t0 for r in done if r.first_token_at]
    if ttft:
        stats["ttft_p50_ms"] = round(float(np.percentile(ttft, 50)) * 1e3, 1)
        stats["ttft_p99_ms"] = round(float(np.percentile(ttft, 99)) * 1e3, 1)
    if slo_ms is not None and ttft:
        stats["slo_ms"] = slo_ms
        stats["slo_attainment"] = round(
            sum(t * 1e3 <= slo_ms for t in ttft) / len(ttft), 3
        )
    if hasattr(engine, "evictions"):
        stats["evictions"] = engine.evictions
    if hasattr(engine, "acceptance_rate"):
        stats["acceptance_rate"] = round(engine.acceptance_rate, 3)
        stats["tokens_per_step"] = round(
            decode_emitted_tokens(done) / max(engine.decode_calls, 1), 2
        )
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument(
        "--keep-ratios", default=None,
        help="comma-separated HPA budgets, e.g. 1.0,0.6,0.3 (omit: serve dense init)",
    )
    ap.add_argument("--fmt", default="factored", choices=("dense", "factored", "bsr"))
    ap.add_argument("--engine", default="paged", choices=tuple(ENGINES))
    ap.add_argument("--kappa", type=float, default=0.7)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV page size in tokens (paged engine)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV page pool size; None = max_slots * max_len worth")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: process prompts in block-aligned "
                         "chunks of this many tokens interleaved with decode "
                         "ticks (paged engine; None = one-shot prefill)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="TTFT SLO; reports attainment and sets request deadlines")
    ap.add_argument("--kv-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"),
                    help="KV storage dtype; int8 stores quantized pages (paged)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft window (tokens/slot/tick); > 0 "
                         "serves through the SpeculativeEngine")
    ap.add_argument("--spec-budget", type=float, default=0.4,
                    help="HPA keep-ratio of the self-speculation draft "
                         "(the low-budget end of the elastic spectrum)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="adapt the draft window from observed acceptance")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)

    scfg = SalaadConfig(selection=SelectionConfig(min_dim=16))
    if args.ckpt_dir:
        from repro.train import checkpoint
        from repro.train.state import init_train_state

        state, blocks = init_train_state(params, scfg)
        state = checkpoint.restore(args.ckpt_dir, state)
        slr, params = state.slr, state.params
    else:
        slr, blocks = init_slr_state(params, scfg)

    engine_cls = ENGINES[args.engine]
    spec_k = args.spec_k
    if engine_cls is SpeculativeEngine and spec_k == 0:
        spec_k = 4
    if spec_k > 0 and engine_cls is PagedServingEngine:
        engine_cls = SpeculativeEngine            # --spec-k implies speculation
    if engine_cls is not ReferenceEngine and cfg.family not in BATCHED_FAMILIES:
        # explicit capability line; paged-only features requested on this
        # family then fail loudly in the ReferenceEngine constructor
        # (EngineCapabilityError) instead of silently degrading
        print(json.dumps({"note": f"family {cfg.family!r} has no per-slot-length "
                          "cache yet; falling back to the reference engine "
                          "(per-slot loop; float32 contiguous cache; no "
                          "kv_dtype / speculative decoding)"}))
        engine_cls = ReferenceEngine
    ecfg = EngineConfig(
        max_slots=args.max_slots, max_len=args.max_len,
        block_size=args.block_size, num_blocks=args.num_blocks,
        kv_dtype=args.kv_dtype, prefill_chunk=args.prefill_chunk,
        spec_k=spec_k, spec_adaptive=args.spec_adaptive,
    )

    def build_engine(weights, draft=None):
        if engine_cls is SpeculativeEngine:
            # self-speculation: default draft is the target itself (useful for
            # dense-init smoke; real deployments pass an HPA-truncated draft)
            return SpeculativeEngine(
                cfg, weights, weights if draft is None else draft, ecfg
            )
        return engine_cls(cfg, weights, ecfg)

    if args.keep_ratios is None:
        engine = build_engine(params)
        print(json.dumps({"budget": None, "fmt": "dense-init",
                          **serve_batch(engine, cfg.vocab_size, args.requests,
                                        args.max_new, args.seed, args.slo_ms)}))
        return

    # one SALAAD state, a spectrum of served capacities — each budget deploys
    # and serves through the same batched SLR-native programs; under
    # speculation the SAME state also yields the draft (the elastic spectrum's
    # low-budget end, --spec-budget)
    for keep in [float(k) for k in args.keep_ratios.split(",")]:
        slr_c, report = hpa_keep_ratio(slr, blocks, keep, args.kappa)
        deployed = DeployedModel.build(cfg, params, slr_c, blocks, fmt=args.fmt)
        draft = None
        if engine_cls is SpeculativeEngine:
            draft_keep = min(args.spec_budget, keep)
            slr_d, _ = hpa_keep_ratio(slr, blocks, draft_keep, args.kappa)
            draft = DeployedModel.build(cfg, params, slr_d, blocks, fmt=args.fmt)
        engine = build_engine(deployed, draft)
        stats = serve_batch(engine, cfg.vocab_size, args.requests, args.max_new,
                            args.seed, args.slo_ms)
        dep = deployment_report(params, slr_c, blocks)
        print(json.dumps({
            "budget": keep,
            "fmt": args.fmt,
            "slr_params": report["params_after"],
            "served_bytes": deployed.param_bytes()["total_bytes"],
            "slr_total_bytes": dep["slr_total_bytes"],
            "compression": round(dep["compression"], 3),
            **stats,
        }))


if __name__ == "__main__":
    main()
