"""Serving launcher: load a checkpoint (or fresh init), materialize the HPA
budget spectrum as ONE ModelBank, and serve batched requests through a single
engine — elastic deployment as a serving-time dimension, not a rebuild loop.

``--keep-ratios`` names the bank's budget tiers (tier 0 = largest). Requests
spread round-robin across the tiers (pin them all with ``--tier``); the paged
engine runs one pre-compiled jitted step per active tier over the shared
paged KV, and ``--tier-policy pressure`` turns on the controller that
downshifts the serving tier under page pressure before resorting to
eviction. All engines implement the ``serving.elastic.Engine`` protocol
(submit / step / run / has_work / capabilities); the per-engine capability
table is printed in ``--help``.

``--adapters N`` switches to multi-tenant serving: N SLR adapters (HPA views
at spread budgets) are registered over ONE shared base and served through a
single ``serving.adapters.AdapterBank`` engine, requests round-robin across
tenants; ``--max-resident-adapters`` bounds the device adapter pool and turns
on LRU swapping (docs/serving.md, "Multi-tenant adapters").

Observability (docs/observability.md): ``--trace-out trace.json`` records a
per-request span trace and writes Chrome trace-event JSON (open in Perfetto);
``--metrics-port N`` serves the Prometheus text exposition of the engine's
metrics registry on ``127.0.0.1:N/metrics`` for the duration of the run
(0 = ephemeral); ``--metrics-out`` persists one scrape to a file. The
printed stats derive from ``engine.stats_snapshot()`` — the same registry
the exporter serves.

  python -m repro.launch.serve --arch salaad_llama_60m --reduced \
      --keep-ratios 1.0,0.6,0.3 --fmt factored --kappa 0.7 --requests 8 \
      --block-size 16 --slo-ms 2000 --tier-policy pressure \
      --trace-out trace.json --metrics-port 0 --metrics-out metrics.txt
"""
from __future__ import annotations

import argparse
import json
import time
import urllib.request

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, init_slr_state
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.models import model as model_lib
from repro.serving.adapters import AdapterBank, adapterize
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank, format_capability_table
from repro.serving.engine import (
    BATCHED_FAMILIES,
    EngineConfig,
    PagedServingEngine,
    ReferenceEngine,
    ServingEngine,
    decode_emitted_tokens,
)
from repro.serving.slr_params import deployment_report
from repro.serving.speculative import SpeculativeEngine
from repro.serving.telemetry import start_metrics_server

ENGINES = {
    "paged": PagedServingEngine,
    "batched": ServingEngine,
    "reference": ReferenceEngine,
    "speculative": SpeculativeEngine,
}


def serve_batch(engine, vocab: int, requests: int, max_new: int, seed: int,
                slo_ms: float | None = None, tiers=(None,),
                adapters=(None,)) -> dict:
    """Drive one engine (Engine protocol) over a random trace, requests
    spread round-robin over ``tiers`` (and, for an AdapterBank engine, over
    ``adapters``); per-tier / per-adapter token counts ride in the stats so
    the elastic spectrum and the tenant mix stay visible in one output."""
    rng = np.random.RandomState(seed)
    # with the prompt cache on, give the trace something to share: every
    # request opens with the same two-page "system prompt"
    shared: list[int] = []
    if getattr(engine, "_prefix", None) is not None:
        shared = rng.randint(0, vocab, size=2 * engine.ecfg.block_size).tolist()
    submitted = time.time()          # deadlines are a wall-clock contract
    for i in range(requests):
        prompt = shared + rng.randint(0, vocab, size=rng.randint(2, 8)).tolist()
        engine.submit(
            prompt, max_new_tokens=max_new,
            deadline=None if slo_ms is None else submitted + slo_ms / 1e3,
            tier=tiers[i % len(tiers)],
            adapter=adapters[i % len(adapters)],
        )
    # engine timestamps (first_token_at etc.) are time.monotonic() values, so
    # latency math must use the same clock — an NTP step mid-run would
    # otherwise produce negative TTFT
    t0 = time.monotonic()
    done = engine.run()
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    snap = engine.stats_snapshot()
    stats = {
        "requests": len(done),
        "tokens": total_tokens,
        "tok_per_s": round(total_tokens / max(dt, 1e-9), 2),
        "sample": done[0].out_tokens if done else [],
        "steps": snap["steps"],
        "jit_retraces": snap["jit_retraces"],
    }
    by_tier: dict[int, int] = {}
    for r in done:
        by_tier[r.tier] = by_tier.get(r.tier, 0) + len(r.out_tokens)
    if len(by_tier) > 1 or (by_tier and next(iter(by_tier)) != 0):
        stats["tokens_by_tier"] = {str(k): v for k, v in sorted(by_tier.items())}
    by_adapter: dict[int, int] = {}
    for r in done:
        if r.adapter is not None:
            by_adapter[r.adapter] = by_adapter.get(r.adapter, 0) \
                + len(r.out_tokens)
    if by_adapter:
        stats["tokens_by_adapter"] = {
            str(k): v for k, v in sorted(by_adapter.items())
        }
    # TTFT on the submitted_at basis (every request here is submitted before
    # run() starts, so this matches the old run-start basis); percentiles
    # come from the registry histogram when telemetry is on
    ttft = [r.first_token_at - r.submitted_at for r in done if r.first_token_at]
    tel = engine.metrics
    if tel.ttft.count(tel.engine):
        stats["ttft_p50_ms"] = round(tel.ttft.percentile(50, tel.engine) * 1e3, 1)
        stats["ttft_p99_ms"] = round(tel.ttft.percentile(99, tel.engine) * 1e3, 1)
    elif ttft:
        stats["ttft_p50_ms"] = round(float(np.percentile(ttft, 50)) * 1e3, 1)
        stats["ttft_p99_ms"] = round(float(np.percentile(ttft, 99)) * 1e3, 1)
    if slo_ms is not None and ttft:
        stats["slo_ms"] = slo_ms
        stats["slo_attainment"] = round(
            sum(t * 1e3 <= slo_ms for t in ttft) / len(ttft), 3
        )
    if hasattr(engine, "evictions"):
        stats["evictions"] = engine.evictions
    if getattr(engine, "_prefix", None) is not None:
        stats["prefix_cache"] = {
            "lookups": engine.prefix_lookups,
            "hits": engine.prefix_hits,
            "hit_tokens": engine.prefix_hit_tokens,
            "cow_copies": engine.cow_copies,
            "reattached_pages": engine.reattached_pages,
            "cached_pages": engine._prefix.pages,
        }
    if getattr(engine, "tier_controller", None) is not None:
        stats["downshift_ticks"] = engine.downshift_ticks
        stats["tier_switches"] = engine.tier_switches
    if hasattr(engine, "acceptance_rate"):
        stats["acceptance_rate"] = round(engine.acceptance_rate, 3)
        stats["tokens_per_step"] = round(
            decode_emitted_tokens(done) / max(engine.decode_calls, 1), 2
        )
    return stats


def serve_with_observability(engine, args, vocab: int, tiers=(None,),
                             adapters=(None,)) -> dict:
    """Run ``serve_batch`` with the requested exports attached: a request
    tracer when ``--trace-out``/``--trace-events`` is set, and a live
    Prometheus endpoint when ``--metrics-port`` is set (``--metrics-out``
    scrapes it over HTTP so CI validates the real exposition path)."""
    tracer = None
    if args.trace_out or args.trace_events:
        tracer = engine.start_trace()
    server = None
    if args.metrics_port is not None:
        server = start_metrics_server(engine.metrics.registry,
                                      port=args.metrics_port)
    stats = serve_batch(engine, vocab, args.requests, args.max_new,
                        args.seed, args.slo_ms, tiers=tiers,
                        adapters=adapters)
    if server is not None:
        port = server.server_address[1]
        stats["metrics_port"] = port
        if args.metrics_out:
            with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics"
            ) as resp:
                text = resp.read().decode()
            with open(args.metrics_out, "w") as f:
                f.write(text)
            stats["metrics_out"] = args.metrics_out
        server.shutdown()
    elif args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(engine.metrics.registry.prometheus_text())
        stats["metrics_out"] = args.metrics_out
    if tracer is not None:
        if args.trace_out:
            tracer.save_chrome(args.trace_out)
            stats["trace_out"] = args.trace_out
        if args.trace_events:
            tracer.save_jsonl(args.trace_events)
            stats["trace_events"] = args.trace_events
    return stats


def main():
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="engine capabilities (serving.elastic.Engine protocol):\n\n"
        + format_capability_table(ENGINES),
    )
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument(
        "--keep-ratios", default=None,
        help="comma-separated HPA budgets materialized as ONE ModelBank's "
             "tiers, largest first, e.g. 1.0,0.6,0.3 (omit: serve dense init)",
    )
    ap.add_argument(
        "--fmt", "--format", dest="fmt", default="factored",
        choices=("dense", "factored", "bsr", "fused"),
        help="deployment format (docs/serving.md#deployment-formats): 'fused' "
             "runs one Pallas pass per linear site with layer-stacked tables",
    )
    ap.add_argument("--engine", default="paged", choices=tuple(ENGINES))
    ap.add_argument("--kappa", type=float, default=0.7)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV page size in tokens (paged engine)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV page pool size; None = max_slots * max_len worth")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: process prompts in block-aligned "
                         "chunks of this many tokens interleaved with decode "
                         "ticks (paged engine; None = one-shot prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prompt cache: share KV pages across requests "
                         "with a common prompt prefix; copy-on-write on first "
                         "divergent write (paged engine)")
    ap.add_argument("--prefix-min-hit", type=int, default=1,
                    help="minimum matched pages before a prefix-cache hit is "
                         "attached (smaller hits prefill from scratch)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="TTFT SLO; reports attainment and sets request deadlines")
    ap.add_argument("--kv-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"),
                    help="KV storage dtype; int8 stores quantized pages (paged)")
    ap.add_argument("--tier", type=int, default=None,
                    help="pin every request to this bank tier (default: "
                         "round-robin across all tiers)")
    ap.add_argument("--tier-policy", default="static",
                    choices=("static", "pressure"),
                    help="pressure: downshift the serving tier under page "
                         "pressure before evicting (paged engine)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft window (tokens/slot/tick); > 0 "
                         "serves through the SpeculativeEngine")
    ap.add_argument("--spec-budget", type=float, default=0.4,
                    help="HPA keep-ratio of the self-speculation draft tier "
                         "(appended to the bank as its cheapest tier)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="adapt the draft window from observed acceptance")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the run here "
                         "(one track per slot + one per jitted program; "
                         "open in Perfetto — see docs/observability.md)")
    ap.add_argument("--trace-events", default=None,
                    help="write the structured JSONL event log here "
                         "(same events as --trace-out, one dict per line)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the Prometheus text exposition on "
                         "127.0.0.1:PORT/metrics during the run (0 = "
                         "ephemeral; the bound port rides in the stats)")
    ap.add_argument("--metrics-out", default=None,
                    help="persist one Prometheus scrape to this file after "
                         "the run (over HTTP when --metrics-port is set)")
    ap.add_argument("--mesh", default=None,
                    help="tensor-parallel serving mesh spec, e.g. "
                         "'model=2,data=1': weights and KV pools shard the "
                         "head/ffn dims over 'model' (must divide the head "
                         "counts); on CPU force devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--adapters", type=int, default=0,
                    help="multi-tenant mode: register N SLR adapters (HPA "
                         "views at spread budgets) over ONE shared base and "
                         "serve them through a single AdapterBank engine, "
                         "requests round-robin across tenants "
                         "(docs/serving.md#multi-tenant-adapters)")
    ap.add_argument("--max-resident-adapters", type=int, default=None,
                    help="device adapter-pool rows; fewer than --adapters "
                         "turns on LRU swapping (None = all resident)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)

    scfg = SalaadConfig(selection=SelectionConfig(min_dim=16))
    if args.ckpt_dir:
        from repro.train import checkpoint
        from repro.train.state import init_train_state

        state, blocks = init_train_state(params, scfg)
        state = checkpoint.restore(args.ckpt_dir, state)
        slr, params = state.slr, state.params
    else:
        slr, blocks = init_slr_state(params, scfg)

    engine_cls = ENGINES[args.engine]
    spec_k = args.spec_k
    if engine_cls is SpeculativeEngine and spec_k == 0:
        spec_k = 4
    if spec_k > 0 and engine_cls is PagedServingEngine:
        engine_cls = SpeculativeEngine            # --spec-k implies speculation
    if engine_cls is not ReferenceEngine and cfg.family not in BATCHED_FAMILIES:
        # explicit capability line (the structured dict a constructor-time
        # EngineCapabilityError would carry); paged-only features requested
        # on this family then fail loudly in the ReferenceEngine constructor
        # instead of silently degrading
        print(json.dumps({
            "note": f"family {cfg.family!r} has no per-slot-length cache "
                    "yet; falling back to the reference engine",
            "capabilities": ReferenceEngine.capabilities(),
        }))
        engine_cls = ReferenceEngine
    ecfg = EngineConfig(
        max_slots=args.max_slots, max_len=args.max_len,
        block_size=args.block_size, num_blocks=args.num_blocks,
        kv_dtype=args.kv_dtype, prefill_chunk=args.prefill_chunk,
        prefix_cache=args.prefix_cache,
        prefix_min_hit_pages=args.prefix_min_hit,
        tier_policy=args.tier_policy,
        spec_k=spec_k, spec_adaptive=args.spec_adaptive,
        mesh=args.mesh,
        adapters=args.adapters > 0,
        max_resident_adapters=args.max_resident_adapters,
    )

    if args.adapters:
        # N tenants as HPA views at spread budgets over ONE shared base —
        # each adapterized onto the base so only the SLR sites differ per
        # tenant and the rest of the tree is stored once
        spread = np.linspace(1.0, 0.4, args.adapters)
        slr_c, _ = hpa_keep_ratio(slr, blocks, 1.0, args.kappa)
        base = DeployedModel.build(cfg, params, slr_c, blocks, fmt=args.fmt)
        tenants = []
        for keep in spread:
            slr_k, _ = hpa_keep_ratio(slr, blocks, float(keep), args.kappa)
            tenants.append(adapterize(
                base, DeployedModel.build(cfg, params, slr_k, blocks,
                                          fmt=args.fmt)))
        bank = AdapterBank(base, tenants,
                           names=[f"tenant{i}" for i in range(args.adapters)])
        engine = engine_cls(bank, ecfg)
        stats = serve_with_observability(
            engine, args, cfg.vocab_size,
            adapters=tuple(range(args.adapters)))
        print(json.dumps({
            "fmt": args.fmt,
            "adapters": bank.adapter_report(),
            **stats,
        }))
        return

    if args.keep_ratios is None:
        bank = ModelBank.single(cfg, params)
        engine = engine_cls(bank, ecfg)
        print(json.dumps({
            "budget": None, "fmt": "dense-init",
            **serve_with_observability(engine, args, cfg.vocab_size),
        }))
        return

    # one SALAAD state, ONE bank, a spectrum of served capacities — every
    # budget is a tier of the same engine (the speculative engine serves its
    # largest budget and drafts with --spec-budget)
    keeps = sorted({float(k) for k in args.keep_ratios.split(",")},
                   reverse=True)
    if engine_cls is SpeculativeEngine:
        target_keep = keeps[0]
        draft_keep = min(args.spec_budget, target_keep)
        dropped = [k for k in keeps[1:] if k != draft_keep]
        if dropped:
            print(json.dumps({
                "note": "speculative mode serves ONE target tier: "
                        f"keep={target_keep} verifies, keep={draft_keep} "
                        f"(--spec-budget) drafts; --keep-ratios {dropped} "
                        "not materialized",
            }))
        keeps = [target_keep] + ([draft_keep] if draft_keep < target_keep
                                 else [])
        ecfg.spec_draft_tier = -1                 # the cheapest tier drafts

    # ONE HPA truncation + deployment per budget: the bank serves these
    # views, and the SAME pass yields the per-tier accounting (no second
    # truncation just for the report)
    models, tier_rows = [], []
    for keep in keeps:
        slr_c, rep = hpa_keep_ratio(slr, blocks, keep, args.kappa)
        models.append(
            DeployedModel.build(cfg, params, slr_c, blocks, fmt=args.fmt)
        )
        dep = deployment_report(params, slr_c, blocks)
        tier_rows.append({
            "slr_params": rep["params_after"],
            "slr_total_bytes": dep["slr_total_bytes"],
            "compression": round(dep["compression"], 3),
        })
    bank = ModelBank(cfg, models, keeps=keeps)
    for tier, row in zip(bank, tier_rows):
        row.update(tier=tier.index, name=tier.name,
                   served_bytes=tier.param_bytes)

    engine = engine_cls(bank, ecfg)
    tiers: tuple = (args.tier,)
    if args.tier is None:
        # round-robin across the budgets (SpeculativeEngine pins every slot
        # to its target tier; its draft tier only drafts)
        tiers = (None,) if engine_cls is SpeculativeEngine \
            else tuple(range(len(bank)))
    stats = serve_with_observability(engine, args, cfg.vocab_size,
                                     tiers=tiers)
    print(json.dumps({
        "fmt": args.fmt,
        "bank": bank.report(),
        "tier_policy": args.tier_policy,
        **stats,
        "tiers": tier_rows,
    }))


if __name__ == "__main__":
    main()
