"""Serving launcher: load a checkpoint (or fresh init), optionally deploy the
SLR surrogate at a parameter budget (HPA), and serve batched requests.

  python -m repro.launch.serve --arch salaad_llama_60m --reduced \
      --keep-ratio 0.6 --kappa 0.7 --requests 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, init_slr_state, surrogate_params
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.models import model as model_lib
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.slr_params import deployment_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--keep-ratio", type=float, default=None, help="HPA budget")
    ap.add_argument("--kappa", type=float, default=0.7)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)

    if args.ckpt_dir:
        from repro.train import checkpoint
        from repro.train.state import init_train_state

        scfg = SalaadConfig(selection=SelectionConfig(min_dim=16))
        state, blocks = init_train_state(params, scfg)
        state = checkpoint.restore(args.ckpt_dir, state)
        slr, params = state.slr, state.params
    else:
        scfg = SalaadConfig(selection=SelectionConfig(min_dim=16))
        slr, blocks = init_slr_state(params, scfg)

    if args.keep_ratio is not None:
        slr, report = hpa_keep_ratio(slr, blocks, args.keep_ratio, args.kappa)
        print("HPA:", json.dumps(report))
        params = surrogate_params(params, slr, blocks)
        print("deployment:", json.dumps(
            {k: v for k, v in deployment_report(params, slr, blocks).items() if k != "blocks"}
        ))

    engine = ServingEngine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    rng = np.random.RandomState(args.seed)
    for _ in range(args.requests):
        prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(2, 8)).tolist()
        engine.submit(prompt, max_new_tokens=args.max_new)
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(
        json.dumps(
            {
                "requests": len(done),
                "tokens": total_tokens,
                "tok_per_s": round(total_tokens / max(dt, 1e-9), 2),
                "sample": done[0].out_tokens if done else [],
            }
        )
    )


if __name__ == "__main__":
    main()
