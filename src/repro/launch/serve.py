"""Serving launcher: load a checkpoint (or fresh init), deploy the SLR model
across one or more HPA budgets, and serve batched requests through the
SLR-native engine — the elastic-deployment spectrum through the fast path.

  python -m repro.launch.serve --arch salaad_llama_60m --reduced \
      --keep-ratios 1.0,0.6,0.3 --fmt factored --kappa 0.7 --requests 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, init_slr_state
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.models import model as model_lib
from repro.serving.deployed import DeployedModel
from repro.serving.engine import (
    BATCHED_FAMILIES,
    EngineConfig,
    ReferenceEngine,
    ServingEngine,
)
from repro.serving.slr_params import deployment_report


def serve_batch(engine, vocab: int, requests: int, max_new: int, seed: int) -> dict:
    rng = np.random.RandomState(seed)
    for _ in range(requests):
        prompt = rng.randint(0, vocab, size=rng.randint(2, 8)).tolist()
        engine.submit(prompt, max_new_tokens=max_new)
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    return {
        "requests": len(done),
        "tokens": total_tokens,
        "tok_per_s": round(total_tokens / max(dt, 1e-9), 2),
        "sample": done[0].out_tokens if done else [],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument(
        "--keep-ratios", default=None,
        help="comma-separated HPA budgets, e.g. 1.0,0.6,0.3 (omit: serve dense init)",
    )
    ap.add_argument("--fmt", default="factored", choices=("dense", "factored", "bsr"))
    ap.add_argument("--engine", default="batched", choices=("batched", "reference"))
    ap.add_argument("--kappa", type=float, default=0.7)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)

    scfg = SalaadConfig(selection=SelectionConfig(min_dim=16))
    if args.ckpt_dir:
        from repro.train import checkpoint
        from repro.train.state import init_train_state

        state, blocks = init_train_state(params, scfg)
        state = checkpoint.restore(args.ckpt_dir, state)
        slr, params = state.slr, state.params
    else:
        slr, blocks = init_slr_state(params, scfg)

    engine_cls = ServingEngine if args.engine == "batched" else ReferenceEngine
    if engine_cls is ServingEngine and cfg.family not in BATCHED_FAMILIES:
        print(json.dumps({"note": f"family {cfg.family!r} has no per-slot-length "
                          "cache yet; falling back to the reference engine"}))
        engine_cls = ReferenceEngine
    ecfg = EngineConfig(max_slots=args.max_slots, max_len=args.max_len)

    if args.keep_ratios is None:
        engine = engine_cls(cfg, params, ecfg)
        print(json.dumps({"budget": None, "fmt": "dense-init",
                          **serve_batch(engine, cfg.vocab_size, args.requests,
                                        args.max_new, args.seed)}))
        return

    # one SALAAD state, a spectrum of served capacities — each budget deploys
    # and serves through the same batched SLR-native programs
    for keep in [float(k) for k in args.keep_ratios.split(",")]:
        slr_c, report = hpa_keep_ratio(slr, blocks, keep, args.kappa)
        deployed = DeployedModel.build(cfg, params, slr_c, blocks, fmt=args.fmt)
        engine = engine_cls(cfg, deployed, ecfg)
        stats = serve_batch(engine, cfg.vocab_size, args.requests, args.max_new, args.seed)
        dep = deployment_report(params, slr_c, blocks)
        print(json.dumps({
            "budget": keep,
            "fmt": args.fmt,
            "slr_params": report["params_after"],
            "served_bytes": deployed.param_bytes()["total_bytes"],
            "slr_total_bytes": dep["slr_total_bytes"],
            "compression": round(dep["compression"], 3),
            **stats,
        }))


if __name__ == "__main__":
    main()
