"""Production training launcher.

Single-host (CPU/tests): ``python -m repro.launch.train --arch salaad_llama_60m
--steps 100 --reduced``. On a real TPU pod, jax.distributed.initialize() picks
up the cluster env and the same script runs SPMD; the XLA flags below enable
the latency-hiding scheduler so GSPMD's weight all-gathers / grad
reduce-scatters overlap with compute (the comm/compute-overlap knob of
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import os

_PERF_FLAGS = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
)
if os.environ.get("REPRO_TPU_PERF_FLAGS", "0") == "1":
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + _PERF_FLAGS

import argparse  # noqa: E402
import json      # noqa: E402

import jax       # noqa: E402

from repro.configs.base import get_arch                       # noqa: E402
from repro.core.admm import SalaadConfig                      # noqa: E402
from repro.core.selection import SelectionConfig              # noqa: E402
from repro.data.synthetic import DataConfig, SyntheticC4      # noqa: E402
from repro.optim.adam import AdamConfig                       # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--no-salaad", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--update-every", type=int, default=20, help="K (Alg. 1)")
    ap.add_argument("--rho-constant", type=float, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    salaad = None
    if not args.no_salaad:
        kw = dict(selection=SelectionConfig(min_dim=16), update_every=args.update_every)
        if args.rho_constant is not None:
            kw["rho_constant"] = args.rho_constant
        salaad = SalaadConfig(**kw)

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        salaad=salaad,
        adam=AdamConfig(lr=args.lr),
    )
    trainer = Trainer(cfg, tcfg)
    state = trainer.init(jax.random.PRNGKey(args.seed))
    state = trainer.maybe_restore(state)

    data = SyntheticC4(
        DataConfig(cfg.vocab_size, args.seq_len, args.batch, seed=args.seed)
    )
    state = trainer.fit(state, data)
    print(json.dumps({"metrics": trainer.metrics_log[-5:], "events": trainer.events}, indent=1))


if __name__ == "__main__":
    main()
