"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract roofline inputs from the compiled artifact.

MUST be the first import in the process: the placeholder-device flag below
has to be set before jax initializes its backends. Do NOT move it, and do NOT
set it anywhere global (tests/benches must see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out report.json]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, get_arch, runnable_cells  # noqa: E402
from repro.core.admm import SalaadConfig                         # noqa: E402
from repro.core.selection import SelectionConfig, select_blocks  # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.models import model                                   # noqa: E402
from repro.optim.adam import AdamConfig                          # noqa: E402
from repro.parallel.sharding import (                            # noqa: E402
    batch_shardings,
    dp_axes,
    dp_size as _dp_size,
    param_sharding_tree,
)
from repro.train.state import abstract_train_state               # noqa: E402
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step  # noqa: E402

# ------------------------------------------------------------ hardware ----
# TPU v5e per chip (roofline constants; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link


# dp_axes / _dp_size / batch_shardings moved to repro.parallel.sharding —
# one axis-naming authority shared with ServingMesh and the serving engines.


def cache_shardings(cache_abstract, mesh):
    """Heuristic cache sharding: heads/model, batch/data (or seq/data at B=1)."""
    dp = dp_axes(mesh)
    dpn = _dp_size(mesh)
    model_n = mesh.shape["model"]

    def one(leaf):
        s = leaf.shape
        if len(s) == 5:  # (stack, B, H, S, D)
            spec = [None] * 5
            if s[2] % model_n == 0:
                spec[2] = "model"
            elif s[4] % model_n == 0:
                # GQA head counts (8/10/12/20) rarely divide the model axis;
                # shard head_dim instead — attention contracts over D, GSPMD
                # emits a psum. Unsharded caches cost up to 214 GB/device
                # (qwen1.5 decode_32k, measured baseline).
                spec[4] = "model"
            if s[1] % dpn == 0:
                spec[1] = dp
            elif s[3] % dpn == 0:
                spec[3] = dp
            return NamedSharding(mesh, P(*spec))
        if len(s) == 4:  # (stack, B, K, C) conv window
            spec = [None] * 4
            if s[1] % dpn == 0:
                spec[1] = dp
            if s[3] % model_n == 0:
                spec[3] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, cache_abstract)


def slr_shardings(slr_abstract, params_shardings, mesh):
    """Surrogate tensors follow their weight's sharding (DESIGN.md §3)."""
    from repro.core.admm import BlockSLR

    flat_params = {}

    def record(path, leaf):
        from repro.core.selection import path_str

        flat_params[path_str(path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(record, params_shardings)

    out = {}
    for name, blk in slr_abstract.items():
        wspec = flat_params.get(name)
        wp = wspec.spec if wspec is not None else P()
        # weight spec covers (stack..., n, m)
        n_ax = wp[-2] if len(wp) >= 2 else None
        m_ax = wp[-1] if len(wp) >= 1 else None
        stack = tuple(wp[:-2]) if len(wp) > 2 else (None,) * (blk.y.ndim - 2)
        ns = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731
        # COO capacity dim: shard over every mesh axis not already used by the
        # stacked dims (experts use 'model'); replicated COO buffers cost
        # ~1 GB/device at dbrx scale (measured).
        used = {a for s in stack if s for a in ((s,) if isinstance(s, str) else s)}
        free_axes = tuple(a for a in mesh.axis_names if a not in used)
        cap = blk.s_coo.values.shape[-1]
        free_n = int(np.prod([mesh.shape[a] for a in free_axes])) if free_axes else 1
        cap_ax = free_axes if free_axes and cap % free_n == 0 else None
        out[name] = type(blk)(
            p=ns(*stack, n_ax, None),
            vt=ns(*stack, None, m_ax),
            s_vals=ns(*stack, None),
            s_coo=type(blk.s_coo)(
                values=ns(*stack, cap_ax), idx=ns(*stack, cap_ax), shape=blk.s_coo.shape
            ),
            y=ns(*stack, n_ax, m_ax),
            z=ns(*stack, n_ax, m_ax),
            alpha=ns(*stack),
            beta=ns(*stack),
            rho=blk.rho,
        )
    return out


COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = \(?([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand sizes of every collective op in the compiled HLO."""
    totals: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        if f"{kind}(" not in line and f"{kind}-start(" not in line and f"{kind}-done(" not in line:
            continue
        if f"{kind}-done(" in line:
            continue  # avoid double counting start/done pairs
        # parse all shapes on the lhs (may be a tuple)
        lhs = line.split("=", 1)[0] + "= " + line.split("=", 1)[1]
        shapes = re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", line.split("(", 1)[0].split("=", 1)[1])
        nbytes = 0
        for dt, dims in shapes:
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        totals[kind] = totals.get(kind, 0) + nbytes
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


_MAJOR_OPS = {
    "dot", "convolution", "scatter", "gather", "reduce", "reduce-window",
    "sort", "concatenate", "dynamic-update-slice", "dynamic-slice",
    "transpose", "copy", "fusion", "select-and-scatter", "rng-bit-generator",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "cumsum", "exponential",
}
_OPCODE_RE = re.compile(r" ([a-z][a-z0-9\-]*)\(")
_SHAPES_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def fusion_aware_hbm_bytes(hlo_text: str) -> float:
    """Fusion-aware HBM-traffic estimate from the per-device HLO.

    XLA's raw "bytes accessed" treats every elementwise intermediate as HBM
    traffic; on TPU those chains fuse. We count 2x (write + read-back) the
    output bytes of ops that genuinely materialize data (matmuls, reductions,
    scatters/gathers, transposes, collectives) and skip fusable elementwise
    ops. Methodology recorded in EXPERIMENTS.md §Roofline; it is an estimate
    between the resident-bytes lower bound and the unfused upper bound.
    """
    total = 0.0
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        m = _OPCODE_RE.search(rhs)
        if not m or m.group(1) not in _MAJOR_OPS:
            continue
        lhs_shapes = rhs[: m.start()]
        for dt, dims in _SHAPES_RE.findall(lhs_shapes):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += 2 * n * DTYPE_BYTES[dt]
    return total


def attention_correction_flops(cfg, shape) -> float:
    """Analytic attention-score FLOPs missing from the compiled count.

    The flash-attention custom-VJP iterates (q-chunk x kv-chunk) lax.scans;
    XLA cost analysis counts a while body ONCE, so the score/PV matmul FLOPs
    are under-reported by ~(nq*nk). The score FLOPs have a closed form —
    fwd = 4*B*H*T*S*D per layer (QK^T + PV) — which we add back with
    multipliers fwd=1 (prefill) or fwd+remat+bwd = 4+4+10 /4 = 4.5x (train).
    Decode paths don't scan (dense cached attention) and need no correction.
    Recorded separately in the §Roofline table as attn_corr_flops.
    """
    if shape.kind == "decode" or cfg.family == "ssm":
        return 0.0
    b, t = shape.global_batch, shape.seq_len
    # balanced causal scheme computes only the lower triangle in the forward:
    # fwd (and remat-fwd) score FLOPs halve; the backward is full-scheme.
    fwd = 0.5 if cfg.causal_scheme == "balanced" else 1.0
    mult = (4 * fwd + 4 * fwd + 10) / 4 if shape.kind == "train" else fwd
    d = cfg.head_dim
    h = cfg.num_heads
    total = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        tt = t + (cfg.num_patches if cfg.family == "vlm" else 0)
        total = cfg.num_layers * 4.0 * b * h * tt * tt * d
    elif cfg.family == "hybrid":
        g = cfg.num_layers // cfg.attn_every
        total = g * 4.0 * b * h * t * t * d
    elif cfg.family == "encdec":
        f = cfg.encoder_seq
        total = (
            cfg.encoder_layers * 4.0 * b * h * f * f * d      # encoder self
            + cfg.num_layers * 4.0 * b * h * t * t * d        # decoder self
            + cfg.num_layers * 4.0 * b * h * t * f * d        # cross
        )
    return total * mult


def model_flops(cfg, shape) -> float:
    """6 * N_active * D (dense) — the 'useful compute' yardstick."""
    params = model.abstract_params(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    if cfg.num_experts:
        # active = non-expert params + top_k/E of expert params
        expert = sum(
            int(np.prod(l.shape))
            for path, l in jax.tree_util.tree_leaves_with_path(params)
            if "experts" in str(path)
        )
        total = (total - expert) + expert * cfg.top_k / cfg.num_experts
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * total * tokens


def _compile_cell(
    arch_id: str,
    shape_id: str,
    mesh,
    salaad: bool = True,
    cfg_overrides: dict | None = None,
    unroll: bool = True,
    accum_steps: int = 1,
):
    """Lower + compile one cell at one unroll setting. Returns compiled obj.

    Two compiles per cell (see run_cell): layer scans make XLA's cost
    analysis count the while-body ONCE (FLOPs off by num_layers), while full
    unrolling makes XLA:CPU's buffer assignment wildly overstate peak memory
    (120 GB vs 14.5 GB measured on olmo_1b train_4k). So: unrolled HLO is the
    FLOP/byte/collective ground truth, scanned HLO is the memory ground truth
    (and the program production actually runs).
    """
    import dataclasses

    cfg = get_arch(arch_id)
    cfg = dataclasses.replace(cfg, scan_unroll=unroll or 1, **(cfg_overrides or {}))
    shape = SHAPES[shape_id]

    params_abs = model.abstract_params(cfg)
    pshard = param_sharding_tree(params_abs, mesh)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_abs))

    scfg = SalaadConfig(
        selection=SelectionConfig(),
        surrogate_dtype=jnp.bfloat16,
    ) if salaad else None

    with mesh:
        if shape.kind == "train":
            state_abs = abstract_train_state(params_abs, scfg)
            blocks = select_blocks(params_abs, scfg.selection) if scfg else []
            state_shard = state_abs._replace(
                params=pshard,
                opt=state_abs.opt._replace(
                    mu=pshard, nu=pshard, count=NamedSharding(mesh, P())
                ),
                slr=slr_shardings(state_abs.slr, pshard, mesh),
                step=NamedSharding(mesh, P()),
            )
            specs = model.input_specs(cfg, shape)
            if accum_steps > 1:
                # pre-split microbatches on the host (see steps.py pre_split)
                specs = {
                    k: jax.ShapeDtypeStruct(
                        (accum_steps, v.shape[0] // accum_steps, *v.shape[1:]),
                        v.dtype,
                    )
                    for k, v in specs.items()
                }
                raw = batch_shardings(
                    {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype) for k, v in specs.items()},
                    mesh,
                )
                bshard = {
                    k: NamedSharding(mesh, P(None, *raw[k].spec))
                    for k in specs
                }
            else:
                bshard = batch_shardings(specs, mesh)
            step = make_train_step(
                cfg, blocks, AdamConfig(), accum_steps=accum_steps,
                pre_split=accum_steps > 1,
            )
            jitted = jax.jit(
                step,
                in_shardings=(state_shard, bshard),
                out_shardings=(state_shard, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_abs, specs)
        elif shape.kind == "prefill":
            specs = model.input_specs(cfg, shape)
            bshard = batch_shardings(specs, mesh)
            step = make_prefill_step(cfg, max_len=shape.seq_len)
            cache_abs = jax.eval_shape(step, params_abs, specs)[1]
            cshard = cache_shardings(cache_abs, mesh)
            jitted = jax.jit(
                step, in_shardings=(pshard, bshard), out_shardings=(None, cshard)
            )
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            b = shape.global_batch
            tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(cfg, b, shape.seq_len)
            )
            cshard = cache_shardings(cache_abs, mesh)
            tshard = batch_shardings({"tokens": tok}, mesh)["tokens"]
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, tshard, cshard),
                out_shardings=(None, cshard),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, tok, cache_abs)

        compiled = lowered.compile()
    return compiled, cfg, shape


def run_cell(
    arch_id: str,
    shape_id: str,
    mesh,
    salaad: bool = True,
    verbose: bool = True,
    cfg_overrides: dict | None = None,
    accum_steps: int = 1,
):
    """Compile a cell twice (unrolled: costs; scanned: memory) and derive the
    roofline record."""
    t0 = time.time()
    multi_pod = "pod" in mesh.axis_names
    compiled_scan, cfg, shape = _compile_cell(
        arch_id, shape_id, mesh, salaad, cfg_overrides, unroll=False,
        accum_steps=accum_steps,
    )
    mem = compiled_scan.memory_analysis()

    n_dev = int(np.prod(list(mesh.shape.values())))
    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(model.abstract_params(cfg))
    )

    def cost_from(c):
        cost = c.cost_analysis()
        hlo = c.as_text()
        coll = collective_bytes(hlo)
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_acc": float(cost.get("bytes accessed", 0.0)),
            "hbm": fusion_aware_hbm_bytes(hlo),
            **{f"coll/{k}": float(v) for k, v in coll.items()},
        }

    if multi_pod:
        # multi-pod pass criterion: compile success + fits; the cost table is
        # single-pod only (assignment §Roofline) — reuse the scanned compile.
        costs = cost_from(compiled_scan)
        cost_method = "scanned(multi-pod; costs not comparable)"
    elif cfg.num_layers <= 16:
        compiled, _, _ = _compile_cell(
            arch_id, shape_id, mesh, salaad, cfg_overrides, unroll=True,
            accum_steps=accum_steps,
        )
        costs = cost_from(compiled)
        cost_method = "full-unroll"
    else:
        # Deep stacks: full unroll takes >30 min/cell on this 1-CPU host.
        # Compile TWO shallow fully-unrolled variants and fit cost linearly in
        # depth — exact for homogeneous layer stacks (every assigned arch),
        # and still 100% derived from compiled artifacts.
        step = cfg.attn_every if cfg.attn_every else 4
        l1, l2 = step, 2 * step
        over = dict(cfg_overrides or {})
        c1, _, _ = _compile_cell(
            arch_id, shape_id, mesh, salaad, {**over, "num_layers": l1},
            unroll=True, accum_steps=accum_steps,
        )
        c2, _, _ = _compile_cell(
            arch_id, shape_id, mesh, salaad, {**over, "num_layers": l2},
            unroll=True, accum_steps=accum_steps,
        )
        k1, k2 = cost_from(c1), cost_from(c2)
        costs = {}
        for key in k1:
            slope = (k2[key] - k1[key]) / (l2 - l1)
            costs[key] = k1[key] + slope * (cfg.num_layers - l1)
        cost_method = f"two-point-depth-fit({l1},{l2})"

    if accum_steps > 1 and not multi_pod:
        # the microbatch lax.scan body is counted once by cost analysis;
        # nearly the whole step lives inside it, so scale by accum (the Adam
        # tail outside the loop is <1% of step cost — conservative upper).
        costs = {k: v * accum_steps for k, v in costs.items()}
        cost_method += f";x{accum_steps}-accum-loop"

    coll = {
        k.split("/", 1)[1]: v for k, v in costs.items() if k.startswith("coll/")
    }

    # cost_analysis and the HLO text describe the PER-DEVICE partitioned
    # program (verified against a hand-checked SPMD matmul) — no /n_dev here.
    flops = costs["flops"]
    bytes_acc = costs["bytes_acc"]
    hbm_bytes = costs["hbm"] + float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    mf = model_flops(cfg, shape)
    attn_corr = attention_correction_flops(cfg, shape) / n_dev
    flops_corrected = flops + attn_corr
    compute_t = flops_corrected / PEAK_FLOPS
    memory_t = hbm_bytes / HBM_BW
    memory_t_upper = bytes_acc / HBM_BW
    coll_t = coll["total"] / ICI_BW
    dominant = max(
        [("compute", compute_t), ("memory", memory_t), ("collective", coll_t)],
        key=lambda kv: kv[1],
    )[0]
    rec = {
        "arch": arch_id,
        "shape": shape_id,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev,
        "params": n_params,
        "hlo_flops": flops,
        "attn_corr_flops": attn_corr,
        "hlo_flops_corrected": flops_corrected,
        "hlo_bytes_unfused": bytes_acc,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll,
        "compute_s": compute_t,
        "memory_s": memory_t,
        "memory_s_unfused_upper": memory_t_upper,
        "collective_s": coll_t,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": (mf / n_dev) / flops_corrected if flops_corrected else 0.0,
        "peak_memory_per_device": getattr(mem, "temp_size_in_bytes", None),
        "argument_size": getattr(mem, "argument_size_in_bytes", None),
        "output_size": getattr(mem, "output_size_in_bytes", None),
        "compile_s": round(time.time() - t0, 1),
        "cost_method": cost_method,
        "accum_steps": accum_steps,
    }
    if verbose:
        print(json.dumps(rec, indent=None, default=str))
        sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-salaad", action="store_true")
    ap.add_argument("--accum", type=int, default=1, help="microbatch accumulation")
    ap.add_argument("--scheme", default=None, help="causal_scheme override (balanced)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = runnable_cells() if args.all else [(args.arch, args.shape)]
    records, failures = [], []
    for mesh in meshes:
        for arch_id, shape_id in cells:
            try:
                records.append(
                    run_cell(
                        arch_id, shape_id, mesh,
                        salaad=not args.no_salaad, accum_steps=args.accum,
                        cfg_overrides=(
                            {"causal_scheme": args.scheme} if args.scheme else None
                        ),
                    )
                )
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch_id, shape_id, str(mesh.shape), str(e)[:200]))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1, default=str)
    print(f"\n=== {len(records)} cells compiled, {len(failures)} failures ===")
    for f in failures:
        print("FAIL:", f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
