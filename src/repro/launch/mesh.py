"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init,
and tests/benches must keep seeing 1 device.

Topology: TPU v5e pods of 256 chips. Single pod: (data=16, model=16).
Multi-pod: a leading "pod" axis; batch shards over ("pod", "data") so the
only cross-pod (DCN) collective is the gradient all-reduce.

Axis names are owned by :class:`repro.parallel.sharding.ServingMesh` — every
mesh built here round-trips through it, so launch, dry-run, and the serving
engines agree on one naming authority.
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import ServingMesh

__all__ = ["make_production_mesh", "make_host_mesh", "make_serving_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ServingMesh.AXES
    # ServingMesh validates the axis names (it allows the leading "pod")
    return ServingMesh(jax.make_mesh(shape, axes)).mesh


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return ServingMesh.create(data=data, model=model).mesh


def make_serving_mesh(spec: str) -> ServingMesh:
    """``"model=N,data=M"`` → a ServingMesh over the first N*M local devices."""
    return ServingMesh.from_spec(spec)
