"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init,
and tests/benches must keep seeing 1 device.

Topology: TPU v5e pods of 256 chips. Single pod: (data=16, model=16).
Multi-pod: a leading "pod" axis; batch shards over ("pod", "data") so the
only cross-pod (DCN) collective is the gradient all-reduce.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
