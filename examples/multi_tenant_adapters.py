"""Multi-tenant adapter serving: one shared base, many SLR tenants, one
engine.

SALAAD's factored form is a LoRA-style ``(P, Vt, S)`` delta over a dense
base, so a pool of serving hardware can host MANY fine-tuned tenants at the
cost of ONE base plus their small adapter tables. This demo trains once,
registers 6 tenant adapters (HPA views at spread budgets) over one shared
fused-format base, and serves a mixed-tenant batch through a single paged
engine — every decode tick runs ONE batched kernel call even though the
slots carry different adapters, a 3-row device pool LRU-swaps the tenants
that don't fit, and nothing retraces across the switches.

    PYTHONPATH=src python examples/multi_tenant_adapters.py
"""
import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.optim.adam import AdamConfig
from repro.serving.adapters import AdapterBank, adapterize
from repro.serving.deployed import DeployedModel
from repro.serving.engine import EngineConfig, PagedServingEngine
from repro.train.trainer import Trainer, TrainerConfig

N_TENANTS = 6
POOL_ROWS = 3          # device pool smaller than the tenant count: LRU swaps


def main():
    cfg = get_arch("salaad_llama_60m").reduced()
    salaad = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=0.5,
        update_every=5, exact_svd=True,
    )
    trainer = Trainer(cfg, TrainerConfig(total_steps=40, salaad=salaad,
                                         adam=AdamConfig(lr=1e-3)))
    state = trainer.init(jax.random.PRNGKey(0))
    state = trainer.fit(state, SyntheticC4(DataConfig(cfg.vocab_size, 32, 8)))

    # one shared base + N tenants: HPA views at spread keep budgets, each
    # adapterized onto the base so only the SLR site tables differ
    slr_c, _ = hpa_keep_ratio(state.slr, trainer.blocks, 1.0, kappa=0.7)
    base = DeployedModel.build(cfg, state.params, slr_c, trainer.blocks,
                               fmt="fused", bsr_block=32)
    tenants = []
    for keep in np.linspace(1.0, 0.4, N_TENANTS):
        slr_k, _ = hpa_keep_ratio(state.slr, trainer.blocks, float(keep), 0.7)
        tenants.append(adapterize(base, DeployedModel.build(
            cfg, state.params, slr_k, trainer.blocks, fmt="fused",
            bsr_block=32)))

    bank = AdapterBank(base, tenants,
                       names=[f"tenant{i}" for i in range(N_TENANTS)])
    engine = PagedServingEngine(bank, EngineConfig(
        adapters=True, max_resident_adapters=POOL_ROWS,
        max_slots=4, max_len=48, block_size=8,
    ))
    rep = bank.adapter_report()
    print(f"{rep['registered']} tenants registered over one {rep['fmt']} "
          f"base; device pool = {rep['capacity']} rows (mode={rep['mode']})")

    # two mixed-tenant waves: the first covers tenants 0-3 (one swap-in
    # already needed), the second rotates to 2-5 — pure LRU swaps + sel
    # rebinds, zero recompilation
    for wave, aids in enumerate(([0, 1, 2, 3], [2, 3, 4, 5])):
        for aid in aids:
            engine.submit([1 + aid, 5, 9], max_new_tokens=6, adapter=aid)
        done = engine.run()
        for r in sorted(done, key=lambda r: r.adapter):
            print(f"wave {wave} tenant {r.adapter} "
                  f"(row-resident) decoded: {r.out_tokens}")
    rep = bank.adapter_report()
    print(f"resident now: {rep['resident']} of {rep['registered']}; "
          f"LRU swap-ins: {rep['swaps']}")
    print(f"jit retraces across every adapter switch: "
          f"{engine.metrics.retraces()} (data-only rebinds)")


if __name__ == "__main__":
    main()
