"""Serve an HPA-compressed SLR model with the batched engine, and exercise
the TPU-targeted SLR kernels (fused low-rank matmul + block-CSR sparse
matmul, interpret mode on CPU) on a deployed block.

    PYTHONPATH=src python examples/serve_slr.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.optim.adam import AdamConfig
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.slr_params import build_slr_linears
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = get_arch("salaad_llama_60m").reduced()
    salaad = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=0.5,
        update_every=5, exact_svd=True,
    )
    trainer = Trainer(cfg, TrainerConfig(total_steps=120, salaad=salaad, adam=AdamConfig(lr=1e-3)))
    state = trainer.init(jax.random.PRNGKey(0))
    data = SyntheticC4(DataConfig(cfg.vocab_size, 32, 8))
    state = trainer.fit(state, data)

    # compress + deploy WITHOUT dense materialization: the engine consumes
    # the factored (p, vt) + COO S representation directly
    from repro.serving.deployed import DeployedModel

    slr_c, rep = hpa_keep_ratio(state.slr, trainer.blocks, keep_ratio=0.7, kappa=0.7)
    deployed = DeployedModel.build(cfg, state.params, slr_c, trainer.blocks, fmt="factored")
    print(
        f"deployed at keep=0.7: slr_params={rep['params_after']} "
        f"served_bytes={deployed.param_bytes()['total_bytes']}"
    )

    # batched serving straight off the SLR weights (single-tier bank)
    from repro.serving.elastic import ModelBank

    engine = ServingEngine(ModelBank.single(cfg, deployed),
                           EngineConfig(max_slots=2, max_len=48))
    for i in range(4):
        engine.submit([1 + i, 2, 3], max_new_tokens=6)
    t0 = time.time()
    done = engine.run()
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, {toks/(time.time()-t0):.1f} tok/s")
    print("sample:", done[0].out_tokens)

    # elastic self-speculation: the SAME SLR state at an aggressive budget
    # drafts for the full-budget target (one jitted k-wide verify per tick)
    from repro.serving.speculative import SpeculativeEngine

    slr_d, _ = hpa_keep_ratio(state.slr, trainer.blocks, keep_ratio=0.4, kappa=0.7)
    draft = DeployedModel.build(cfg, state.params, slr_d, trainer.blocks, fmt="dense")
    target = DeployedModel.build(cfg, state.params, slr_c, trainer.blocks, fmt="dense")
    # the draft/target pair is two tiers of one bank: tier 0 verifies,
    # the cheapest tier (spec_draft_tier=-1, the default) drafts
    spec = SpeculativeEngine(
        ModelBank(cfg, [target, draft], keeps=[0.7, 0.4]),
        EngineConfig(
            max_slots=2, max_len=48, block_size=8, spec_k=4,
            spec_draft_mode="sequential",   # short demo: no lookahead warmup
        ))
    for i in range(4):
        spec.submit([1 + i, 2, 3], max_new_tokens=6)
    done = spec.run()
    print(
        f"speculative: {sum(len(r.out_tokens) for r in done)} tokens in "
        f"{spec.decode_calls} verify steps, acceptance {spec.acceptance_rate:.2f}"
    )

    # TPU-kernel path on one deployed block (interpret mode on CPU)
    linears = build_slr_linears(slr_c, trainer.blocks, fmt="bsr", bsr_block=32)
    name, lin = next((k, v) for k, v in linears.items() if v.p is not None and v.p.ndim == 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, lin.shape[0]))
    y_kernel = lin.apply(x, kernel=True)
    y_ref = lin.apply(x, kernel=False)
    err = float(jnp.abs(y_kernel - y_ref).max())
    occ = lin.s_bsr.occupancy if lin.s_bsr is not None else float("nan")
    print(f"kernel path on '{name}': max|Δ| vs XLA path = {err:.2e}, BSR occupancy {occ:.2f}")


if __name__ == "__main__":
    main()
