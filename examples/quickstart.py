"""Quickstart: SALAAD end to end in ~2 minutes on CPU.

Trains a tiny LLaMA-family model with Algorithm 1, shows the structured
surrogate, compresses it to 60% params with HPA, and evaluates all three
model variants (X, L+S, compressed) — the paper's Table 1 row in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, slr_param_count, surrogate_params
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.models import model as model_lib
from repro.optim.adam import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = get_arch("salaad_llama_60m").reduced()
    salaad = SalaadConfig(
        selection=SelectionConfig(min_dim=16),
        rho_constant=0.5,
        update_every=5,
        exact_svd=True,  # tiny matrices: exact SVD is fine (rSVD at scale)
    )
    trainer = Trainer(cfg, TrainerConfig(total_steps=40, salaad=salaad, adam=AdamConfig(lr=1e-3)))
    state = trainer.init(jax.random.PRNGKey(0))
    data = SyntheticC4(DataConfig(cfg.vocab_size, 32, 8))

    print("== stage 1+2 training (Algorithm 1) ==")
    state = trainer.fit(state, data)
    for m in trainer.metrics_log:
        if "loss" in m:
            print(f"  step {m['step']:>3}  loss {m['loss']:.3f}")

    def eval_loss(params):
        return float(model_lib.loss_fn(params, data.batch(9999), cfg)[0])

    print("\n== deployment variants ==")
    print(f"  X     (dense)      loss {eval_loss(state.params):.3f}")
    surr = trainer.surrogate(state)
    n_slr = slr_param_count(state.slr, trainer.blocks)["_total"]
    print(f"  L+S   (surrogate)  loss {eval_loss(surr):.3f}   slr_params {n_slr}")

    slr_c, report = hpa_keep_ratio(state.slr, trainer.blocks, keep_ratio=0.6, kappa=0.7)
    comp = surrogate_params(state.params, slr_c, trainer.blocks)
    print(
        f"  HPA60 (compressed) loss {eval_loss(comp):.3f}   "
        f"slr_params {report['params_after']}  (phi_L={report['phi_L']:.2f}, "
        f"phi_S={report['phi_S']:.2f})"
    )


if __name__ == "__main__":
    main()
