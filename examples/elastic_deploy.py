"""Elastic deployment (Fig. 3 in miniature): one SALAAD checkpoint, a sweep
of parameter budgets, no retraining — the paper's headline capability.

    PYTHONPATH=src python examples/elastic_deploy.py
"""
import jax

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, surrogate_params
from repro.core.hpa import hpa_keep_ratio, removable_params
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.models import model as model_lib
from repro.optim.adam import AdamConfig
from repro.serving.slr_params import deployment_report
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = get_arch("salaad_llama_60m").reduced()
    salaad = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=0.5,
        update_every=5, exact_svd=True,
    )
    trainer = Trainer(cfg, TrainerConfig(total_steps=50, salaad=salaad, adam=AdamConfig(lr=1e-3)))
    state = trainer.init(jax.random.PRNGKey(0))
    data = SyntheticC4(DataConfig(cfg.vocab_size, 32, 8))
    state = trainer.fit(state, data)

    def eval_loss(params):
        return float(model_lib.loss_fn(params, data.batch(9999), cfg)[0])

    c_l, c_s = removable_params(state.slr, trainer.blocks)
    print(f"trained once; removable SLR params: L={c_l} S={c_s}")
    print(f"{'keep':>6} {'slr_params':>10} {'loss':>8}   (single checkpoint, no retraining)")
    for keep in (1.0, 0.85, 0.7, 0.55, 0.4, 0.25):
        slr_c, rep = hpa_keep_ratio(state.slr, trainer.blocks, keep, kappa=0.7)
        params_c = surrogate_params(state.params, slr_c, trainer.blocks)
        print(f"{keep:>6.2f} {rep['params_after']:>10} {eval_loss(params_c):>8.3f}")

    rep = deployment_report(state.params, state.slr, trainer.blocks)
    print(
        f"\ndeployment bytes: dense={rep['dense_total_bytes']/1e6:.2f}MB "
        f"slr={rep['slr_total_bytes']/1e6:.2f}MB "
        f"(compression x{rep['compression']:.2f})"
    )


if __name__ == "__main__":
    main()
