"""Elastic deployment (Fig. 3 in miniature): one SALAAD checkpoint, a sweep
of parameter budgets, no retraining — and since the elastic API landed, the
sweep is SERVED, not just evaluated: one ModelBank materializes the budget
spectrum as tiers and a single paged engine decodes all of them concurrently
(per-tier jitted steps over one shared paged KV).

    PYTHONPATH=src python examples/elastic_deploy.py
"""
import jax

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, surrogate_params
from repro.core.hpa import hpa_keep_ratio, removable_params
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.models import model as model_lib
from repro.optim.adam import AdamConfig
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, PagedServingEngine
from repro.serving.slr_params import deployment_report
from repro.train.trainer import Trainer, TrainerConfig

BUDGETS = (1.0, 0.7, 0.4)


def main():
    cfg = get_arch("salaad_llama_60m").reduced()
    salaad = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=0.5,
        update_every=5, exact_svd=True,
    )
    trainer = Trainer(cfg, TrainerConfig(total_steps=50, salaad=salaad, adam=AdamConfig(lr=1e-3)))
    state = trainer.init(jax.random.PRNGKey(0))
    data = SyntheticC4(DataConfig(cfg.vocab_size, 32, 8))
    state = trainer.fit(state, data)

    def eval_loss(params):
        return float(model_lib.loss_fn(params, data.batch(9999), cfg)[0])

    c_l, c_s = removable_params(state.slr, trainer.blocks)
    print(f"trained once; removable SLR params: L={c_l} S={c_s}")
    print(f"{'keep':>6} {'slr_params':>10} {'loss':>8}   (single checkpoint, no retraining)")
    for keep in (1.0, 0.85, 0.7, 0.55, 0.4, 0.25):
        slr_c, rep = hpa_keep_ratio(state.slr, trainer.blocks, keep, kappa=0.7)
        params_c = surrogate_params(state.params, slr_c, trainer.blocks)
        print(f"{keep:>6.2f} {rep['params_after']:>10} {eval_loss(params_c):>8.3f}")

    rep = deployment_report(state.params, state.slr, trainer.blocks)
    print(
        f"\ndeployment bytes: dense={rep['dense_total_bytes']/1e6:.2f}MB "
        f"slr={rep['slr_total_bytes']/1e6:.2f}MB "
        f"(compression x{rep['compression']:.2f})"
    )

    # --- serve the spectrum: one bank, one engine, three tiers ------------
    bank = ModelBank.build(cfg, state.params, state.slr, trainer.blocks,
                           budgets=BUDGETS, kappa=0.7, fmt="factored")
    for t in bank:
        print(f"tier {t.index} ({t.name}): served_bytes={t.param_bytes}")
    print(f"shared base across tiers: {bank.shared_base_bytes()} bytes")

    engine = PagedServingEngine(bank, EngineConfig(
        max_slots=len(bank), max_len=48, block_size=8,
    ))
    for i in range(len(bank)):
        engine.submit([1 + i, 2, 3], max_new_tokens=6, tier=i)
    done = engine.run()
    for r in sorted(done, key=lambda r: r.tier):
        print(f"tier {r.tier} decoded concurrently: {r.out_tokens}")
    print(
        f"one engine, {len(bank)} budgets in flight: "
        f"{engine.decode_traces} compiled decode programs, "
        f"{engine.decode_calls} device calls"
    )


if __name__ == "__main__":
    main()
