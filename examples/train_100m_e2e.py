"""End-to-end driver: train a ~100M-class SALAAD model for a few hundred
steps with checkpointing and restart (the deliverable-(b) e2e example).

Full-size paper 130M config with real block shapes; on this CPU container
use --tiny to shrink steps/width while keeping the exact pipeline.

    PYTHONPATH=src python examples/train_100m_e2e.py --tiny
    PYTHONPATH=src python examples/train_100m_e2e.py --steps 300   # real run
"""
import argparse
import os
import tempfile

import jax

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.optim.adam import AdamConfig
from repro.train import checkpoint
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_arch("salaad_llama_130m")
    steps, seq, batch = args.steps, 256, 16
    if args.tiny:
        cfg, steps, seq, batch = cfg.reduced(), 30, 32, 8

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="salaad_ckpt_")
    salaad = SalaadConfig(
        selection=SelectionConfig(min_dim=16),
        update_every=20,
        exact_svd=args.tiny,
    )
    tcfg = TrainerConfig(
        total_steps=steps,
        ckpt_dir=ckpt_dir,
        ckpt_every=max(steps // 3, 10),
        salaad=salaad,
        adam=AdamConfig(lr=3e-4 if not args.tiny else 1e-3),
        log_every=max(steps // 10, 1),
    )
    trainer = Trainer(cfg, tcfg)
    state = trainer.init(jax.random.PRNGKey(0))
    state = trainer.maybe_restore(state)  # resume-after-crash path
    data = SyntheticC4(DataConfig(cfg.vocab_size, seq, batch))

    print(f"training {cfg.name}: {steps} steps, ckpt -> {ckpt_dir}")
    state = trainer.fit(state, data)
    for m in trainer.metrics_log:
        print(" ", m)
    print("events:", trainer.events)
    print("checkpoints:", checkpoint.all_steps(ckpt_dir))

    # simulate a preemption + restart: a fresh trainer resumes from disk
    trainer2 = Trainer(cfg, tcfg)
    state2 = trainer2.init(jax.random.PRNGKey(0))
    state2 = trainer2.maybe_restore(state2)
    print(f"restart resumes at step {int(state2.step)} (of {steps})")


if __name__ == "__main__":
    main()
