"""Fused SLR kernel (PR 7 tentpole) — one Pallas pass for low-rank + sparse.

Three layers of coverage, all in interpret mode so CPU CI exercises the
kernel bodies:

  1. kernel parity: fused vs the jnp oracle AND vs the separate
     lowrank+bsr calls it replaces, across dtypes, ranks (incl. r=0),
     occupancies (incl. empty S), decode/prefill row widths, ragged shapes,
     the stacked layer axis (incl. under ``lax.scan``), and the per-slot
     adapter axis (multi-tenant serving, PR 10);
  2. fast paths: the empty-S skip never launches a kernel, and decode-width
     row tiles don't pad small batches to 128;
  3. the ``fused`` deployment format: scan-stacked (never unrolled), forward
     parity with ``factored``, and greedy token streams bitwise-identical to
     ``factored`` across paged decode, chunked prefill, int8 KV pages,
     speculative decoding, and elastic tiers (the acceptance criteria).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, admm_update, init_slr_state
from repro.core.selection import SelectionConfig
from repro.kernels import ops, ref
from repro.kernels.bsr_matmul import bsr_from_dense
from repro.kernels.slr_matmul import row_tile, stack_bsr
from repro.models import model as model_lib
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, PagedServingEngine
from repro.serving.slr_params import SLRLinear
from repro.serving.speculative import SpeculativeEngine

I = dict(interpret=True)
TOL = {jnp.float32: dict(atol=2e-3, rtol=2e-3), jnp.bfloat16: dict(atol=1e-1, rtol=1e-1)}


def rnd(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


def make_sparse(key, n, m, occupancy, bs, dtype=jnp.float32):
    """Block-sparse dense matrix with ~``occupancy`` live tiles (padded dims
    allowed: the trailing partial blocks are part of the live tiles)."""
    ib, jb = -(-n // bs), -(-m // bs)
    mask = jax.random.uniform(jax.random.PRNGKey(key + 77), (ib, jb)) < occupancy
    full = rnd(key, (ib * bs, jb * bs), dtype) * jnp.repeat(
        jnp.repeat(mask, bs, 0), bs, 1
    ).astype(dtype)
    return np.asarray(full[:n, :m], np.float32)


def assert_close(got, want, dtype):
    got32, want32 = np.asarray(got, np.float32), np.asarray(want, np.float32)
    scale = max(float(np.abs(want32).max()), 1.0)
    np.testing.assert_allclose(got32 / scale, want32 / scale, **TOL[dtype])


# ------------------------------------------------------------ kernel parity ---


class TestFusedKernelParity:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("t", [4, 128])          # decode / prefill widths
    @pytest.mark.parametrize("r", [0, 8])
    @pytest.mark.parametrize("occupancy", [0.0, 0.4, 1.0])
    def test_matrix(self, dtype, t, r, occupancy):
        k, m, bs = 96, 160, 32
        x = rnd(0, (t, k), dtype)
        p, vt = rnd(1, (k, r), dtype), rnd(2, (r, m), dtype)
        s = make_sparse(3, k, m, occupancy, bs)
        bsr = bsr_from_dense(s.astype(np.asarray(x).dtype), bs)
        got = ops.slr_matmul(x, p, vt, bsr, **I)
        assert got.shape == (t, m) and got.dtype == x.dtype
        assert_close(got, ref.slr_matmul_ref(x, p, vt, bsr), dtype)

    @pytest.mark.parametrize("t", [4, 128])
    def test_matches_separate_calls(self, t):
        """The fused pass replaces lowrank_matmul + bsr_matmul + XLA add."""
        k, m, r, bs = 128, 128, 16, 32
        x, p, vt = rnd(0, (t, k)), rnd(1, (k, r)), rnd(2, (r, m))
        bsr = bsr_from_dense(make_sparse(3, k, m, 0.5, bs), bs)
        fused = ops.slr_matmul(x, p, vt, bsr, **I)
        separate = ops.lowrank_matmul(x, p, vt, **I) + ops.bsr_matmul(x, bsr, **I)
        assert_close(fused, separate, jnp.float32)

    def test_ragged_shape_pads(self):
        """Satellite: odd hidden sizes deploy — trailing partial blocks are
        zero-padded, outputs sliced back (masked parity vs plain matmul)."""
        t, k, m, r, bs = 5, 72, 100, 4, 32
        x, p, vt = rnd(0, (t, k)), rnd(1, (k, r)), rnd(2, (r, m))
        s = make_sparse(3, k, m, 0.3, bs)
        bsr = bsr_from_dense(s, bs)
        assert bsr.shape == (k, m) and bsr.padded_shape == (96, 128)
        got = ops.slr_matmul(x, p, vt, bsr, **I)
        want = np.asarray(x) @ (np.asarray(p) @ np.asarray(vt) + s)
        assert_close(got, want, jnp.float32)

    def test_fully_truncated(self):
        """r = 0 AND empty S: y = x @ 0 without any kernel launch."""
        x = rnd(0, (8, 64))
        bsr = bsr_from_dense(np.zeros((64, 32), np.float32), 32)
        got = ops.slr_matmul(x, jnp.zeros((64, 0)), jnp.zeros((0, 32)), bsr, **I)
        np.testing.assert_array_equal(got, jnp.zeros((8, 32)))
        got = ops.slr_matmul(x, None, None, bsr, **I)
        np.testing.assert_array_equal(got, jnp.zeros((8, 32)))


class TestStackedKernel:
    def _stacked(self, num_l=3, k=64, m=128, r=8, bs=32):
        p, vt = rnd(1, (num_l, k, r)), rnd(2, (num_l, r, m))
        # per-layer occupancies including one all-empty layer inside a
        # non-empty stack — its counts row is all zero, the epilogue only
        # pays the per-column low-rank emit
        mats = [
            bsr_from_dense(make_sparse(10 + l, k, m, occ, bs), bs)
            for l, occ in enumerate((0.4, 0.0, 0.9))
        ]
        return p, vt, stack_bsr(mats)

    def test_layers_match_per_matrix_oracle(self):
        p, vt, stack = self._stacked()
        x = rnd(0, (8, 64))
        for l in range(stack.num_layers):
            got = ops.slr_matmul_stacked(x, p, vt, stack, jnp.int32(l), **I)
            want = ref.slr_matmul_stacked_ref(x, p, vt, stack, jnp.int32(l))
            assert_close(got, want, jnp.float32)

    def test_scannable_over_layers(self):
        """The whole point of the layer axis: the stack rides lax.scan."""
        p, vt, stack = self._stacked()
        x = rnd(0, (4, 64))

        def body(carry, l):
            return carry, ops.slr_matmul_stacked(carry, p, vt, stack, l, **I)

        _, ys = jax.lax.scan(body, x, jnp.arange(stack.num_layers))
        for l in range(stack.num_layers):
            assert_close(
                ys[l], ref.slr_matmul_stacked_ref(x, p, vt, stack, jnp.int32(l)),
                jnp.float32,
            )

    def test_stack_pads_to_common_maxb(self):
        _, _, stack = self._stacked()
        assert stack.rows.shape[0] == 3
        # layer 2 at 0.9 occupancy dictates MAXB; layer 1 is all padding
        assert int(np.max(np.asarray(stack.counts)[1])) == 0
        assert stack.rows.shape[2] == int(np.max(np.asarray(stack.counts)))

    def test_empty_stack_dispatches_lowrank(self):
        num_l, k, m, r = 2, 64, 64, 4
        p, vt = rnd(1, (num_l, k, r)), rnd(2, (num_l, r, m))
        mats = [bsr_from_dense(np.zeros((k, m), np.float32), 32)] * num_l
        stack = stack_bsr(mats)
        assert stack.empty
        got = ops.slr_matmul_stacked(rnd(0, (4, k)), p, vt, stack, jnp.int32(1), **I)
        want = ref.lowrank_matmul_ref(rnd(0, (4, k)), p[1], vt[1])
        assert_close(got, want, jnp.float32)


class TestMultiAdapterKernel:
    """The adapter axis (PR 10): slot ``b`` of the batch runs adapter
    ``ids[b]``'s tables, gathered inside the kernel's DMA index maps. The
    auto dispatch lowers to the jnp oracle off-TPU, so these tests force
    ``interpret=True`` to keep the emulated kernel body covered."""

    def _pool(self, n=4, k=64, m=128, r=8, bs=32):
        p, vt = rnd(1, (n, k, r)), rnd(2, (n, r, m))
        mats = [
            bsr_from_dense(make_sparse(20 + a, k, m, occ, bs), bs)
            for a, occ in enumerate((0.6, 0.0, 0.9, 0.3))
        ]
        return p, vt, stack_bsr(mats)

    @pytest.mark.parametrize("t", [1, 8])            # decode / prefill widths
    def test_slots_match_per_slot_oracle(self, t):
        p, vt, stack = self._pool()
        x = rnd(0, (6, t, 64))
        ids = jnp.asarray([2, 0, 3, 0, 1, 2], jnp.int32)   # repeats included
        got = ops.slr_matmul_multi(x, p, vt, stack, ids, **I)
        assert got.shape == (6, t, 128) and got.dtype == x.dtype
        assert_close(got, ref.slr_matmul_multi_ref(x, p, vt, stack, ids),
                     jnp.float32)

    def test_slot_output_depends_only_on_its_id(self):
        """Permuting the slot->adapter map permutes rows, nothing else —
        the scalar-prefetch gather is truly per slot."""
        p, vt, stack = self._pool()
        x = rnd(0, (4, 1, 64))
        perm = np.asarray([3, 1, 0, 2])
        a = ops.slr_matmul_multi(x, p, vt, stack,
                                 jnp.asarray([0, 1, 2, 3]), **I)
        b = ops.slr_matmul_multi(x[perm], p, vt, stack,
                                 jnp.asarray(perm, jnp.int32), **I)
        assert_close(b, np.asarray(a)[perm], jnp.float32)

    def test_empty_pool_dispatches_lowrank_per_slot(self):
        n, k, m, r = 3, 64, 64, 4
        p, vt = rnd(1, (n, k, r)), rnd(2, (n, r, m))
        stack = stack_bsr([bsr_from_dense(np.zeros((k, m), np.float32), 32)] * n)
        assert stack.empty
        x, ids = rnd(0, (2, 4, k)), jnp.asarray([2, 0], jnp.int32)
        got = ops.slr_matmul_multi(x, p, vt, stack, ids, **I)
        assert_close(got, ref.slr_matmul_multi_ref(x, p, vt, stack, ids),
                     jnp.float32)

    def test_rank_zero_pool(self):
        p, vt, stack = self._pool(r=0)
        x, ids = rnd(0, (3, 1, 64)), jnp.asarray([1, 3, 0], jnp.int32)
        got = ops.slr_matmul_multi(x, p, vt, stack, ids, **I)
        assert_close(got, ref.slr_matmul_multi_ref(x, p, vt, stack, ids),
                     jnp.float32)

    def test_auto_dispatch_is_the_oracle_off_tpu(self, monkeypatch):
        """Interpret-mode grid emulation charges every call for the FULL
        pooled operands (cost grows with pool capacity, not batch), so the
        non-TPU lowering is the vectorized oracle; explicit interpret=True
        still reaches the kernel (the tests above depend on it)."""
        import repro.kernels.ops as ops_mod

        assert ops_mod._auto_interpret()            # this container: no TPU
        monkeypatch.setattr(
            ops_mod, "slr_matmul_multi_pallas",
            lambda *a, **k: pytest.fail("emulated kernel in auto dispatch"),
        )
        p, vt, stack = self._pool()
        x, ids = rnd(0, (2, 1, 64)), jnp.asarray([1, 2], jnp.int32)
        got = ops.slr_matmul_multi(x, p, vt, stack, ids)
        assert_close(got, ref.slr_matmul_multi_ref(x, p, vt, stack, ids),
                     jnp.float32)


# ---------------------------------------------------------------- fast paths ---


class TestFastPaths:
    def test_empty_s_skips_bsr_kernel(self, monkeypatch):
        """ops.bsr_matmul must not launch a kernel for a statically-empty S."""
        import repro.kernels.ops as ops_mod

        monkeypatch.setattr(
            ops_mod, "bsr_matmul_pallas",
            lambda *a, **k: pytest.fail("kernel launched for empty S"),
        )
        bsr = bsr_from_dense(np.zeros((64, 32), np.float32), 32)
        assert bsr.empty
        out = ops.bsr_matmul(rnd(0, (8, 64)), bsr)
        np.testing.assert_array_equal(out, jnp.zeros((8, 32)))

    def test_empty_s_skips_fused_sparse_epilogue(self, monkeypatch):
        """The fused wrapper drops to the low-rank-only kernel for empty S."""
        import repro.kernels.ops as ops_mod

        monkeypatch.setattr(
            ops_mod, "slr_matmul_pallas",
            lambda *a, **k: pytest.fail("fused kernel launched for empty S"),
        )
        x, p, vt = rnd(0, (8, 64)), rnd(1, (64, 4)), rnd(2, (4, 32))
        bsr = bsr_from_dense(np.zeros((64, 32), np.float32), 32)
        got = ops.slr_matmul(x, p, vt, bsr, **I)
        assert_close(got, ref.lowrank_matmul_ref(x, p, vt), jnp.float32)

    def test_decode_width_row_tiles(self):
        assert row_tile(4, jnp.float32) == 8      # not 128
        assert row_tile(4, jnp.bfloat16) == 16
        assert row_tile(100, jnp.float32) == 104
        assert row_tile(300, jnp.float32) == 128  # capped for prefill


# ------------------------------------------------------------- fused format ---


@pytest.fixture(scope="module")
def trained():
    cfg = get_arch("olmo_1b").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=5.0, exact_svd=True
    )
    state, blocks = init_slr_state(params, scfg)
    for step in range(4):
        state, _ = admm_update(params, state, blocks, scfg, step)
    return cfg, params, state, blocks


@pytest.fixture(scope="module")
def banks(trained):
    cfg, params, state, blocks = trained
    return {
        fmt: ModelBank.build(cfg, params, state, blocks, budgets=(1.0, 0.6),
                             fmt=fmt, bsr_block=32)
        for fmt in ("factored", "fused")
    }


PROMPTS = [[5, 7, 11, 13, 17], [23, 29, 31, 37, 41, 43, 47, 53, 59], [61, 67, 71]]


def run_tokens(engine, prompts, max_new=5, tiers=None):
    for i, p in enumerate(prompts):
        engine.submit(p, max_new_tokens=max_new,
                      tier=None if tiers is None else tiers[i])
    return {r.uid: r.out_tokens for r in engine.run()}


class TestFusedFormat:
    def test_layers_stay_scan_stacked(self, trained):
        """Unlike 'bsr', 'fused' never unrolls the layer stack — the stacked
        tables scan by index through the kernel's scalar-prefetch maps."""
        cfg, params, state, blocks = trained
        dm = DeployedModel.build(cfg, params, state, blocks, fmt="fused",
                                 bsr_block=32)
        assert not isinstance(dm.params["layers"], (list, tuple))
        is_slr = lambda x: isinstance(x, SLRLinear)  # noqa: E731
        stacked = [
            leaf for leaf in jax.tree_util.tree_leaves(
                dm.params["layers"], is_leaf=is_slr)
            if isinstance(leaf, SLRLinear)
        ]
        assert stacked and any(l.scan_by_index for l in stacked)
        assert all(l.fuse for l in stacked)

    def test_forward_parity_vs_factored(self, trained):
        cfg, params, state, blocks = trained
        dm_fa = DeployedModel.build(cfg, params, state, blocks, fmt="factored")
        dm_fu = DeployedModel.build(cfg, params, state, blocks, fmt="fused",
                                    bsr_block=32)
        toks = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6], [5, 3, 5, 8, 9, 7, 9, 3]],
                           jnp.int32)
        lf, lu = dm_fa.forward(toks), dm_fu.forward(toks)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lu),
                                   atol=2e-3, rtol=2e-3)
        assert bool((lf.argmax(-1) == lu.argmax(-1)).all())

    def test_param_bytes_accounts_stacked_tables(self, trained):
        cfg, params, state, blocks = trained
        dm = DeployedModel.build(cfg, params, state, blocks, fmt="fused",
                                 bsr_block=32)
        acct = dm.param_bytes()
        assert acct["format"] == "fused" and acct["structured_bytes"] > 0


class TestFusedEngineStreams:
    """Acceptance: fused greedy streams bitwise-identical to factored."""

    def _compare(self, banks, engine_cls, ecfg_kw, max_new=5, tiers=None):
        streams = {}
        for fmt in ("factored", "fused"):
            eng = engine_cls(banks[fmt], EngineConfig(**ecfg_kw))
            streams[fmt] = run_tokens(eng, PROMPTS, max_new=max_new, tiers=tiers)
        assert streams["fused"] == streams["factored"], streams
        return streams["fused"]

    def test_paged_decode(self, banks):
        out = self._compare(
            banks, PagedServingEngine,
            dict(max_slots=3, max_len=32, block_size=8),
        )
        assert all(len(t) == 5 for t in out.values())

    def test_chunked_prefill(self, banks):
        self._compare(
            banks, PagedServingEngine,
            dict(max_slots=3, max_len=64, block_size=8, prefill_chunk=8),
        )

    def test_int8_kv_pages(self, banks):
        self._compare(
            banks, PagedServingEngine,
            dict(max_slots=3, max_len=32, block_size=8, kv_dtype="int8"),
        )

    def test_speculative(self, banks):
        self._compare(
            banks, SpeculativeEngine,
            dict(max_slots=2, max_len=32, block_size=8, spec_k=3),
        )

    def test_elastic_tiers(self, banks):
        """Per-request tiers: tier-1 slots ride the 0.6-budget fused weights
        and still match factored token-for-token."""
        self._compare(
            banks, PagedServingEngine,
            dict(max_slots=3, max_len=32, block_size=8),
            tiers=[0, 1, 1],
        )
