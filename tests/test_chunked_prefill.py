"""Chunked prefill tests (PR 4 tentpole).

The core invariant: splitting prompt processing into block-aligned chunks
interleaved with decode ticks changes WHEN prefill work happens, never WHAT
gets served — greedy token streams are identical to one-shot prefill across
chunk sizes, int8 pages, eviction/resume (including eviction landing
MID-prefill), the Pallas kernel path, and the speculative engine. At the
model level, chunk-chained prefill reproduces one-shot logits/KV to float
accumulation-order tolerance with identical argmax (the batched matmul
shapes differ, so bitwise equality is asserted on the emitted token streams,
not raw float pages).

Also covers the PR 4 satellites: ``decode_emitted_tokens`` accounting when an
eviction lands mid-prefill (the old ``1 + evictions`` convention overcounted
prefill emissions), EDF admission ordering unified across both batched
engines, monotonic-clock timestamps, and the query-tiled k-query kernel at
chunk widths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.kernels.ops import paged_attention_kquery
from repro.kernels.ref import paged_attention_kquery_ref
from repro.models import model as model_lib
from repro.models import transformer as transformer_lib
from repro.models.attention import blockwise_attention
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineCapabilityError,
    EngineConfig,
    PagedServingEngine,
    ReferenceEngine,
    Request,
    ServingEngine,
    decode_emitted_tokens,
)
from repro.serving.speculative import SpeculativeEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = get_arch("salaad_llama_60m").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# a long prompt spanning several chunks mixed with shorts that finish and
# free their slots mid-stream (slot reuse while the long one is in flight)
PROMPTS = [[5, 7, 11], [3, 1], list(range(2, 40)), [8, 8, 2],
           [1, 2, 3, 4, 5, 6], [9, 1]]


def run_tokens(engine, prompts=PROMPTS, max_new=5):
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    return {r.uid: r.out_tokens for r in engine.run()}


# ----------------------------------------------------------- model level ---


class TestChunkPrefillStep:
    def _paged(self, cfg, S, bs, nb):
        cache = model_lib.init_paged_cache(
            cfg, S, S * nb, bs, nb, dtype=jnp.float32
        )
        table = np.arange(S * nb, dtype=np.int32).reshape(S, nb)
        return cache._replace(block_table=jnp.asarray(table))

    @pytest.mark.parametrize("chunk", [8, 16])
    def test_chunk_chain_matches_oneshot(self, tiny, chunk):
        """Chaining chunk_prefill_step over an empty paged cache reproduces
        the one-shot prefill scatter: same argmax at the prompt end, same
        greedy continuation over several decode steps, KV pages equal to
        accumulation-order tolerance."""
        cfg, params = tiny
        S, bs, nb = 2, 8, 8
        prompts = [list(range(2, 40)), [7, 3, 9, 1, 4]]
        lens = np.array([len(p) for p in prompts], np.int32)
        bucket = 40

        toks = np.zeros((S, bucket), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
        one = self._paged(cfg, S, bs, nb)
        logits1, kvs, _ = model_lib._forward(
            params, {"tokens": jnp.asarray(toks)}, cfg, collect_kv=True
        )
        page_map = np.full((S, bucket // bs), S * nb, np.int32)
        for i, p in enumerate(prompts):
            blocks = -(-len(p) // bs)
            page_map[i, :blocks] = np.asarray(one.block_table)[i, :blocks]
        one = transformer_lib.scatter_prefill_pages(
            one, kvs, jnp.asarray(page_map)
        )
        one = one._replace(length=jnp.asarray(lens))
        last1 = np.asarray(logits1)[np.arange(S), lens - 1]

        chk = self._paged(cfg, S, bs, nb)
        progress = np.zeros((S,), np.int32)
        last2 = np.zeros_like(last1)
        while (progress < lens).any():
            ck = np.zeros((S, chunk), np.int32)
            counts = np.zeros((S,), np.int32)
            for i, p in enumerate(prompts):
                c = min(chunk, len(p) - int(progress[i]))
                if c > 0:
                    ck[i, :c] = p[progress[i] : progress[i] + c]
                    counts[i] = c
            lg, chk = model_lib.chunk_prefill_step(
                params, jnp.asarray(ck), jnp.asarray(counts), chk, cfg
            )
            lg = np.asarray(lg)
            for i in range(S):
                if counts[i] and progress[i] + counts[i] >= lens[i]:
                    last2[i] = lg[i, counts[i] - 1]
            progress += counts
        assert np.array_equal(chk.length, lens)

        # prompt-end logits: identical argmax, tight float agreement
        np.testing.assert_allclose(last1, last2, atol=1e-4)
        assert np.array_equal(last1.argmax(-1), last2.argmax(-1))

        # KV at every VALID position agrees to accumulation tolerance (the
        # padded tails of the last block/chunk carry path-specific junk that
        # is never attended — masked out of the comparison)
        def valid_kv(cache):
            k = np.asarray(cache.k)              # (L, P, H, bs, D)
            bt = np.asarray(cache.block_table)
            return [
                np.stack([
                    k[:, bt[i, j // bs], :, j % bs] for j in range(int(ln))
                ])
                for i, ln in enumerate(lens)
            ]

        for a, b in zip(valid_kv(one), valid_kv(chk)):
            np.testing.assert_allclose(a, b, atol=1e-4)

        # greedy continuation: identical token streams from either cache
        t1 = last1.argmax(-1).astype(np.int32)
        t2 = last2.argmax(-1).astype(np.int32)
        for _ in range(4):
            assert np.array_equal(t1, t2)
            l1, one = model_lib.decode_step(params, jnp.asarray(t1[:, None]), one, cfg)
            l2, chk = model_lib.decode_step(params, jnp.asarray(t2[:, None]), chk, cfg)
            t1 = np.asarray(l1)[:, -1].argmax(-1).astype(np.int32)
            t2 = np.asarray(l2)[:, -1].argmax(-1).astype(np.int32)

    def test_contiguous_per_slot_chunk(self, tiny):
        """Chunked prefill against a per-slot-length CONTIGUOUS cache (the
        blockwise path with (B,) causal offsets — previously
        NotImplementedError) matches the paged chunk path's logits."""
        cfg, params = tiny
        S, max_len, chunk = 2, 32, 8
        prompts = [list(range(2, 18)), [7, 3, 9, 1, 4, 2, 8, 8, 1, 2]]
        lens = np.array([len(p) for p in prompts], np.int32)

        contig = model_lib.init_cache(cfg, S, max_len, dtype=jnp.float32)
        contig = contig._replace(length=jnp.zeros((S,), jnp.int32))
        paged = model_lib.init_paged_cache(cfg, S, S * 8, 4, 8, dtype=jnp.float32)
        paged = paged._replace(
            block_table=jnp.asarray(
                np.arange(S * 8, dtype=np.int32).reshape(S, 8)
            )
        )
        progress = np.zeros((S,), np.int32)
        while (progress < lens).any():
            ck = np.zeros((S, chunk), np.int32)
            counts = np.zeros((S,), np.int32)
            for i, p in enumerate(prompts):
                c = min(chunk, len(p) - int(progress[i]))
                if c > 0:
                    ck[i, :c] = p[progress[i] : progress[i] + c]
                    counts[i] = c
            lc, contig = model_lib.chunk_prefill_step(
                params, jnp.asarray(ck), jnp.asarray(counts), contig, cfg
            )
            lp, paged = model_lib.chunk_prefill_step(
                params, jnp.asarray(ck), jnp.asarray(counts), paged, cfg
            )
            for i in range(S):
                c = int(counts[i])
                if c:
                    np.testing.assert_allclose(
                        np.asarray(lc)[i, :c], np.asarray(lp)[i, :c],
                        atol=1e-4,
                    )
                    assert np.array_equal(
                        np.asarray(lc)[i, :c].argmax(-1),
                        np.asarray(lp)[i, :c].argmax(-1),
                    )
            progress += counts

    def test_blockwise_per_slot_offset(self):
        """(B,) causal offsets in blockwise_attention == per-row runs with
        the matching scalar offset."""
        rng = np.random.RandomState(0)
        b, hq, hkv, t, s, d = 3, 4, 2, 5, 24, 8
        q = jnp.asarray(rng.randn(b, hq, t, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
        offs = np.array([0, 7, 19], np.int32)
        out = blockwise_attention(
            q, k, v, q_block=4, kv_block=8, causal_offset=jnp.asarray(offs)
        )
        for i, o in enumerate(offs):
            row = blockwise_attention(
                q[i : i + 1], k[i : i + 1], v[i : i + 1],
                q_block=4, kv_block=8, causal_offset=int(o),
            )
            np.testing.assert_allclose(
                np.asarray(out[i]), np.asarray(row[0]), atol=1e-5
            )

    def test_contiguous_boundary_write_drops_not_clamps(self, tiny):
        """A ragged chunk whose padded tail crosses max_len on a contiguous
        per-slot cache must DROP the out-of-range rows — a clamped
        dynamic_update_slice would shift the write start back over valid
        history."""
        cfg, params = tiny
        S, max_len, C = 2, 16, 8
        cache = model_lib.init_cache(cfg, S, max_len, dtype=jnp.float32)
        cache = cache._replace(length=jnp.asarray([12, 0], jnp.int32))
        k_before = np.asarray(cache.k).copy()
        toks = np.zeros((S, C), np.int32)
        toks[0, :4] = [1, 2, 3, 4]
        toks[1, :3] = [5, 6, 7]
        _, out = model_lib.chunk_prefill_step(
            params, jnp.asarray(toks), jnp.asarray([4, 3], jnp.int32),
            cache, cfg,
        )
        # slot 0 wrote 12..15; the padded tail (16..19) dropped — history
        # at 0..11 is untouched bit-for-bit
        assert np.array_equal(
            np.asarray(out.k)[:, 0, :, :12], k_before[:, 0, :, :12]
        )
        assert np.array_equal(np.asarray(out.length), [16, 3])

    def test_rejects_stateless_families(self, tiny):
        cfg, _ = tiny
        bad = dataclasses.replace(cfg, family="ssm")
        with pytest.raises(ValueError):
            model_lib.chunk_prefill_step(
                None, jnp.zeros((1, 4), jnp.int32),
                jnp.zeros((1,), jnp.int32), None, bad
            )


# ---------------------------------------------------------------- kernel ---


class TestChunkWidthKernel:
    """The k-query Pallas kernel generalized to chunk-width queries: the
    query axis tiles across the grid, kq pads to the tile multiple, and the
    (tiling-free) jnp oracle must be reproduced exactly for every (width,
    tile) combination — including tiles that do NOT divide kq."""

    def _pool(self, seed=0, b=3, hq=4, hkv=2, d=8, bs=4, nbt=6, n=24):
        rng = np.random.RandomState(seed)
        kp = jnp.asarray(rng.randn(n, hkv, bs, d), jnp.float32)
        vp = jnp.asarray(rng.randn(n, hkv, bs, d), jnp.float32)
        bt = jnp.asarray(
            rng.permutation(n)[: b * nbt].reshape(b, nbt), jnp.int32
        )
        lengths = jnp.asarray([5, 0, 13], jnp.int32)
        return kp, vp, bt, lengths, rng

    @pytest.mark.parametrize("kq", [1, 4, 6, 16])
    @pytest.mark.parametrize("q_tile", [None, 2, 3, 4])
    def test_kernel_matches_ref_at_chunk_widths(self, kq, q_tile):
        kp, vp, bt, lengths, rng = self._pool()
        q = jnp.asarray(rng.randn(3, 4, kq, d := 8), jnp.float32)
        ref = paged_attention_kquery_ref(q, kp, vp, bt, lengths)
        out = paged_attention_kquery(q, kp, vp, bt, lengths, q_tile=q_tile)
        assert out.shape == (3, 4, kq, d)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5
        )

    def test_auto_tiling_kicks_in_for_wide_chunks(self):
        """A chunk wide enough to exceed the per-tile row budget must still
        match the oracle (auto q_tile path)."""
        from repro.kernels.paged_attention import _MAX_Q_ROWS

        kp, vp, bt, lengths, rng = self._pool()
        kq = _MAX_Q_ROWS // 2 + 8          # group=2 -> rows > _MAX_Q_ROWS
        q = jnp.asarray(rng.randn(3, 4, kq, 8), jnp.float32)
        ref = paged_attention_kquery_ref(q, kp, vp, bt, lengths)
        out = paged_attention_kquery(q, kp, vp, bt, lengths)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5
        )


# ---------------------------------------------------------------- engine ---


class TestChunkedEngineEquivalence:
    @pytest.mark.parametrize("chunk", [8, 16, 32])
    def test_streams_match_oneshot_and_padded(self, tiny, chunk):
        """The core acceptance invariant: chunked greedy output is bitwise
        identical to one-shot paged AND slot-padded output, under slot reuse
        and mid-stream admission; the chunk program compiles exactly once and
        fully replaces the one-shot prefill program."""
        cfg, params = tiny
        ref = run_tokens(
            ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=64))
        )
        one = run_tokens(PagedServingEngine(
            ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=64, block_size=8)
        ))
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8, prefill_chunk=chunk
        ))
        got = run_tokens(eng)
        assert got == ref == one
        assert eng.chunk_traces == 1
        assert eng.chunk_calls > 0 and eng.prefill_calls == 0
        # every request completed exactly one prefill (no eviction here)
        assert decode_emitted_tokens(
            [Request(0, [1], out_tokens=t, prefill_emitted=1)
             for t in got.values()]
        ) == sum(len(t) - 1 for t in got.values())

    def test_int8_pages(self, tiny):
        cfg, params = tiny
        ref = run_tokens(PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8, kv_dtype="int8"
        )))
        got = run_tokens(PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8, kv_dtype="int8",
            prefill_chunk=16,
        )))
        assert got == ref

    def test_pallas_kernel_path(self, tiny):
        cfg, params = tiny
        c2 = dataclasses.replace(cfg, kernel_impl="pallas")
        dense = run_tokens(PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8, prefill_chunk=16
        )))
        pallas = run_tokens(PagedServingEngine(ModelBank.single(c2, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8, prefill_chunk=16
        )))
        assert pallas == dense

    @pytest.mark.parametrize("mode", ["parallel", "sequential"])
    def test_speculative_engine_inherits_chunking(self, tiny, mode):
        """SpeculativeEngine chunks BOTH caches (target + draft) and still
        emits streams identical to the plain paged engine under greedy."""
        cfg, params = tiny
        ref = run_tokens(PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8
        )))
        eng = SpeculativeEngine(ModelBank(cfg, [params, params]), EngineConfig(
            max_slots=2, max_len=64, block_size=8, spec_k=3,
            spec_draft_mode=mode, prefill_chunk=16,
        ))
        got = run_tokens(eng)
        assert got == ref
        assert eng.chunk_calls > 0 and eng.prefill_calls == 0

    def test_monotonic_timestamps(self, tiny):
        """Engine timestamps ride the monotonic clock: per-request ordering
        submitted <= admitted <= first_token <= finished always holds and
        token_times never decrease (an NTP step cannot break this)."""
        cfg, params = tiny
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8, prefill_chunk=8
        ))
        for p in PROMPTS[:3]:
            eng.submit(p, max_new_tokens=4)
        for r in eng.run():
            assert r.submitted_at <= r.admitted_at <= r.first_token_at
            assert r.first_token_at <= r.finished_at
            assert all(a <= b for a, b in
                       zip(r.token_times, r.token_times[1:]))

    def test_invalid_chunk_rejected(self, tiny):
        cfg, params = tiny
        with pytest.raises(ValueError):
            PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
                max_slots=2, max_len=64, block_size=8, prefill_chunk=12
            ))
        with pytest.raises(ValueError):
            PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
                max_slots=2, max_len=64, block_size=8, prefill_chunk=0
            ))

    def test_capability_errors_on_non_paged_engines(self, tiny):
        """prefill_chunk is paged-only and must fail loudly elsewhere (the
        'never silently drop a requested feature' convention)."""
        cfg, params = tiny
        with pytest.raises(EngineCapabilityError):
            ServingEngine(ModelBank.single(cfg, params), EngineConfig(
                max_slots=2, max_len=64, prefill_chunk=16
            ))
        with pytest.raises(EngineCapabilityError):
            ReferenceEngine(ModelBank.single(cfg, params), EngineConfig(
                max_slots=2, max_len=64, prefill_chunk=16
            ))


class TestChunkedEviction:
    def test_decode_phase_eviction_preserves_tokens(self, tiny):
        """A pool too small for two requests forces eviction; the evicted
        request resumes by re-prefilling CHUNK-BY-CHUNK and must emit the
        same tokens. Accounting: every completed admission emitted one
        prefill token, so prefill_emitted == 1 + evictions here."""
        cfg, params = tiny
        prompts = [[5, 7, 11], [3, 1, 4]]
        e_ref = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=16))
        ref = run_tokens(e_ref, prompts, max_new=10)

        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=16, block_size=4, num_blocks=4,
            decode_reserve=1, prefill_chunk=4,
        ))
        done = []
        for p in prompts:
            eng.submit(p, max_new_tokens=10)
        done = eng.run()
        assert {r.uid: r.out_tokens for r in done} == ref
        assert eng.evictions >= 1
        assert eng.allocator.used_blocks == 0
        for r in done:
            # decode-phase evictions: every admission reached its prompt end
            assert r.prefill_emitted == 1 + r.evictions
        assert decode_emitted_tokens(done) == sum(
            len(r.out_tokens) - 1 - r.evictions for r in done
        )

    def test_eviction_mid_prefill_resumes_correctly(self, tiny):
        """Two long prompts whose chunked prefills jointly exhaust the pool:
        one gets evicted MID-prefill (no decode-phase victim exists), loses
        its partial chunks, re-admits, and still emits the reference stream.
        Accounting regression: that request completed ONE prefill but has
        evictions >= 1, so prefill_emitted != 1 + evictions — the old
        ``len(out) - 1 - evictions`` convention would undercount its decode
        tokens."""
        cfg, params = tiny
        prompts = [list(range(2, 22)), list(range(30, 50))]   # 20 toks each
        e_ref = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32))
        ref = run_tokens(e_ref, prompts, max_new=4)

        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=32, block_size=4, num_blocks=8,
            decode_reserve=1, prefill_chunk=4,
        ))
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        done = eng.run(max_steps=500)
        assert {r.uid: r.out_tokens for r in done} == ref
        assert eng.evictions >= 1
        assert eng.allocator.used_blocks == 0
        evicted = [r for r in done if r.evictions]
        assert evicted, "pool was sized to force a mid-prefill eviction"
        mid_prefill = [r for r in evicted
                       if r.prefill_emitted < 1 + r.evictions]
        assert mid_prefill, (
            "expected at least one eviction to land mid-prefill "
            f"(got {[(r.uid, r.evictions, r.prefill_emitted) for r in done]})"
        )
        # decode-token accounting stays exact even for that request
        total = sum(len(r.out_tokens) for r in done)
        emitted_by_prefill = sum(r.prefill_emitted for r in done)
        assert decode_emitted_tokens(done) == total - emitted_by_prefill

    def test_contending_prefills_terminate_without_livelock(self, tiny):
        """Regression: two prompts whose TOTAL page needs exceed the pool are
        both admitted (chunked admission reserves only the first chunk). An
        earlier design let each prefill's page growth evict the other — the
        two requests ping-ponged forever (measured livelock: 10k steps, zero
        completions, plus a KeyError on the ready batch). Prefill growth now
        STALLS and the all-stalled deadlock breaker evicts exactly one
        victim, so both requests finish with the reference streams."""
        cfg, params = tiny
        prompts = [list(range(1, 49)), list(range(50, 98))]   # 48 toks each
        e_ref = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=64))
        ref = run_tokens(e_ref, prompts, max_new=4)
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=64, block_size=8, num_blocks=9,
            prefill_chunk=8,
        ))
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        done = eng.run(max_steps=500)
        assert {r.uid: r.out_tokens for r in done} == ref
        assert eng.evictions >= 1
        assert eng.allocator.used_blocks == 0

    def test_three_way_contention_survivors_absorb_freed_pages(self, tiny):
        """Regression: when the all-stalled deadlock breaker evicts a slot
        holding exactly one chunk's pages, the SURVIVORS must absorb those
        pages within the same tick — deferring to the next tick let the
        evicted request re-admit and re-reserve exactly what it freed (a
        measured 2-tick ping-pong: 3 slots, 0 completions, unbounded
        evictions)."""
        cfg, params = tiny
        prompts = [list(range(1, 41)), list(range(41, 81)),
                   list(range(81, 121))]                  # 40 toks each
        e_ref = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=3, max_len=64))
        ref = run_tokens(e_ref, prompts, max_new=4)
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=3, max_len=64, block_size=4, num_blocks=14,
            prefill_chunk=8,
        ))
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        done = eng.run(max_steps=500)
        assert {r.uid: r.out_tokens for r in done} == ref
        assert len(done) == 3
        assert eng.evictions >= 1
        assert eng.allocator.used_blocks == 0

    def test_decode_growth_can_evict_stalled_prefill(self, tiny):
        """A nearly-finished decoder growing into a dry pool evicts the
        mid-prefill slot (longest_remaining counts its whole max_new), never
        the other way around — the decoder always finishes and frees its
        pages for the prefill to resume."""
        cfg, params = tiny
        # the short request finishes prefill immediately and decodes while
        # the long one's chunks grow into the pool
        prompts = [list(range(2, 26)), [7, 7, 7]]
        e_ref = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32))
        ref = run_tokens(e_ref, prompts, max_new=6)
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=32, block_size=4, num_blocks=8,
            decode_reserve=1, prefill_chunk=4,
        ))
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        done = eng.run(max_steps=500)
        assert {r.uid: r.out_tokens for r in done} == ref
        assert eng.allocator.used_blocks == 0
        short_req = next(r for r in done if len(r.prompt) == 3)
        assert short_req.evictions == 0


class TestEDFAdmission:
    def _req(self, uid, deadline=None, evictions=0):
        return Request(uid, [1], deadline=deadline, evictions=evictions)

    def test_order_unified_across_engines(self, tiny):
        """Both batched engines share one EDF order: earliest deadline first,
        evicted/resumed requests break ties, then FIFO."""
        cfg, params = tiny
        for eng in (
            ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=16)),
            PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
                max_slots=2, max_len=16, block_size=8
            )),
        ):
            eng._queue = [
                self._req(1, deadline=9.0),
                self._req(2, deadline=3.0),
                self._req(3),                              # no deadline: last
                self._req(4, deadline=3.0, evictions=1),   # tie: evicted first
                self._req(5, deadline=1.0),
                self._req(6),
            ]
            eng._order_queue()
            assert [r.uid for r in eng._queue] == [5, 4, 2, 1, 3, 6]

    def test_padded_engine_admits_edf(self, tiny):
        """The slot-padded engine used to pop FIFO ignoring deadlines; now an
        urgent late submission is admitted (and finishes) first."""
        cfg, params = tiny
        eng = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=1, max_len=32))
        eng.submit([5, 7, 11], max_new_tokens=3, deadline=100.0)
        eng.submit([3, 1], max_new_tokens=3, deadline=50.0)
        eng.submit([8, 8, 2], max_new_tokens=3, deadline=1.0)
        done = eng.run()
        assert [r.uid for r in done] == [3, 2, 1]

    def test_edf_beats_eviction_priority(self, tiny):
        """An evicted request does NOT jump an urgent fresh request with an
        earlier deadline (EDF stays primary; eviction is only a tiebreak)."""
        cfg, params = tiny
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=16, block_size=8
        ))
        eng._queue = [
            self._req(1, deadline=5.0, evictions=2),
            self._req(2, deadline=1.0),
        ]
        eng._order_queue()
        assert [r.uid for r in eng._queue] == [2, 1]
