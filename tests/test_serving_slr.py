"""Deployment-path tests: SLR parameter formats, kernels vs XLA fallback,
deployment accounting, and the surrogate-equals-deployed invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, admm_update, init_slr_state, surrogate_params
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.models import model as model_lib
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.slr_params import build_slr_linears, deployment_report


@pytest.fixture(scope="module")
def trained():
    cfg = get_arch("olmo_1b").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=5.0, exact_svd=True
    )
    state, blocks = init_slr_state(params, scfg)
    for step in range(4):
        state, _ = admm_update(params, state, blocks, scfg, step)
    return cfg, params, state, blocks


class TestSLRLinears:
    def test_factored_apply_matches_surrogate(self, trained):
        cfg, params, state, blocks = trained
        linears = build_slr_linears(state, blocks, fmt="factored")
        surr = surrogate_params(params, state, blocks)
        info = next(b for b in blocks if not b.stack_dims)
        lin = linears[info.name]
        w_surr = surr
        for p in info.path:
            w_surr = w_surr[getattr(p, "key", getattr(p, "idx", None))]
        x = jax.random.normal(jax.random.PRNGKey(1), (8, info.n))
        np.testing.assert_allclose(
            lin.apply(x), x @ w_surr, atol=1e-3, rtol=1e-3
        )

    def test_bsr_kernel_matches_xla(self, trained):
        cfg, params, state, blocks = trained
        linears = build_slr_linears(state, blocks, fmt="bsr", bsr_block=32)
        checked = 0
        for info in blocks:
            lin = linears[info.name]
            if lin.p is None or lin.p.ndim != 2:
                continue
            x = jax.random.normal(jax.random.PRNGKey(2), (8, info.n))
            np.testing.assert_allclose(
                lin.apply(x, kernel=True), lin.apply(x, kernel=False),
                atol=2e-3, rtol=2e-3,
            )
            checked += 1
        assert checked >= 1

    def test_param_bytes_drop_after_hpa(self, trained):
        cfg, params, state, blocks = trained
        before = deployment_report(params, state, blocks)
        comp, _ = hpa_keep_ratio(state, blocks, keep_ratio=0.4, kappa=0.7)
        after = deployment_report(params, comp, blocks)
        assert after["slr_total_bytes"] < before["slr_total_bytes"]
        assert after["compression"] > before["compression"]

    def test_deployed_model_runs(self, trained):
        """HPA-compressed surrogate params drive the unchanged model code."""
        cfg, params, state, blocks = trained
        comp, _ = hpa_keep_ratio(state, blocks, keep_ratio=0.5, kappa=0.7)
        deploy = surrogate_params(params, comp, blocks)
        batch = {
            "tokens": jnp.ones((2, 8), jnp.int32),
            "labels": jnp.ones((2, 8), jnp.int32),
        }
        loss, _ = model_lib.loss_fn(deploy, batch, cfg)
        assert np.isfinite(float(loss))


class TestDeployedModel:
    """The serving-format forward must match the dense-materialized forward."""

    def test_factored_and_bsr_match_dense_forward(self, trained):
        cfg, params, state, blocks = trained
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0, cfg.vocab_size)
        dense = DeployedModel.build(cfg, params, state, blocks, fmt="dense")
        ref = dense.forward(toks)
        for fmt in ("factored", "bsr"):
            dm = DeployedModel.build(cfg, params, state, blocks, fmt=fmt, bsr_block=32)
            np.testing.assert_allclose(
                np.asarray(dm.forward(toks)), np.asarray(ref), atol=1e-3, rtol=1e-3,
            )

    def test_formats_work_under_jit(self, trained):
        cfg, params, state, blocks = trained
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 6), 0, cfg.vocab_size)
        dm = DeployedModel.build(cfg, params, state, blocks, fmt="factored")
        f = jax.jit(lambda p, t: model_lib._forward(p, {"tokens": t}, cfg)[0])
        np.testing.assert_allclose(
            np.asarray(f(dm.params, toks)), np.asarray(dm.forward(toks)),
            atol=1e-5, rtol=1e-5,
        )

    def test_served_bytes_shrink_with_budget(self, trained):
        cfg, params, state, blocks = trained
        full = DeployedModel.build(cfg, params, state, blocks, fmt="factored")
        comp, _ = hpa_keep_ratio(state, blocks, keep_ratio=0.4, kappa=0.7)
        small = DeployedModel.build(cfg, params, comp, blocks, fmt="factored")
        assert small.param_bytes()["total_bytes"] < full.param_bytes()["total_bytes"]


class TestBatchedEngine:
    """The tentpole invariants: one jitted decode step per engine tick for ALL
    active slots, and exact parity with the plain full-forward greedy rollout."""

    def _full_forward_greedy(self, cfg, params, prompt, n_new):
        toks = list(prompt)
        for _ in range(n_new):
            logits, _, _ = model_lib._forward(
                params, {"tokens": jnp.asarray([toks], jnp.int32)}, cfg
            )
            toks.append(int(jnp.argmax(logits[0, -1])))
        return toks[len(prompt):]

    def test_one_device_call_per_decode_step(self, trained):
        cfg, params, state, blocks = trained
        eng = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32))
        for i in range(5):
            eng.submit([1 + i, 2, 3], max_new_tokens=4)
        done = eng.run()
        assert len(done) == 5 and all(len(r.out_tokens) == 4 for r in done)
        total_tokens = sum(len(r.out_tokens) for r in done)
        # one jitted decode program, traced exactly once, one device call per
        # step — NOT one per slot per token (the seed reference behavior)
        assert eng.decode_traces == 1
        assert eng.decode_calls < total_tokens
        # prefill went through the batched program: one trace per bucket,
        # never one call per token
        assert eng.prefill_traces <= 2
        assert eng.prefill_calls <= 5

    def test_batched_decode_matches_full_forward(self, trained):
        """Per-slot lengths + batched sampling == independent greedy rollouts."""
        cfg, params, state, blocks = trained
        prompts = [[5, 7, 11], [3, 1], [2, 9, 4, 6]]
        eng = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32))
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        by_uid = {r.uid: r.out_tokens for r in eng.run()}
        for uid, prompt in enumerate(prompts, start=1):
            ref = self._full_forward_greedy(cfg, params, prompt, 4)
            assert by_uid[uid] == ref, (uid, by_uid[uid], ref)

    def test_engine_serves_slr_formats_identically(self, trained):
        cfg, params, state, blocks = trained
        comp, _ = hpa_keep_ratio(state, blocks, keep_ratio=0.6, kappa=0.7)
        outs = {}
        for fmt in ("dense", "factored"):
            dm = DeployedModel.build(cfg, params, comp, blocks, fmt=fmt)
            eng = ServingEngine(dm, EngineConfig(max_slots=2, max_len=32))
            eng.submit([4, 8, 15], max_new_tokens=4)
            eng.submit([16, 23], max_new_tokens=4)
            outs[fmt] = [r.out_tokens for r in sorted(eng.run(), key=lambda r: r.uid)]
        assert outs["dense"] == outs["factored"]


class TestBenchmarkModules:
    """Smoke the benchmark harness entry points at minimal sizes."""

    def test_fig2_overhead(self):
        from benchmarks import fig2_overhead

        r = fig2_overhead.run(steps=2)
        assert r["train_step_s"] > 0 and r["admm_step_s"] > 0

    def test_table10_freq_trend(self):
        from benchmarks import table10_freq

        rows = table10_freq.run(steps=8, ks=(2, 8))
        by_k = {r["K"]: r for r in rows}
        # more frequent ADMM (smaller K) tracks better: lower recon error
        assert by_k[2]["final_recon"] <= by_k[8]["final_recon"] * 1.5

    def test_roofline_loader(self):
        from benchmarks import roofline

        recs = roofline.load_records()  # may be empty before the sweep
        assert isinstance(recs, list)
