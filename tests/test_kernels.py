"""Per-kernel shape/dtype sweeps, assert_allclose against ref.py oracles.

Every Pallas kernel runs in interpret=True (Python-on-CPU execution of the
kernel body) against the pure-jnp oracle.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bsr_matmul import bsr_from_dense, bsr_to_dense

I = dict(interpret=True)


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(atol=2e-3, rtol=2e-3), jnp.bfloat16: dict(atol=1e-1, rtol=1e-1)}


class TestSoftThresholdKernel:
    @pytest.mark.parametrize("shape", [(128, 128), (300, 170), (64, 513), (1, 7)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("tau", [0.0, 0.3, 5.0])
    def test_sweep(self, shape, dtype, tau):
        x = rnd(0, shape, dtype)
        got = ops.soft_threshold(x, tau, **I)
        want = ref.soft_threshold_ref(x, tau)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32), **TOL[dtype]
        )


class TestLowrankMatmulKernel:
    @pytest.mark.parametrize(
        "t,k,r,m", [(128, 128, 16, 128), (200, 320, 24, 260), (64, 512, 8, 96), (13, 40, 4, 17)]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, t, k, r, m, dtype):
        x, p, vt = rnd(0, (t, k), dtype), rnd(1, (k, r), dtype), rnd(2, (r, m), dtype)
        got = ops.lowrank_matmul(x, p, vt, bm=64, bk=128, bn=128, **I)
        want = ref.lowrank_matmul_ref(x, p, vt)
        scale = max(float(jnp.abs(want.astype(jnp.float32)).max()), 1.0)
        np.testing.assert_allclose(
            np.asarray(got, np.float32) / scale,
            np.asarray(want, np.float32) / scale,
            **TOL[dtype],
        )

    def test_zero_rank_edge(self):
        x, p, vt = rnd(0, (32, 64), jnp.float32), jnp.zeros((64, 8)), jnp.zeros((8, 32))
        got = ops.lowrank_matmul(x, p, vt, **I)
        np.testing.assert_array_equal(got, jnp.zeros((32, 32)))


class TestBsrMatmulKernel:
    @pytest.mark.parametrize("bs", [32, 64, 128])
    @pytest.mark.parametrize("occupancy", [0.0, 0.1, 0.5, 1.0])
    def test_occupancy_sweep(self, bs, occupancy):
        key = jax.random.PRNGKey(0)
        n, m = 4 * bs, 3 * bs
        mask = jax.random.uniform(key, (n // bs, m // bs)) < occupancy
        dense = rnd(1, (n, m), jnp.float32) * jnp.repeat(jnp.repeat(mask, bs, 0), bs, 1)
        bsr = bsr_from_dense(np.asarray(dense), bs)
        assert bsr.occupancy == pytest.approx(float(mask.mean()), abs=1e-6)
        np.testing.assert_allclose(bsr_to_dense(bsr), dense, atol=1e-6)
        x = rnd(2, (100, n), jnp.float32)
        got = ops.bsr_matmul(x, bsr, bt=64, **I)
        want = ref.bsr_matmul_ref(x, bsr)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)

    def test_bf16(self):
        bs = 32
        n, m = 2 * bs, 2 * bs
        dense = rnd(1, (n, m), jnp.bfloat16)
        bsr = bsr_from_dense(np.asarray(dense.astype(jnp.float32)).astype(np.float32), bs)
        x = rnd(2, (64, n), jnp.float32)
        got = ops.bsr_matmul(x, bsr, **I)
        want = ref.bsr_matmul_ref(x, bsr)
        np.testing.assert_allclose(got, want, atol=1e-1, rtol=1e-1)

    def test_padded_partial_blocks(self):
        """Shapes not divisible by block_size zero-pad the trailing blocks
        (this used to be a bare assert) — masked parity vs the dense matmul."""
        bs = 32
        n, m = 72, 100  # neither divides 32
        rng = np.random.RandomState(1)
        dense = (rng.randn(n, m) * (rng.rand(n, m) < 0.15)).astype(np.float32)
        bsr = bsr_from_dense(dense, bs)
        assert bsr.shape == (n, m) and bsr.padded_shape == (96, 128)
        np.testing.assert_allclose(bsr_to_dense(bsr), dense, atol=1e-6)
        x = rnd(2, (17, n), jnp.float32)
        got = ops.bsr_matmul(x, bsr, **I)
        assert got.shape == (17, m)
        np.testing.assert_allclose(got, np.asarray(x) @ dense, atol=2e-3, rtol=2e-3)

    def test_empty_matrix_fast_path(self):
        """All-zero S: static ``empty`` flag set, matmul returns exact zeros
        without burning the MAXB >= 1 padding slot."""
        bsr = bsr_from_dense(np.zeros((64, 64), np.float32), 32)
        assert bsr.empty and bsr.occupancy == 0.0
        x = rnd(0, (8, 64), jnp.float32)
        np.testing.assert_array_equal(ops.bsr_matmul(x, bsr, **I), np.zeros((8, 64)))

    def test_ragged_rows(self):
        """Non-uniform blocks per column exercise the scalar-prefetch path."""
        bs = 32
        dense = np.zeros((4 * bs, 4 * bs), np.float32)
        rng = np.random.RandomState(0)
        dense[0 * bs : 1 * bs, 0 * bs : 1 * bs] = rng.randn(bs, bs)
        dense[2 * bs : 3 * bs, 0 * bs : 1 * bs] = rng.randn(bs, bs)
        dense[3 * bs : 4 * bs, 0 * bs : 1 * bs] = rng.randn(bs, bs)
        dense[1 * bs : 2 * bs, 3 * bs : 4 * bs] = rng.randn(bs, bs)
        bsr = bsr_from_dense(dense, bs)
        assert np.asarray(bsr.counts).tolist() == [3, 0, 0, 1]
        x = rnd(3, (48, 4 * bs), jnp.float32)
        got = ops.bsr_matmul(x, bsr, bt=48, **I)
        np.testing.assert_allclose(got, x @ dense, atol=2e-3, rtol=2e-3)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "b,hq,hkv,t,s,d",
        [
            (1, 2, 2, 128, 128, 64),   # MHA
            (2, 4, 2, 128, 128, 32),   # GQA group 2
            (1, 8, 1, 64, 64, 32),     # MQA
            (1, 2, 2, 256, 256, 64),   # longer
        ],
    )
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep(self, b, hq, hkv, t, s, d, causal):
        q = rnd(0, (b, hq, t, d), jnp.float32) * 0.5
        k = rnd(1, (b, hkv, s, d), jnp.float32) * 0.5
        v = rnd(2, (b, hkv, s, d), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64, **I)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)

    def test_bf16(self):
        q = rnd(0, (1, 2, 128, 32), jnp.bfloat16) * 0.5
        k = rnd(1, (1, 2, 128, 32), jnp.bfloat16) * 0.5
        v = rnd(2, (1, 2, 128, 32), jnp.bfloat16)
        got = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64, **I)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=5e-2, rtol=5e-2
        )

    def test_block_size_invariance(self):
        """Result must not depend on the tiling."""
        q = rnd(0, (1, 2, 256, 32), jnp.float32) * 0.3
        k = rnd(1, (1, 2, 256, 32), jnp.float32) * 0.3
        v = rnd(2, (1, 2, 256, 32), jnp.float32)
        a = ops.flash_attention(q, k, v, bq=256, bk=256, **I)
        b = ops.flash_attention(q, k, v, bq=64, bk=128, **I)
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


class TestPageCopyKernel:
    """Batched KV page copy (copy-on-write device half of prefix sharing)."""

    @pytest.mark.parametrize(
        "l,p,h,bs,d,dtype",
        [
            (2, 8, 2, 16, 32, jnp.float32),    # fp payload pool
            (2, 8, 2, 16, 32, jnp.int8),       # int8 payload pool
            (2, 8, 2, 16, 1, jnp.float32),     # int8 scale pool shape
        ],
    )
    def test_copies_match_ref(self, l, p, h, bs, d, dtype):
        pool = rnd(0, (l, p, h, bs, d), jnp.float32)
        if dtype == jnp.int8:
            pool = (pool * 10).astype(jnp.int8)
        src = jnp.array([0, 3, 5], jnp.int32)
        dst = jnp.array([1, 6, 7], jnp.int32)
        got = ops.page_copy(pool.astype(dtype), src, dst, **I)
        want = ref.page_copy_ref(pool.astype(dtype), src, dst)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_untouched_pages_preserved(self):
        pool = rnd(1, (1, 8, 2, 16, 32), jnp.float32)
        got = ops.page_copy(pool, jnp.array([2], jnp.int32),
                            jnp.array([5], jnp.int32), **I)
        keep = [i for i in range(8) if i != 5]
        np.testing.assert_array_equal(
            np.asarray(got[:, keep]), np.asarray(pool[:, keep])
        )
        np.testing.assert_array_equal(
            np.asarray(got[:, 5]), np.asarray(pool[:, 2])
        )

    def test_identity_padding_is_noop(self):
        """The engine pads CoW batches to a power of two with (0, 0) pairs;
        src == dst entries must leave the pool bitwise unchanged."""
        pool = rnd(2, (2, 8, 2, 16, 32), jnp.float32)
        src = jnp.array([3, 0, 0, 0], jnp.int32)
        dst = jnp.array([4, 0, 0, 0], jnp.int32)
        got = ops.page_copy(pool, src, dst, **I)
        want = ref.page_copy_ref(pool, jnp.array([3]), jnp.array([4]))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
