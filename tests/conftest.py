"""Suite-wide fixtures and process setup.

The tier-1 suite runs ~500 compile-heavy tests in ONE process; XLA's CPU
backend JITs every engine program it meets along the way. Two pieces of
setup keep that sustainable:

* the stack rlimit is raised up front — LLVM compilation recurses deeply
  and the 8 MB default soft limit leaves little headroom late in the run
  (the main-thread stack grows on demand up to the soft limit, so raising
  it here is enough),
* ``jax.clear_caches()`` runs between test modules, releasing executables
  cached for functions the finished module will never call again.
"""
import gc
import resource

import jax
import pytest


def _raise_stack_limit():
    soft, hard = resource.getrlimit(resource.RLIMIT_STACK)
    want = 512 * 1024 * 1024
    if soft != resource.RLIM_INFINITY and soft < want:
        if hard == resource.RLIM_INFINITY or hard >= want:
            try:
                resource.setrlimit(resource.RLIMIT_STACK, (want, hard))
            except (ValueError, OSError):
                pass


_raise_stack_limit()


@pytest.fixture(scope="module", autouse=True)
def _drop_stale_jit_caches():
    """Free executables compiled by previous modules before this one runs."""
    gc.collect()
    jax.clear_caches()
    yield
