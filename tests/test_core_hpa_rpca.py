"""Tests for HPA (deploy-time truncation) and the RPCA baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import sparse
from repro.core.admm import SalaadConfig, admm_update, init_slr_state
from repro.core.hpa import _split_budget, hpa_compress, hpa_keep_ratio, removable_params
from repro.core.prox import density, effective_rank_ratio
from repro.core.rpca import rpca
from repro.core.selection import SelectionConfig


def make_slr_matrix(key, n, m, rank, dens, noise=0.0):
    ku, kv, ks, kn = jax.random.split(key, 4)
    u = jax.random.normal(ku, (n, rank)) / np.sqrt(rank)
    v = jax.random.normal(kv, (rank, m))
    s = jnp.where(jax.random.uniform(ks, (n, m)) < dens, 2.0, 0.0)
    x = u @ v + s
    if noise:
        x = x + noise * jax.random.normal(kn, (n, m))
    return x


@pytest.fixture(scope="module")
def trained_state():
    key = jax.random.PRNGKey(0)
    params = {
        "embed": {"embedding": make_slr_matrix(jax.random.fold_in(key, 0), 64, 48, 4, 0.05)},
        "layers": {
            "proj": jnp.stack(
                [make_slr_matrix(jax.random.fold_in(key, i + 1), 48, 64, 3 + i, 0.04) for i in range(3)]
            )
        },
    }
    cfg = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=10.0, exact_svd=True
    )
    state, blocks = init_slr_state(params, cfg)
    for step in range(6):
        state, _ = admm_update(params, state, blocks, cfg, step)
    return params, state, blocks


class TestBudgetSplit:
    def test_basic(self):
        phi_l, phi_s = _split_budget(100, 0.5, 1000, 1000)
        assert phi_l == pytest.approx(0.05)
        assert phi_s == pytest.approx(0.05)

    def test_surplus_reassignment_l(self):
        # kappa*C exceeds C_L -> surplus flows to S (footnote 3)
        phi_l, phi_s = _split_budget(100, 0.9, 50, 1000)
        assert phi_l == 1.0
        assert phi_s == pytest.approx((100 - 50) / 1000)

    def test_surplus_reassignment_s(self):
        phi_l, phi_s = _split_budget(100, 0.1, 1000, 50)
        assert phi_s == 1.0
        assert phi_l == pytest.approx((100 - 50) / 1000)

    def test_infeasible_raises(self):
        with pytest.raises(ValueError):
            _split_budget(300, 0.5, 100, 100)

    @given(
        st.integers(0, 200),
        st.floats(0.0, 1.0),
        st.integers(1, 500),
        st.integers(1, 500),
    )
    @settings(max_examples=60, deadline=None)
    def test_budget_conserved(self, c, kappa, c_l, c_s):
        """Property: phi_L*C_L + phi_S*C_S == min(C, C_L + C_S) always."""
        c = min(c, c_l + c_s)
        phi_l, phi_s = _split_budget(c, kappa, c_l, c_s)
        assert phi_l * c_l + phi_s * c_s == pytest.approx(c, abs=1e-6)
        assert 0 <= phi_l <= 1 and 0 <= phi_s <= 1


class TestHPA:
    def test_budget_met_approximately(self, trained_state):
        params, state, blocks = trained_state
        c_l, c_s = removable_params(state, blocks)
        total = c_l + c_s
        new_state, report = hpa_compress(state, blocks, total // 3, kappa=0.6)
        # ceil/floor granularity: within one rank-unit per block of target
        max_unit = max(b.n + b.m for b in blocks) * sum(b.num_blocks for b in blocks)
        assert abs(report["removed"] - total // 3) <= max_unit

    def test_proportional_across_blocks(self, trained_state):
        """Remark 4.2: relative rank differences between blocks are preserved."""
        params, state, blocks = trained_state
        ranks_before = {
            info.name: np.asarray(jnp.sum(state[info.name].s_vals > 0, axis=-1), float)
            for info in blocks
        }
        c_l, c_s = removable_params(state, blocks)
        new_state, report = hpa_compress(state, blocks, (c_l + c_s) // 4, kappa=1.0)
        for info in blocks:
            rb = ranks_before[info.name]
            ra = np.asarray(jnp.sum(new_state[info.name].s_vals > 0, axis=-1), float)
            # keep fraction is ceil((1-phi)*r)/r for every slice: same phi
            expected = np.ceil((1 - report["phi_L"]) * rb)
            np.testing.assert_array_equal(ra, expected)

    def test_keeps_largest_magnitudes(self, trained_state):
        params, state, blocks = trained_state
        name = blocks[0].name
        before = state[name]
        c_l, c_s = removable_params(state, blocks)
        new_state, _ = hpa_compress(state, blocks, (c_l + c_s) // 2, kappa=0.0)
        after = new_state[name]
        # every surviving sparse magnitude >= every removed one (per slice)
        bvals, avals = np.abs(np.asarray(before.s_coo.values)), np.abs(np.asarray(after.s_coo.values))
        alive = np.asarray(after.s_coo.idx) >= 0
        was_alive = np.asarray(before.s_coo.idx) >= 0
        removed = was_alive & ~alive
        if removed.any() and alive.any():
            assert avals[alive].min() >= bvals[removed].max() - 1e-9

    def test_kappa_zero_touches_only_sparse(self, trained_state):
        params, state, blocks = trained_state
        c_l, c_s = removable_params(state, blocks)
        budget = min(c_s, (c_l + c_s) // 8)
        new_state, report = hpa_compress(state, blocks, budget, kappa=0.0)
        assert report["phi_L"] == 0.0
        for info in blocks:
            np.testing.assert_array_equal(
                np.asarray(state[info.name].s_vals), np.asarray(new_state[info.name].s_vals)
            )

    def test_keep_ratio_wrapper(self, trained_state):
        params, state, blocks = trained_state
        new_state, report = hpa_keep_ratio(state, blocks, keep_ratio=0.5, kappa=0.7)
        assert report["params_after"] <= 0.55 * report["params_before"]

    def test_full_budget_empties_everything(self, trained_state):
        params, state, blocks = trained_state
        c_l, c_s = removable_params(state, blocks)
        new_state, _ = hpa_compress(state, blocks, c_l + c_s, kappa=0.5)
        c_l2, c_s2 = removable_params(new_state, blocks)
        assert c_s2 == 0
        # L keeps at most ceil(0)=0 per slice... ceil((1-1)*r)=0
        assert c_l2 == 0


class TestRPCA:
    def test_exact_recovery_synthetic(self):
        """Classic RPCA guarantee: exact-ish recovery of low-rank + sparse."""
        key = jax.random.PRNGKey(0)
        u = jax.random.normal(key, (80, 4)) / 2
        v = jax.random.normal(jax.random.fold_in(key, 1), (4, 80)) / 2
        l_true = u @ v
        s_mask = jax.random.uniform(jax.random.fold_in(key, 2), (80, 80)) < 0.05
        s_true = jnp.where(s_mask, 5.0, 0.0)
        x = l_true + s_true
        l, s, hist = rpca(x, n_iter=60)
        assert float(hist[-1]) < 1e-5
        np.testing.assert_allclose(l, l_true, atol=0.05)
        np.testing.assert_allclose(s, s_true, atol=0.05)

    def test_weak_structure_on_random(self):
        """App. A reproduction in miniature: a generic (standard-trained-like)
        random matrix does NOT decompose into strong SLR structure."""
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
        l, s, _ = rpca(x, n_iter=50)
        rr = float(effective_rank_ratio(l))
        dens = float(density(s, eps=1e-6))
        # weak: either rank stays high or sparse part stays dense
        assert rr > 0.3 or dens > 0.3

    def test_residual_decreases(self):
        x = make_slr_matrix(jax.random.PRNGKey(2), 48, 48, 3, 0.05, noise=0.01)
        _, _, hist = rpca(x, n_iter=40)
        h = np.asarray(hist)
        assert h[-1] < h[0]
