"""Minimal stand-in for the ``hypothesis`` API surface the test suite uses.

The tier-1 suite must run green on a bare container (no pip installs), so the
property tests fall back to this shim when ``hypothesis`` is absent:

  * ``strategies.floats(lo, hi)`` / ``strategies.integers(lo, hi)`` — bounded
    samplers that always include both endpoints;
  * ``given(*strategies)`` — runs the test body over a deterministic grid of
    examples (endpoints first, then seeded uniform draws);
  * ``settings(...)`` — honours ``max_examples``, ignores the rest.

With real hypothesis installed (see requirements-dev.txt) the tests import it
instead and get full shrinking/fuzzing behaviour.
"""
from __future__ import annotations

import functools
import inspect
import itertools

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20
_SETTINGS_ATTR = "_shim_max_examples"


class _Strategy:
    def __init__(self, lo, hi, cast):
        self.lo, self.hi, self.cast = lo, hi, cast

    def examples(self, n: int, rng: np.random.RandomState):
        out = [self.cast(self.lo), self.cast(self.hi)]
        while len(out) < n:
            out.append(self.cast(self.lo + (self.hi - self.lo) * rng.random_sample()))
        return out[:n]


class strategies:  # noqa: N801 — mirrors `from hypothesis import strategies as st`
    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(float(min_value), float(max_value), float)

    @staticmethod
    def integers(min_value=0, max_value=1, **_kw):
        return _Strategy(int(min_value), int(max_value), lambda v: int(round(v)))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
    def deco(fn):
        setattr(fn, _SETTINGS_ATTR, max_examples)
        return fn

    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, _SETTINGS_ATTR, _DEFAULT_MAX_EXAMPLES)
            # cap the grid: endpoints cross-product would explode for many args
            rng = np.random.RandomState(0)
            columns = [s.examples(n, rng) for s in strats]
            corner = list(itertools.islice(
                itertools.product(*[(s.cast(s.lo), s.cast(s.hi)) for s in strats]), n
            ))
            rows = corner + list(zip(*columns))
            seen = set()
            for row in rows[:max(n, len(corner))]:
                if row in seen:
                    continue
                seen.add(row)
                fn(*args, *row, **kwargs)

        # pytest must not see the strategy-filled parameters as fixtures:
        # expose a signature with only the leading (non-strategy) params.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[: -len(strats)] if strats else []
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        return wrapper

    return deco
