"""Model-internals tests: flash custom VJP, balanced-causal scheme, chunked
SSD vs naive recurrence, blockwise attention, RoPE, norms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.ref import attention_ref
from repro.models.attention import blockwise_attention
from repro.models.flash_balanced import balanced_causal_fwd
from repro.models.flash_vjp import flash_attention_jax
from repro.models.layers import apply_rope, nonparam_layernorm, rmsnorm
from repro.models.ssm import ssd_chunked


def rnd(seed, shape, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


class TestBlockwiseAttention:
    @pytest.mark.parametrize("t,s,causal", [(96, 96, True), (64, 128, False), (100, 100, True)])
    def test_matches_dense(self, t, s, causal):
        q, k, v = rnd(0, (2, 4, t, 32)), rnd(1, (2, 2, s, 32)), rnd(2, (2, 2, s, 32), 1.0)
        got = blockwise_attention(q, k, v, causal=causal, q_block=32, kv_block=32)
        np.testing.assert_allclose(
            got, attention_ref(q, k, v, causal=causal), atol=2e-3, rtol=2e-3
        )


class TestFlashVJP:
    def test_forward_matches_dense(self):
        q, k, v = rnd(0, (2, 4, 96, 32)), rnd(1, (2, 2, 96, 32)), rnd(2, (2, 2, 96, 32), 1.0)
        got = flash_attention_jax(q, k, v, True, 32, 32, 0)
        np.testing.assert_allclose(
            got, attention_ref(q, k, v, causal=True), atol=2e-3, rtol=2e-3
        )

    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("scheme", ["full", "balanced"])
    def test_gradients_match_dense(self, causal, scheme):
        q, k, v = rnd(0, (1, 4, 64, 16)), rnd(1, (1, 2, 64, 16)), rnd(2, (1, 2, 64, 16), 1.0)

        def f(q, k, v):
            return jnp.sum(jnp.sin(flash_attention_jax(q, k, v, causal, 32, 32, 0, scheme)))

        def g(q, k, v):
            return jnp.sum(jnp.sin(attention_ref(q, k, v, causal=causal)))

        g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-3)

    def test_no_quadratic_residuals(self):
        """The VJP must not save (T, S)-shaped tensors: check the jaxpr of the
        fwd pass residuals stay O(T)."""
        q, k, v = rnd(0, (1, 2, 256, 16)), rnd(1, (1, 2, 256, 16)), rnd(2, (1, 2, 256, 16))
        _, vjp = jax.vjp(lambda *a: flash_attention_jax(*a, True, 64, 64, 0), q, k, v)
        max_elems = max(
            int(np.prod(x.shape)) for x in jax.tree.leaves(vjp) if hasattr(x, "shape")
        )
        # largest residual should be O(T*D)-ish, far below T*S = 65536*heads
        assert max_elems <= 256 * 16 * 2 * 2  # (B*H*T*D)


class TestBalancedScheme:
    @pytest.mark.parametrize("t,bq", [(128, 32), (96, 32), (160, 32), (64, 64)])
    def test_matches_dense(self, t, bq):
        q, k, v = rnd(0, (2, 4, t, 32)), rnd(1, (2, 2, t, 32)), rnd(2, (2, 2, t, 32), 1.0)
        out, lse = balanced_causal_fwd(q, k, v, q_block=bq)
        np.testing.assert_allclose(
            out, attention_ref(q, k, v, causal=True), atol=2e-3, rtol=2e-3
        )

    def test_lse_matches_full_scheme(self):
        from repro.models.flash_vjp import _fwd_impl

        q, k, v = rnd(0, (1, 2, 128, 16)), rnd(1, (1, 2, 128, 16)), rnd(2, (1, 2, 128, 16))
        _, lse_full = _fwd_impl(q, k, v, True, 32, 32, 0, "full")
        _, lse_bal = _fwd_impl(q, k, v, True, 32, 32, 0, "balanced")
        np.testing.assert_allclose(lse_full, lse_bal, atol=1e-4, rtol=1e-4)


class TestSSD:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_matches_recurrence(self, chunk):
        B, L, H, P, N = 2, 64, 3, 8, 16
        x = rnd(0, (B, L, H, P))
        dt = jax.nn.softplus(rnd(1, (B, L, H), 1.0))
        a = -jnp.exp(rnd(2, (H,), 0.3))
        b_in, c_in = rnd(3, (B, L, N)), rnd(4, (B, L, N))

        s = np.zeros((B, H, P, N))
        ys = []
        for t in range(L):
            da = np.exp(np.asarray(dt[:, t]) * np.asarray(a)[None])
            upd = np.einsum(
                "bh,bhp,bn->bhpn", np.asarray(dt[:, t]), np.asarray(x[:, t]), np.asarray(b_in[:, t])
            )
            s = s * da[:, :, None, None] + upd
            ys.append(np.einsum("bhpn,bn->bhp", s, np.asarray(c_in[:, t])))
        y_ref = np.stack(ys, 1)

        y, s_final = ssd_chunked(x, dt, a, b_in, c_in, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s_final), s, atol=1e-4, rtol=1e-3)

    def test_init_state_continuation(self):
        """ssd(x, init_state) == ssd over the concatenated sequence (chunked
        prefill correctness for the SSM serving path)."""
        B, L, H, P, N = 1, 32, 2, 4, 8
        x = rnd(0, (B, 2 * L, H, P))
        dt = jax.nn.softplus(rnd(1, (B, 2 * L, H), 1.0))
        a = -jnp.exp(rnd(2, (H,), 0.3))
        b_in, c_in = rnd(3, (B, 2 * L, N)), rnd(4, (B, 2 * L, N))
        y_full, s_full = ssd_chunked(x, dt, a, b_in, c_in, chunk=8)
        y1, s1 = ssd_chunked(x[:, :L], dt[:, :L], a, b_in[:, :L], c_in[:, :L], chunk=8)
        y2, s2 = ssd_chunked(
            x[:, L:], dt[:, L:], a, b_in[:, L:], c_in[:, L:], chunk=8, init_state=s1
        )
        np.testing.assert_allclose(y2, y_full[:, L:], atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(s2, s_full, atol=1e-4, rtol=1e-3)


class TestLayers:
    def test_rope_preserves_norm(self):
        x = rnd(0, (2, 8, 4, 32), 1.0)
        pos = jnp.arange(8)[None, :]
        y = apply_rope(x, pos)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
        )

    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = rnd(0, (1, 1, 1, 16), 1.0)
        k = rnd(1, (1, 1, 1, 16), 1.0)

        def dot_at(i, j):
            qi = apply_rope(jnp.broadcast_to(q, (1, 1, 1, 16)), jnp.array([[i]]))
            kj = apply_rope(jnp.broadcast_to(k, (1, 1, 1, 16)), jnp.array([[j]]))
            return float(jnp.sum(qi * kj))

        assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)

    @given(st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_nonparam_ln_standardizes(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 3 + 1
        y = nonparam_layernorm(x)
        np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0, atol=1e-4)
        np.testing.assert_allclose(np.std(np.asarray(y), -1), 1, atol=1e-2)

    def test_rmsnorm_scale_zero_is_identity_gain(self):
        x = rnd(0, (2, 16), 1.0)
        y = rmsnorm(x, jnp.zeros(16))
        rms = jnp.sqrt(jnp.mean(x * x, -1, keepdims=True))
        np.testing.assert_allclose(y, x / rms, rtol=1e-5)
