"""Unit + property tests for proximal operators and structural statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.prox import (
    density,
    effective_rank_ratio,
    effective_rank_ratio_from_singular_values,
    soft_threshold,
    svt,
)

jax.config.update("jax_enable_x64", False)


class TestSoftThreshold:
    def test_zero_tau_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (17, 9))
        np.testing.assert_allclose(soft_threshold(x, 0.0), x)

    def test_known_values(self):
        x = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
        np.testing.assert_allclose(
            soft_threshold(x, 1.0), jnp.array([-1.0, 0.0, 0.0, 0.0, 1.0])
        )

    @given(st.floats(0.0, 5.0), st.integers(0, 1000))
    @settings(max_examples=25, deadline=None)
    def test_prox_property(self, tau, seed):
        """soft_threshold(z, tau) minimizes tau|s|_1 + 1/2 (s-z)^2 element-wise:
        check optimality vs random perturbations (prox property)."""
        z = jax.random.normal(jax.random.PRNGKey(seed), (32,))
        s = soft_threshold(z, tau)
        obj = lambda v: tau * jnp.sum(jnp.abs(v)) + 0.5 * jnp.sum((v - z) ** 2)
        base = obj(s)
        for pseed in range(3):
            pert = 0.1 * jax.random.normal(jax.random.PRNGKey(1000 + pseed), (32,))
            assert obj(s + pert) >= base - 1e-5

    def test_shrinkage_never_crosses_zero(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (100,))
        s = soft_threshold(x, 0.3)
        assert jnp.all(s * x >= 0)
        assert jnp.all(jnp.abs(s) <= jnp.abs(x))


class TestSVT:
    def test_zero_tau_reconstructs(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (24, 16))
        _, l = svt(x, 0.0)
        np.testing.assert_allclose(l, x, atol=1e-4)

    def test_large_tau_zeroes(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (24, 16))
        s_thr, l = svt(x, 1e6)
        assert jnp.all(s_thr == 0)
        np.testing.assert_allclose(l, jnp.zeros_like(x), atol=1e-6)

    def test_rank_reduction(self):
        key = jax.random.PRNGKey(1)
        u = jax.random.normal(key, (40, 3))
        v = jax.random.normal(jax.random.fold_in(key, 1), (3, 30))
        x = u @ v + 0.01 * jax.random.normal(jax.random.fold_in(key, 2), (40, 30))
        s_full = jnp.linalg.svd(x, compute_uv=False)
        tau = float(s_full[3]) * 1.5  # kill the noise floor
        s_thr, l = svt(x, tau)
        assert int(jnp.sum(s_thr > 0)) == 3

    def test_singular_values_match_matrix(self):
        x = jax.random.normal(jax.random.PRNGKey(7), (20, 20))
        s_thr, l = svt(x, 0.5)
        s_of_l = jnp.linalg.svd(l, compute_uv=False)
        np.testing.assert_allclose(
            np.sort(np.asarray(s_of_l))[::-1], np.sort(np.asarray(s_thr))[::-1], atol=1e-4
        )


class TestEffectiveRank:
    def test_identity_full_rank(self):
        # identity: all singular values equal -> need ceil(gamma*n) of them
        r = effective_rank_ratio(jnp.eye(10), gamma=0.999)
        assert float(r) == 1.0

    def test_rank_one(self):
        x = jnp.outer(jnp.ones(10), jnp.ones(8))
        r = effective_rank_ratio(x, gamma=0.999)
        assert float(r) == pytest.approx(1 / 8)

    def test_zero_matrix(self):
        assert float(effective_rank_ratio(jnp.zeros((5, 5)))) == 0.0

    def test_denom_override(self):
        s = jnp.array([10.0, 0.0, 0.0])
        r = effective_rank_ratio_from_singular_values(s, denom=100)
        assert float(r) == pytest.approx(0.01)

    @given(st.integers(1, 12), st.integers(0, 100))
    @settings(max_examples=20, deadline=None)
    def test_monotone_in_rank(self, rank, seed):
        """A matrix built from `rank` strong directions has eff-rank >= rank
        coverage at gamma<=(rank/(rank+eps)) and exactly counts them when the
        spectrum is flat."""
        s = jnp.concatenate([jnp.ones(rank), jnp.zeros(16 - rank)])
        r = effective_rank_ratio_from_singular_values(s, gamma=0.999)
        assert float(r) == pytest.approx(rank / 16)

    def test_batched(self):
        s = jnp.stack([jnp.array([1.0, 1.0, 0.0, 0.0]), jnp.array([1.0, 0.0, 0.0, 0.0])])
        r = effective_rank_ratio_from_singular_values(s)
        np.testing.assert_allclose(r, [0.5, 0.25])


class TestDensity:
    def test_half(self):
        x = jnp.array([[1.0, 0.0], [0.0, 2.0]])
        assert float(density(x)) == 0.5

    def test_zero(self):
        assert float(density(jnp.zeros((4, 4)))) == 0.0
