"""Tests for the prefetch pipeline and int8 KV-cache quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data.pipeline import DevicePrefetcher, Prefetcher
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.models import model as model_lib
from repro.serving.kv_quant import (
    append_token,
    cache_bytes,
    dequantize_cache,
    quantize_cache,
    quantize_kv,
)


class TestPrefetcher:
    def test_stream_matches_direct(self):
        src = SyntheticC4(DataConfig(100, 8, 2))
        pf = Prefetcher(src, depth=2)
        try:
            for step in range(4):
                np.testing.assert_array_equal(
                    pf.batch(step)["tokens"], src.batch(step)["tokens"]
                )
        finally:
            pf.close()

    def test_device_prefetcher_places_arrays(self):
        src = SyntheticC4(DataConfig(100, 8, 2))
        pf = DevicePrefetcher(src, depth=1)
        try:
            b = pf.batch(0)
            assert isinstance(jax.tree.leaves(b)[0], jax.Array)
        finally:
            pf.close()

    def test_trainer_runs_on_prefetcher(self):
        from repro.optim.adam import AdamConfig
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = get_arch("salaad_llama_60m").reduced()
        tr = Trainer(cfg, TrainerConfig(total_steps=3, salaad=None, adam=AdamConfig(lr=1e-3)))
        state = tr.init(jax.random.PRNGKey(0))
        pf = Prefetcher(SyntheticC4(DataConfig(cfg.vocab_size, 16, 4)))
        try:
            state = tr.fit(state, pf, steps=3)
            assert int(state.step) == 3
        finally:
            pf.close()


class TestKVQuant:
    def test_roundtrip_error_bound(self):
        k = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 2, 16, 32))
        q, s = quantize_kv(k)
        back = (q.astype(jnp.float32) * s)
        err = jnp.abs(back - k)
        assert float(err.max()) <= float(s.max()) * 0.5 + 1e-6

    def test_cache_roundtrip_and_bytes(self):
        cfg = get_arch("olmo_1b").reduced()
        cache = model_lib.init_cache(cfg, 2, 16, dtype=jnp.float32)
        cache = cache._replace(
            k=jax.random.normal(jax.random.PRNGKey(1), cache.k.shape),
            v=jax.random.normal(jax.random.PRNGKey(2), cache.v.shape),
        )
        qc = quantize_cache(cache)
        back = dequantize_cache(qc, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(back.k), np.asarray(cache.k), atol=2e-2)
        # payload: int8 + per-token f32 scale vs f32 dense ~= 3.5x smaller
        assert cache_bytes(qc) < 0.45 * cache_bytes(cache)

    def test_append_is_history_exact(self):
        """Appending tokens never perturbs already-stored entries."""
        cfg = get_arch("olmo_1b").reduced()
        cache = model_lib.init_cache(cfg, 1, 8, dtype=jnp.float32)
        qc = quantize_cache(cache)
        layers, b, h, _, d = cache.k.shape
        k1 = jax.random.normal(jax.random.PRNGKey(3), (layers, b, h, 1, d))
        v1 = jax.random.normal(jax.random.PRNGKey(4), (layers, b, h, 1, d))
        qc = append_token(qc, k1, v1)
        snap = np.asarray(qc.k_q[:, :, :, 0])
        k2 = jax.random.normal(jax.random.PRNGKey(5), (layers, b, h, 1, d)) * 100
        qc = append_token(qc, k2, v1)
        np.testing.assert_array_equal(np.asarray(qc.k_q[:, :, :, 0]), snap)
        assert int(qc.length) == 2

    def test_decode_quality_with_quantized_cache(self):
        """Greedy decode with an int8 cache matches the fp32-cache decode on
        a trained-at-init model (logit perturbation << logit gaps)."""
        cfg = get_arch("olmo_1b").reduced()
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        prompt = [3, 1, 4, 1, 5]
        # fp32 path
        cache = model_lib.init_cache(cfg, 1, 16, dtype=jnp.float32)
        for t in prompt:
            lg, cache = model_lib.decode_step(params, jnp.asarray([[t]], jnp.int32), cache, cfg)
        ref = np.asarray(lg[0, -1])
        # int8 path: quantize the filled cache, dequantize per step
        cache2 = model_lib.init_cache(cfg, 1, 16, dtype=jnp.float32)
        for t in prompt[:-1]:
            lg2, cache2 = model_lib.decode_step(params, jnp.asarray([[t]], jnp.int32), cache2, cfg)
        qc = quantize_cache(cache2)
        deq = dequantize_cache(qc, dtype=jnp.float32)
        lg2, _ = model_lib.decode_step(params, jnp.asarray([[prompt[-1]]], jnp.int32), deq, cfg)
        got = np.asarray(lg2[0, -1])
        assert np.argmax(got) == np.argmax(ref)
        np.testing.assert_allclose(got, ref, atol=0.1)
