"""Serving-telemetry tests: the observability PR's invariants.

The load-bearing claim is ZERO COST ON THE DEVICE PATH: greedy token
streams must be bitwise identical with telemetry (and tracing) on vs off,
across every engine configuration — paged, chunked prefill, speculative,
elastic pressure tiers, prefix cache. The rest covers the registry
primitives (counters / gauges / histograms and their pre-bound fast
paths), the Chrome-trace export schema, the Prometheus text exposition +
HTTP endpoint, exactly-once token accounting through eviction/resume
(satellite: the accounting audit), centralized provenance key identity
across engines, and the retrace detector.

Each (config, telemetry on/off) engine is built and driven EXACTLY ONCE
through the module-scoped ``driven`` fixture and every test reads from
that one run — engines jit their own programs, and pointless re-compiles
are what pushes a long single-core suite run over the edge.
"""
import json
import urllib.request

import jax
import pytest

from repro.configs.base import get_arch
from repro.models import model as model_lib
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineConfig,
    PagedServingEngine,
    ReferenceEngine,
    ServingEngine,
)
from repro.serving.speculative import SpeculativeEngine
from repro.serving.telemetry import (
    EngineTelemetry,
    MetricsRegistry,
    NullTelemetry,
    engine_provenance,
    request_itls,
    request_ttft,
    start_metrics_server,
    validate_prometheus_text,
)
from repro.serving.trace import validate_chrome_trace


@pytest.fixture(scope="module")
def tiny():
    cfg = get_arch("salaad_llama_60m").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(vocab: int, n: int = 6):
    """Shared prefix + unique tails: exercises the radix cache when on."""
    shared = [(7 * i + 3) % (vocab - 2) + 1 for i in range(12)]
    return [shared + [(i * 5 + j) % (vocab - 2) + 1 for j in range(2 + i % 3)]
            for i in range(n)]


# engine-builder per configuration: (engine class, bank tiers, ecfg kwargs)
_BASE = dict(max_slots=2, max_len=48, block_size=8, num_blocks=24)
CONFIGS = {
    "paged": (PagedServingEngine, 1, dict(_BASE)),
    "chunked_prefill": (PagedServingEngine, 1,
                        dict(_BASE, prefill_chunk=8)),
    "speculative": (SpeculativeEngine, 1, dict(_BASE, spec_k=2)),
    "elastic_pressure": (PagedServingEngine, 2,
                         dict(_BASE, num_blocks=16, tier_policy="pressure")),
    "prefix_cache": (PagedServingEngine, 1,
                     dict(_BASE, prefill_chunk=8, prefix_cache=True)),
    # starved page pool + long generations: forces eviction + resume for
    # the exactly-once accounting audit (same jitted shapes as "paged")
    "paged_tight": (PagedServingEngine, 1,
                    dict(_BASE, num_blocks=6)),
}
_MAX_NEW = {"paged_tight": 16}


def _build(tiny, name: str, telemetry: bool):
    cfg, params = tiny
    cls, tiers, kw = CONFIGS[name]
    keeps = [1.0, 0.5][:tiers] if tiers > 1 else None
    bank = ModelBank(cfg, [params] * tiers, keeps=keeps)
    return cls(bank, EngineConfig(telemetry=telemetry, **kw))


@pytest.fixture(scope="module")
def driven(tiny):
    """Memoized (config, telemetry) -> (engine, streams, done): every engine
    is constructed, traced (when instrumented), and driven over the shared
    prompt trace ONCE; tests read the run instead of re-jitting engines."""
    cfg, _ = tiny
    cache = {}

    def get(name: str, telemetry: bool):
        key = (name, telemetry)
        if key not in cache:
            eng = _build(tiny, name, telemetry)
            if telemetry:
                eng.start_trace()
            prompts = _prompts(cfg.vocab_size)
            for p in prompts:
                eng.submit(list(p),
                           max_new_tokens=_MAX_NEW.get(name, 6))
            done = eng.run()
            assert len(done) == len(prompts)
            streams = [r.out_tokens
                       for r in sorted(done, key=lambda r: r.uid)]
            cache[key] = (eng, streams, done)
        return cache[key]

    return get


# ------------------------------------------------- bitwise on/off identity ---


class TestBitwiseInvariance:
    """Telemetry and tracing are host-side observers: turning them on must
    not change a single emitted token, in any engine configuration."""

    @pytest.mark.parametrize("name", sorted(CONFIGS))
    def test_streams_identical_on_off(self, driven, name):
        eng_off, s_off, _ = driven(name, False)
        eng_on, s_on, _ = driven(name, True)
        assert s_off == s_on
        assert isinstance(eng_off.metrics, NullTelemetry)
        assert not isinstance(eng_on.metrics, NullTelemetry)
        # the instrumented run actually recorded something
        tel = eng_on.metrics
        assert tel.counter_value(tel.tokens, "emitted") == \
            sum(len(s) for s in s_on)

    def test_null_telemetry_records_nothing(self, driven):
        eng, streams, _ = driven("paged", False)
        tel = eng.metrics
        assert tel.enabled is False
        assert sum(len(s) for s in streams) > 0
        assert tel.counter_value(tel.tokens, "emitted") == 0
        assert tel.ttft.count(tel.engine) == 0
        tel.snapshot()                    # still callable, reads empty


# ------------------------------------------------------- registry internals ---


class TestRegistryPrimitives:
    def test_counter_monotone_and_incrementer(self):
        r = MetricsRegistry()
        c = r.counter("t_total", "x", ("engine",))
        c.inc(2, "E")
        inc = c.incrementer("E")
        inc()
        inc(3)
        assert c.value("E") == 6
        with pytest.raises(ValueError):
            c.inc(-1, "E")

    def test_histogram_exact_percentiles(self):
        r = MetricsRegistry()
        h = r.histogram("h_seconds", "x", ("engine",))
        for v in (0.010, 0.020, 0.030, 0.040):
            h.observe(v, "E")
        assert h.count("E") == 4
        assert h.sum_("E") == pytest.approx(0.100)
        assert h.percentile(0, "E") == pytest.approx(0.010)
        assert h.percentile(100, "E") == pytest.approx(0.040)

    def test_histogram_reset_keeps_bound_observers_live(self):
        """reset() zeroes IN PLACE so engines' pre-bound observer closures
        (the per-token fast path) survive a benchmark's warmup reset."""
        r = MetricsRegistry()
        h = r.histogram("h_seconds", "x", ("engine",))
        obs = h.observer("E")
        obs(0.5)
        assert h.count("E") == 1
        h.reset()
        assert h.count("E") == 0
        obs(0.25)                          # the old handle must still land
        assert h.count("E") == 1
        assert h.percentile(50, "E") == pytest.approx(0.25)

    def test_gauge_setter(self):
        r = MetricsRegistry()
        g = r.gauge("g", "x", ("engine",))
        set_ = g.setter("E")
        set_(3)
        assert g.value("E") == 3.0

    def test_duplicate_declaration_rejected(self):
        r = MetricsRegistry()
        r.counter("c_total", "x", ("engine",))
        with pytest.raises(ValueError):
            r.gauge("c_total", "x", ("engine",))


# ---------------------------------------------------------- retrace detector ---


class TestRetraceDetector:
    def test_first_compile_then_steady_then_retrace(self):
        tel = EngineTelemetry("T")
        n = {"traces": 0}

        def bump():
            with tel.measure_program("p", 0, traces=lambda: n["traces"]):
                n["traces"] += 1

        def steady():
            with tel.measure_program("p", 0, traces=lambda: n["traces"]):
                pass

        bump()                             # first use: compile, NOT a retrace
        assert tel.counter_value(tel.jit_compiles, "p", "0") == 1
        assert tel.retraces() == 0
        steady()                           # warm call
        assert tel.retraces() == 0
        bump()                             # seen program compiles again
        assert tel.counter_value(tel.jit_retraces, "p", "0") == 1
        assert tel.retraces() == 1

    def test_tiers_tracked_independently(self):
        tel = EngineTelemetry("T")
        n = {"traces": 0}
        for tier in (0, 1):
            with tel.measure_program("p", tier, traces=lambda: n["traces"]):
                n["traces"] += 1
        assert tel.retraces() == 0         # tier 1's first compile is not a
        #                                    retrace of tier 0's program

    def test_engine_steady_state_has_no_retraces(self, tiny, driven):
        cfg, _ = tiny
        eng, _, _ = driven("chunked_prefill", True)
        # a SECOND full drive on the warm engine: every program re-runs,
        # nothing may recompile
        for p in _prompts(cfg.vocab_size):
            eng.submit(list(p), max_new_tokens=6)
        eng.run()
        snap = eng.stats_snapshot()
        assert snap["jit_retraces"] == 0
        assert snap["steps"] > 0


# ------------------------------------------------- exactly-once accounting ---


class TestTokenAccounting:
    """Satellite: each emitted token is counted exactly once — eviction,
    resume re-prefill, and prefix-cache hits must not double-count."""

    def _audit(self, eng, done):
        tel = eng.metrics
        emitted = sum(len(r.out_tokens) for r in done)
        assert tel.counter_value(tel.tokens, "emitted") == emitted
        for r in done:
            assert len(r.token_times) == len(r.out_tokens)
        # TTFT + ITLs partition the token timeline per request
        assert tel.ttft.count(tel.engine) == len(done)
        assert tel.itl.count(tel.engine) == emitted - len(done)

    def test_eviction_resume_counts_once(self, driven):
        # paged_tight starves the pool: admission pressure forces eviction
        eng, _, done = driven("paged_tight", True)
        self._audit(eng, done)
        tel = eng.metrics
        assert eng.evictions > 0
        # resumed work re-prefills, but re-prefill is compute accounting —
        # the emitted count stays exactly-once
        assert tel.counter_value(tel.tokens, "reprefill") > 0
        assert tel.counter_value(tel.tokens, "prefill_compute") >= \
            tel.counter_value(tel.tokens, "reprefill")

    def test_prefix_hits_split_from_compute(self, tiny, driven):
        cfg, _ = tiny
        eng, _, done = driven("prefix_cache", True)
        self._audit(eng, done)
        tel = eng.metrics
        assert eng.prefix_hits > 0
        hit = tel.counter_value(tel.tokens, "prefix_hit")
        compute = tel.counter_value(tel.tokens, "prefill_compute")
        total_prompt = sum(len(p) for p in _prompts(cfg.vocab_size))
        assert hit > 0
        # every prompt token is either prefix-hit or prefilled, never both
        assert hit + compute == total_prompt

    def test_latency_helpers_are_canonical(self, driven):
        _, _, done = driven("paged", True)
        for r in done:
            assert request_ttft(r) >= 0
            gaps = request_itls(r)
            assert len(gaps) == len(r.out_tokens) - 1
            assert all(g >= 0 for g in gaps)


# ----------------------------------------------------------- chrome traces ---


class TestChromeTrace:
    def test_roundtrip_schema(self, driven, tmp_path):
        eng, _, _ = driven("chunked_prefill", True)
        path = tmp_path / "trace.json"
        eng.tracer.save_chrome(path)
        doc = json.loads(path.read_text())
        rep = validate_chrome_trace(doc)
        assert rep["events"] > 0
        assert rep["tracks"] >= 2          # slot tracks + program tracks
        for ev in doc["traceEvents"]:
            assert ev["ph"] in ("X", "i", "M")
            assert "pid" in ev and "tid" in ev and "name" in ev
            if ev["ph"] == "X":
                assert ev["dur"] >= 0 and ev["ts"] >= 0

    def test_jsonl_event_log(self, driven, tmp_path):
        eng, _, _ = driven("paged", True)
        path = tmp_path / "events.jsonl"
        eng.tracer.save_jsonl(path)
        lines = path.read_text().splitlines()
        assert lines
        for ln in lines:
            ev = json.loads(ln)
            assert "name" in ev and "kind" in ev

    def test_validator_rejects_unbalanced(self):
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": [
                {"ph": "B", "ts": 0, "pid": 1, "tid": 1, "name": "x"},
            ]})


# ------------------------------------------------------------- prometheus ---


class TestPrometheus:
    def test_text_exposition_valid(self, driven):
        eng, _, _ = driven("paged", True)
        text = eng.metrics.registry.prometheus_text()
        rep = validate_prometheus_text(text)
        assert rep["families"] > 10
        assert "serve_tokens_total" in text
        assert "serve_ttft_seconds_bucket" in text

    def test_http_endpoint(self, driven):
        eng, _, _ = driven("paged", True)
        server = start_metrics_server(eng.metrics.registry, port=0)
        try:
            port = server.server_address[1]
            with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics"
            ) as resp:
                assert resp.status == 200
                body = resp.read().decode()
            validate_prometheus_text(body)
            assert "serve_tokens_total" in body
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(f"http://127.0.0.1:{port}/nope")
        finally:
            server.shutdown()


# -------------------------------------------------------------- provenance ---


class TestProvenance:
    """Satellite: BENCH payload provenance is generated centrally, so every
    engine's payload carries IDENTICAL keys."""

    def test_keys_identical_across_engines(self, tiny):
        cfg, params = tiny
        bank = ModelBank.single(cfg, params)
        # construction only — provenance never runs the model, so these
        # engines jit nothing
        engines = [
            ServingEngine(bank, EngineConfig(max_slots=1, max_len=16)),
            PagedServingEngine(bank, EngineConfig(max_slots=1, max_len=16,
                                                  block_size=8)),
            ReferenceEngine(bank, EngineConfig(max_slots=1, max_len=16)),
            SpeculativeEngine(bank, EngineConfig(max_slots=1, max_len=16,
                                                 block_size=8, spec_k=2)),
        ]
        provs = [engine_provenance(e) for e in engines]
        keysets = [frozenset(p) for p in provs]
        assert len(set(keysets)) == 1, keysets
        cfg_keys = [frozenset(p["config"]) for p in provs]
        assert len(set(cfg_keys)) == 1
        for p in provs:
            json.dumps(p)                  # serializable by contract

    def test_stats_snapshot_schema(self, driven):
        eng, _, _ = driven("paged", True)
        snap = eng.stats_snapshot()
        for key in ("engine", "steps", "jit_retraces", "metrics"):
            assert key in snap, key
        assert snap["engine"] == "PagedServingEngine"
        assert "serve_tokens_total" in snap["metrics"]
