"""Multi-tenant adapter serving tests (PR 6 tentpole).

The core invariants: (1) a slot served through an :class:`AdapterBank` with a
single registered adapter emits greedy token streams bitwise-identical to the
same model served as a plain ``ModelBank`` tier — across deployment formats,
int8 KV pages, and chunked prefill; (2) one engine batches slots running
DIFFERENT adapters in one decode tick, and every stream is token-identical to
a single-tenant run of that adapter; (3) adapter switches are pure data
rebinds — the pool swap is an ``.at[].set`` into frozen shapes and ``sel`` is
a data leaf, so ``serve_jit_retraces_total`` stays 0 across switches; (4) LRU
residency under a tight ``max_resident_adapters`` never evicts a pinned
(streaming) adapter, and unregistering an adapter with live slots is
rejected; (5) KV allocator and prefix-cache accounting are unchanged by
adapter switching (pages never cross adapters).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, admm_update, init_slr_state
from repro.core.selection import SelectionConfig
from repro.models import model as model_lib
from repro.serving.adapters import (
    AdapterBank,
    AdapterError,
    AdapterRegistry,
    adapterize,
)
from repro.serving.deployed import DeployedModel
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineCapabilityError,
    EngineConfig,
    PagedServingEngine,
    ReferenceEngine,
    RequestRejected,
    ServingEngine,
)
from repro.serving.speculative import SpeculativeEngine

PROMPTS = [[5, 7, 11, 2], [3, 1, 9], [8, 8, 2, 6, 4], [1, 2]]
ASSIGN = [0, 1, 2, 1]  # slot -> adapter for the mixed-batch runs


@pytest.fixture(scope="module")
def trained():
    cfg = get_arch("olmo_1b").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=5.0, exact_svd=True
    )
    states = []
    for seed in range(3):
        state, blocks = init_slr_state(params, scfg)
        for step in range(2 + seed):
            state, _ = admm_update(params, state, blocks, scfg, step)
        states.append((state, blocks))
    return cfg, params, states


@pytest.fixture(scope="module")
def deployed(trained):
    """fmt -> (base DeployedModel, [3 adapterized DeployedModels])."""
    cfg, params, states = trained
    out = {}
    for fmt in ("fused", "factored", "dense"):
        models = [
            DeployedModel.build(cfg, params, st, blocks, fmt=fmt, bsr_block=32)
            for st, blocks in states
        ]
        base = models[0]
        out[fmt] = (base, [adapterize(base, m) for m in models])
    return out


def run_multi(engine, prompts=PROMPTS, assign=ASSIGN, max_new=6):
    outs = {}
    for p, aid in zip(prompts, assign):
        outs[engine.submit(p, max_new_tokens=max_new, adapter=aid)] = None
    for r in engine.run():
        outs[r.uid] = list(r.out_tokens)
    return [outs[u] for u in sorted(outs)]


def run_single(cls, cfg, model, prompts, max_new=6, **kw):
    eng = cls(ModelBank.single(cfg, model), EngineConfig(**kw))
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    byuid = {r.uid: list(r.out_tokens) for r in eng.run()}
    return [byuid[u] for u in uids]


# ------------------------------------------------------------------ parity ---


class TestSingleTenantParity:
    """AdapterBank with ONE registered adapter == plain ModelBank tier."""

    @pytest.mark.parametrize("fmt", ["fused", "factored", "dense"])
    def test_bitwise_vs_modelbank_tier(self, trained, deployed, fmt):
        cfg, _, _ = trained
        base, adapters = deployed[fmt]
        bank = AdapterBank(base, [adapters[1]])
        ekw = dict(max_slots=2, max_len=32, block_size=8)
        eng = PagedServingEngine(bank, EngineConfig(adapters=True, **ekw))
        got = run_multi(eng, PROMPTS[:2], [0, 0])
        want = run_single(PagedServingEngine, cfg, adapters[1],
                          PROMPTS[:2], **ekw)
        assert got == want

    def test_parity_int8_kv(self, trained, deployed):
        cfg, _, _ = trained
        base, adapters = deployed["fused"]
        ekw = dict(max_slots=2, max_len=32, block_size=8, kv_dtype="int8")
        eng = PagedServingEngine(AdapterBank(base, [adapters[2]]),
                                 EngineConfig(adapters=True, **ekw))
        got = run_multi(eng, PROMPTS[:2], [0, 0])
        assert got == run_single(PagedServingEngine, cfg, adapters[2],
                                 PROMPTS[:2], **ekw)

    def test_parity_chunked_prefill(self, trained, deployed):
        cfg, _, _ = trained
        base, adapters = deployed["fused"]
        prompts = [list(range(1, 20)), list(range(3, 15))]
        ekw = dict(max_slots=2, max_len=64, block_size=8, prefill_chunk=8,
                   prefix_cache=True)
        eng = PagedServingEngine(AdapterBank(base, [adapters[1]]),
                                 EngineConfig(adapters=True, **ekw))
        got = run_multi(eng, prompts, [0, 0])
        assert got == run_single(PagedServingEngine, cfg, adapters[1],
                                 prompts, **ekw)

    def test_slot_padded_engine_parity(self, trained, deployed):
        cfg, _, _ = trained
        base, adapters = deployed["fused"]
        eng = ServingEngine(AdapterBank(base, [adapters[1]]),
                            EngineConfig(adapters=True, max_slots=2,
                                         max_len=32))
        got = run_multi(eng, PROMPTS[:2], [0, 0])
        assert got == run_single(ServingEngine, cfg, adapters[1],
                                 PROMPTS[:2], max_slots=2, max_len=32)


class TestMixedAdapters:
    """One decode tick batches slots running different adapters."""

    @pytest.mark.parametrize("fmt,mode", [("fused", "batched"),
                                          ("factored", "grouped")])
    def test_mixed_streams_match_single_tenant(self, trained, deployed,
                                               fmt, mode):
        cfg, _, _ = trained
        base, adapters = deployed[fmt]
        bank = AdapterBank(base, adapters)
        assert bank.mode == mode
        ekw = dict(max_slots=4, max_len=32, block_size=8)
        eng = PagedServingEngine(bank, EngineConfig(adapters=True, **ekw))
        got = run_multi(eng)
        for aid in set(ASSIGN):
            prompts = [p for p, a in zip(PROMPTS, ASSIGN) if a == aid]
            mine = [g for g, a in zip(got, ASSIGN) if a == aid]
            assert mine == run_single(PagedServingEngine, cfg, adapters[aid],
                                      prompts, **ekw)

    def test_eight_adapters_one_engine(self, trained, deployed):
        """Acceptance: >= 8 registered adapters served concurrently, each
        stream matching its adapter's single-tenant run."""
        cfg, _, _ = trained
        base, adapters = deployed["fused"]
        bank = AdapterBank(base)
        aids = [bank.register(adapters[i % 3], name=f"tenant{i}")
                for i in range(8)]
        assert len(bank.registry.ids) == 8
        prompts = [[i + 1, 2 * i + 3, 7] for i in range(8)]
        ekw = dict(max_slots=8, max_len=32, block_size=8)
        eng = PagedServingEngine(bank, EngineConfig(adapters=True, **ekw))
        got = run_multi(eng, prompts, aids)
        for i, aid in enumerate(aids):
            want = run_single(PagedServingEngine, cfg, adapters[i % 3],
                              [prompts[i]], **ekw)
            assert [got[i]] == want

    def test_zero_retraces_across_switches(self, trained, deployed):
        """Steady state: a second mixed wave with a different slot->adapter
        assignment compiles nothing new."""
        _, _, _ = trained
        base, adapters = deployed["fused"]
        eng = PagedServingEngine(
            AdapterBank(base, adapters),
            EngineConfig(adapters=True, max_slots=4, max_len=32,
                         block_size=8))
        run_multi(eng)
        before = eng.metrics.retraces()
        run_multi(eng, PROMPTS, [2, 0, 1, 0])   # shuffled assignment
        assert eng.metrics.retraces() == before == 0


# --------------------------------------------------------------- residency ---


class TestResidency:
    def test_lru_swap_under_tight_capacity(self, deployed):
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters, max_resident=2)
        bank.materialize()
        assert bank.capacity == 2 and bank.resident == [0, 1]
        row, swapped = bank.acquire(2)
        assert swapped and bank.swaps == 1
        assert 2 in bank.resident and row is not None
        # LRU: adapter 0 (least recently acquired) was the victim
        assert 0 not in bank.resident

    def test_pinned_adapter_never_evicted(self, deployed):
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters, max_resident=2)
        bank.materialize()
        bank.pin(0)
        bank.acquire(2)                      # must evict 1, not pinned 0
        assert 0 in bank.resident and 1 not in bank.resident
        bank.pin(2)
        row, swapped = bank.acquire(1)       # every row pinned: defer
        assert row is None and not swapped
        bank.unpin(0)
        row, swapped = bank.acquire(1)
        assert row is not None and swapped

    def test_engine_serves_more_adapters_than_rows(self, trained, deployed):
        """max_resident_adapters=2 with 3 adapters in flight: the engine
        defers the overflow request and still finishes everything."""
        cfg, _, _ = trained
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters)
        ekw = dict(max_slots=2, max_len=32, block_size=8)
        eng = PagedServingEngine(
            bank, EngineConfig(adapters=True, max_resident_adapters=2, **ekw))
        got = run_multi(eng, PROMPTS[:3], [0, 1, 2])
        assert bank.swaps >= 1
        for i, aid in enumerate((0, 1, 2)):
            assert [got[i]] == run_single(PagedServingEngine, cfg,
                                          adapters[aid], [PROMPTS[i]], **ekw)

    def test_unregister_while_streaming_rejected(self, deployed):
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters)
        eng = PagedServingEngine(
            bank, EngineConfig(adapters=True, max_slots=2, max_len=32,
                               block_size=8))
        eng.submit([1, 2, 3], max_new_tokens=8, adapter=1)
        eng.step()                           # admits: adapter 1 now pinned
        with pytest.raises(AdapterError, match="streaming"):
            bank.unregister(1)
        eng.run()                            # drain: slot released, unpinned
        bank.unregister(1)
        assert 1 not in bank.registry

    def test_unknown_adapter_rejected_after_unregister(self, deployed):
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters)
        eng = PagedServingEngine(
            bank, EngineConfig(adapters=True, max_slots=2, max_len=32,
                               block_size=8))
        bank.unregister(2)
        with pytest.raises(RequestRejected):
            eng.submit([1, 2], max_new_tokens=2, adapter=2)

    def test_allocator_accounting_unchanged_by_switches(self, deployed):
        """Adapter switching moves no KV: after draining a mixed wave every
        page is back (modulo pages retained by the per-adapter prefix
        caches, which remain reclaimable)."""
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters)
        eng = PagedServingEngine(
            bank, EngineConfig(adapters=True, max_slots=4, max_len=32,
                               block_size=8, prefix_cache=True))
        total = eng.allocator.free_blocks
        run_multi(eng)
        cached = sum(pc.pages for pc in eng._all_prefixes())
        assert eng.allocator.free_blocks + cached == total
        assert sum(pc.reclaimable_pages for pc in eng._all_prefixes()) \
            == cached
        # prefix caches are PER ADAPTER: every adapter that streamed has its
        # own cache, so one tenant's pages can never serve another's prompt
        assert set(eng._prefix_caches) == set(ASSIGN)
        run_multi(eng)   # second wave reuses/republishes, still balanced
        cached = sum(pc.pages for pc in eng._all_prefixes())
        assert eng.allocator.free_blocks + cached == total


# -------------------------------------------------------------- validation ---


class TestValidation:
    def test_registry_rejects_bsr(self, trained):
        cfg, params, states = trained
        st, blocks = states[0]
        bsr = DeployedModel.build(cfg, params, st, blocks, fmt="bsr",
                                  bsr_block=32)
        with pytest.raises(AdapterError, match="bsr"):
            AdapterRegistry(bsr)

    def test_config_validation(self):
        with pytest.raises(ValueError):
            EngineConfig(max_resident_adapters=4)          # needs adapters
        with pytest.raises(ValueError):
            EngineConfig(adapters=True, max_resident_adapters=0)

    def test_bank_and_flag_must_agree(self, trained, deployed):
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters)
        with pytest.raises(ValueError):
            PagedServingEngine(bank, EngineConfig(max_slots=2, max_len=32,
                                                  block_size=8))
        cfg, params, _ = trained
        with pytest.raises(ValueError):
            ServingEngine(ModelBank.single(cfg, params),
                          EngineConfig(adapters=True, max_slots=2,
                                       max_len=32))

    def test_adapter_kwarg_without_bank_rejected(self, trained):
        cfg, params, _ = trained
        eng = ServingEngine(ModelBank.single(cfg, params),
                            EngineConfig(max_slots=2, max_len=32))
        with pytest.raises(RequestRejected):
            eng.submit([1, 2], max_new_tokens=2, adapter=0)

    def test_reference_engine_has_no_adapters(self, trained):
        cfg, params, _ = trained
        eng = ReferenceEngine(ModelBank.single(cfg, params),
                              EngineConfig(max_slots=1, max_len=16))
        with pytest.raises(RequestRejected):
            eng.submit([1, 2], max_new_tokens=2, adapter=0)
        assert not ReferenceEngine.capabilities()["features"][
            "multi_tenant_adapters"]

    def test_speculative_engine_rejects_bank(self, deployed):
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters)
        with pytest.raises(EngineCapabilityError):
            SpeculativeEngine(bank, EngineConfig(
                adapters=True, max_slots=1, max_len=16, block_size=8,
                spec_k=2))

    def test_adapter_telemetry_counters(self, deployed):
        base, adapters = deployed["fused"]
        bank = AdapterBank(base, adapters, max_resident=2)
        eng = PagedServingEngine(
            bank, EngineConfig(adapters=True, max_resident_adapters=2,
                               max_slots=2, max_len=32, block_size=8,
                               telemetry=True))
        run_multi(eng, PROMPTS[:3], [0, 1, 2])
        assert int(eng.metrics.adapter_swaps.total()) == bank.swaps >= 1
        assert int(eng.metrics.adapter_tokens.total()) == 18  # 3 x 6 tokens
