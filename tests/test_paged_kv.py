"""Paged KV cache + continuous batching tests.

Covers the PR 2 tentpole invariants: block-allocator accounting, paged
decode attention matching the contiguous cache bitwise, the Pallas paged
kernel matching its jnp oracle, and the paged engine producing IDENTICAL
token streams to the slot-padded engine on a fixed trace — including under
mid-stream admission, forced eviction, and int8 page quantization.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.kernels.ops import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import model as model_lib
from repro.models import transformer as transformer_lib
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    BlockAllocator,
    EngineConfig,
    PagedServingEngine,
    ReferenceEngine,
    RequestRejected,
    ServingEngine,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_arch("salaad_llama_60m").reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestBlockAllocator:
    def test_alloc_free_roundtrip(self):
        a = BlockAllocator(8)
        pages = a.alloc(5)
        assert len(pages) == 5 and len(set(pages)) == 5
        assert a.free_blocks == 3 and a.used_blocks == 5
        a.free(pages[:2])
        assert a.free_blocks == 5 and a.used_blocks == 3
        a.free(pages[2:])
        assert a.free_blocks == 8 and a.used_blocks == 0

    def test_no_partial_grants_and_no_double_alloc(self):
        a = BlockAllocator(4)
        p1 = a.alloc(3)
        assert a.alloc(2) is None          # only 1 free: refuse, don't shrink
        assert a.free_blocks == 1
        p2 = a.alloc(1)
        assert set(p1).isdisjoint(p2)      # a page is never handed out twice
        assert a.alloc(1) is None

    def test_double_free_rejected(self):
        a = BlockAllocator(4)
        pages = a.alloc(2)
        a.free(pages)
        with pytest.raises(ValueError):
            a.free(pages)

    def test_bad_free_is_atomic(self):
        """A free list containing an unowned page must raise WITHOUT freeing
        anything: the old page-by-page loop raised mid-way, leaving
        free + used != pool for callers that caught the error."""
        a = BlockAllocator(8)
        pages = a.alloc(4)
        with pytest.raises(ValueError):
            a.free([pages[0], pages[1], 99])      # 99 was never allocated
        # nothing was freed: the invariant AND the exact ownership survive
        assert a.free_blocks + a.used_blocks == 8
        assert a.used_blocks == 4
        with pytest.raises(ValueError):
            a.free([pages[0], pages[0]])          # duplicate within one call
        assert a.used_blocks == 4
        a.free(pages)                             # the good free still works
        assert a.free_blocks == 8 and a.used_blocks == 0
        assert a.alloc(8) is not None

    def test_interchangeable_pages_no_fragmentation(self):
        """Freeing ANY n pages lets ANY n-page request through: pool capacity
        is the only constraint (no contiguity, no external fragmentation)."""
        a = BlockAllocator(6)
        held = [a.alloc(2) for _ in range(3)]
        a.free(held[0])
        a.free(held[2])                    # non-adjacent frees
        assert a.alloc(4) is not None      # still a single 4-page grant


class TestPagedAttentionKernel:
    def _pool(self, seed=0, b=3, hq=4, hkv=2, d=8, bs=4, nb=4, n=10):
        rng = np.random.RandomState(seed)
        q = jnp.asarray(rng.randn(b, hq, d), jnp.float32)
        kp = jnp.asarray(rng.randn(n, hkv, bs, d), jnp.float32)
        vp = jnp.asarray(rng.randn(n, hkv, bs, d), jnp.float32)
        # ragged per-slot lengths; slot 1 is empty; unmapped tails everywhere
        bt = jnp.asarray([[0, 1, n, n], [2, n, n, n], [3, 4, 5, n]], jnp.int32)
        lengths = jnp.asarray([5, 0, 11], jnp.int32)
        return q, kp, vp, bt, lengths

    def test_pallas_matches_ref(self):
        q, kp, vp, bt, lengths = self._pool()
        out = paged_attention(q, kp, vp, bt, lengths)
        ref = paged_attention_ref(q, kp, vp, bt, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def test_ref_matches_contiguous_masked_attention(self):
        """Gathering pages through the block table reproduces the contiguous
        per-slot decode attention exactly (same values at positions < len)."""
        q, kp, vp, bt, lengths = self._pool()
        n, hkv, bs, d = kp.shape
        b, hq, _ = q.shape
        s = bt.shape[1] * bs
        # materialize the contiguous equivalent: position j <- page[j//bs]
        kc = np.zeros((b, hkv, s, d), np.float32)
        vc = np.zeros((b, hkv, s, d), np.float32)
        btn = np.asarray(bt)
        for bi in range(b):
            for j in range(int(lengths[bi]) + 1):
                pg = btn[bi, j // bs]
                if pg < n:
                    kc[bi, :, j] = np.asarray(kp)[pg, :, j % bs]
                    vc[bi, :, j] = np.asarray(vp)[pg, :, j % bs]
        group = hq // hkv
        qg = np.asarray(q).reshape(b, hkv, group, d) / np.sqrt(d)
        sc = np.einsum("bhgd,bhsd->bhgs", qg, kc)
        mask = np.arange(s)[None, :] <= np.asarray(lengths)[:, None]
        sc = np.where(mask[:, None, None], sc, -1e30)
        w = jax.nn.softmax(jnp.asarray(sc), axis=-1)
        exp = np.einsum("bhgs,bhsd->bhgd", np.asarray(w), vc).reshape(b, hq, d)
        got = np.asarray(paged_attention_ref(q, kp, vp, bt, lengths))
        np.testing.assert_allclose(got, exp, atol=1e-5)


class TestPagedDecodeEquivalence:
    """Paged decode through the REAL model == contiguous-cache decode,
    bitwise, at ragged per-slot lengths."""

    def test_logits_bitwise_equal(self, tiny):
        cfg, params = tiny
        S, max_len, bs = 3, 32, 8
        nb = max_len // bs
        prompts = [[5, 7, 11, 2, 9], [3, 1], [2, 9, 4, 6, 1, 8, 3]]
        bucket = 8
        toks = np.zeros((S, bucket), np.int32)
        lens = np.ones((S,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
            lens[i] = len(p)

        # contiguous per-slot cache via the batched prefill
        cache = model_lib.init_cache(cfg, S, max_len, dtype=jnp.float32)
        _, pc = model_lib.prefill(
            params, {"tokens": jnp.asarray(toks)}, cfg, max_len=max_len,
            cache_dtype=jnp.float32,
        )
        cache = cache._replace(
            k=cache.k.at[:, jnp.arange(S)].set(pc.k),
            v=cache.v.at[:, jnp.arange(S)].set(pc.v),
            length=jnp.asarray(lens),
        )

        # paged cache: same prefill heads scattered into pages
        num_pages = S * nb
        paged = model_lib.init_paged_cache(
            cfg, S, num_pages, bs, nb, dtype=jnp.float32
        )
        _, kvs, _ = model_lib._forward(
            params, {"tokens": jnp.asarray(toks)}, cfg, collect_kv=True
        )
        table = np.full((S, nb), num_pages, np.int32)
        page_map = np.full((S, bucket // bs), num_pages, np.int32)
        nxt = 0
        for i, p in enumerate(prompts):
            need = -(-(len(p) + 4) // bs)          # prompt + decode room
            for j in range(need):
                table[i, j] = nxt
                if j < -(-len(p) // bs):
                    page_map[i, j] = nxt
                nxt += 1
        paged = paged._replace(
            block_table=jnp.asarray(table), length=jnp.asarray(lens)
        )
        paged = transformer_lib.scatter_prefill_pages(
            paged, kvs, jnp.asarray(page_map)
        )

        tok = jnp.asarray([[9], [4], [7]], jnp.int32)
        for _ in range(3):
            lg_c, cache = model_lib.decode_step(params, tok, cache, cfg)
            lg_p, paged = model_lib.decode_step(params, tok, paged, cfg)
            assert np.array_equal(np.asarray(lg_c), np.asarray(lg_p)), (
                "paged decode logits diverged from contiguous"
            )


class TestPagedEngine:
    PROMPTS = [[5, 7, 11], [3, 1], [2, 9, 4, 6], [8, 8, 2], [1, 2, 3, 4, 5, 6], [9, 1]]

    def _tokens(self, engine, max_new=5):
        for p in self.PROMPTS:
            engine.submit(p, max_new_tokens=max_new)
        return {r.uid: r.out_tokens for r in engine.run()}

    def test_matches_unpaged_engine_midstream_admission(self, tiny):
        """6 requests over 2 slots: admissions happen mid-stream while other
        slots are mid-decode; token streams must be identical per uid."""
        cfg, params = tiny
        ref = self._tokens(ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32)))
        got = self._tokens(PagedServingEngine(
            ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32, block_size=8)
        ))
        assert got == ref
        assert all(len(t) == 5 for t in got.values())

    @pytest.mark.parametrize("policy", ["longest_remaining", "lru"])
    def test_eviction_preserves_tokens(self, tiny, policy):
        """A pool too small for two full requests forces eviction; the evicted
        request resumes by re-prefilling and must emit the same tokens."""
        cfg, params = tiny
        prompts = [[5, 7, 11], [3, 1, 4]]
        e_ref = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=16))
        for p in prompts:
            e_ref.submit(p, max_new_tokens=10)
        ref = {r.uid: r.out_tokens for r in e_ref.run()}

        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=16, block_size=4, num_blocks=4,
            decode_reserve=1, evict_policy=policy,
        ))
        for p in prompts:
            eng.submit(p, max_new_tokens=10)
        got = {r.uid: r.out_tokens for r in eng.run()}
        assert eng.evictions >= 1, "pool was sized to force an eviction"
        assert got == ref
        assert eng.allocator.used_blocks == 0   # everything returned

    def test_pages_released_incrementally(self, tiny):
        """Finished requests return pages immediately (not at drain time)."""
        cfg, params = tiny
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=32, block_size=8
        ))
        eng.submit([1, 2, 3], max_new_tokens=4)
        eng.submit([4, 5], max_new_tokens=12)
        seen_free = []
        while eng.has_work:
            eng.step()
            seen_free.append(eng.allocator.free_blocks)
        assert eng.allocator.used_blocks == 0
        # free count must rise strictly before the drain completes
        assert max(seen_free[:-1]) > min(seen_free[:-1])

    def test_rejects_oversized_requests(self, tiny):
        cfg, params = tiny
        for eng in (
            ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=16)),
            PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=16, block_size=8)),
            ReferenceEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=16)),
        ):
            with pytest.raises(RequestRejected):
                eng.submit(list(range(1, 20)), max_new_tokens=4)
            assert not eng.has_work  # rejection leaves the engine clean

    def test_rejects_empty_prompt_and_tiny_pool(self, tiny):
        cfg, params = tiny
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=16, block_size=4, num_blocks=2
        ))
        with pytest.raises(RequestRejected):
            eng.submit([], max_new_tokens=2)
        with pytest.raises(RequestRejected):
            # fits max_len but can never fit the 2-page pool
            eng.submit([1, 2, 3, 4, 5], max_new_tokens=8)
        eng.submit([1, 2, 3], max_new_tokens=4)      # 2 pages: fits
        assert len(eng.run()) == 1

    def test_int8_pages_match_float(self, tiny):
        """kv_dtype='int8' stores quantized pages (serving/kv_quant.py layout)
        and still greedy-decodes the same tokens at init scale."""
        cfg, params = tiny
        ref = self._tokens(PagedServingEngine(
            ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32, block_size=8)
        ))
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=32, block_size=8, kv_dtype="int8"
        ))
        assert eng.cache.k.dtype == jnp.int8 and eng.cache.k_scale is not None
        got = self._tokens(eng)
        assert got == ref

    def test_int8_rejected_by_contiguous_engine(self, tiny):
        cfg, params = tiny
        with pytest.raises(ValueError):
            ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, kv_dtype="int8"))

    def test_pallas_kernel_through_engine(self, tiny):
        """kernel_impl='pallas' routes paged decode through the Pallas kernel
        (interpret mode here) and emits the same tokens as the jnp gather."""
        import dataclasses

        cfg, params = tiny
        out = {}
        for impl in ("dense", "pallas"):
            c = dataclasses.replace(cfg, kernel_impl=impl)
            eng = PagedServingEngine(ModelBank.single(c, params), EngineConfig(
                max_slots=2, max_len=32, block_size=8
            ))
            eng.submit([5, 7, 11], max_new_tokens=4)
            eng.submit([3, 1], max_new_tokens=4)
            out[impl] = {r.uid: r.out_tokens for r in eng.run()}
        assert out["dense"] == out["pallas"]

    def test_one_decode_trace_and_call_per_tick(self, tiny):
        """The paged engine keeps the PR 1 invariant: ONE jitted decode step
        per tick over all slots, compiled exactly once."""
        cfg, params = tiny
        eng = PagedServingEngine(ModelBank.single(cfg, params), EngineConfig(
            max_slots=2, max_len=32, block_size=8
        ))
        got = self._tokens(eng)
        total = sum(len(t) for t in got.values())
        assert eng.decode_traces == 1
        assert eng.decode_calls < total
