"""Tensor-parallel serving tests (PR 9 tentpole): mesh-aware engines.

The core contract: an engine built with ``EngineConfig(mesh="model=N")``
streams greedy tokens IDENTICAL to the single-device engine — across paged /
chunked-prefill / int8-KV / speculative / elastic-pressure / prefix-cached
configs and both kernel implementations — with allclose logits, zero jit
retraces, payload pools sharded over the head axis, and the BlockAllocator /
prefix cache untouched (block tables stay replicated host bookkeeping).

The parity matrix needs real multi-device placement, so those classes skip
unless the process was launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the env var must be
set before the FIRST jax import — a dedicated CI step provides it; under the
plain tier-1 run conftest imports jax first and these skip). Mesh-spec and
EngineConfig validation tests run everywhere.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, admm_update, init_slr_state
from repro.core.selection import SelectionConfig
from repro.models import model as model_lib
from repro.parallel.sharding import ServingMesh, parse_mesh_spec
from repro.serving.elastic import ModelBank
from repro.serving.engine import (
    EngineCapabilityError,
    EngineConfig,
    PagedServingEngine,
    ReferenceEngine,
    ServingEngine,
    _device_put_tiers,
    _kv_pool_device_bytes,
)
from repro.serving.speculative import SpeculativeEngine
from repro.serving.telemetry import engine_provenance

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 set "
           "before the first jax import (see the CI sharded-serving step)",
)

PROMPTS = [[1, 2, 3, 4], [5, 6, 7], [2, 4, 6, 8, 10], [1, 2, 3, 9]]


def drive(engine, tiers=True, max_new=6):
    """Submit the fixed prompt set (alternating tiers) and run to drain."""
    for i, p in enumerate(PROMPTS):
        engine.submit(p, max_new_tokens=max_new,
                      tier=(i % 2) if tiers else None)
    done = engine.run()
    return {tuple(r.prompt): r.out_tokens for r in done}


def ecfg(**kw):
    return EngineConfig(max_slots=4, max_len=32, block_size=8, **kw)


# ------------------------------------------------------- single-device safe --


class TestMeshSpec:
    """parse_mesh_spec + EngineConfig format validation (no devices needed)."""

    def test_defaults_and_forms(self):
        assert parse_mesh_spec("") == {"data": 1, "model": 1}
        assert parse_mesh_spec("model=2") == {"data": 1, "model": 2}
        assert parse_mesh_spec("model=4,data=2") == {"data": 2, "model": 4}
        assert parse_mesh_spec(" data=2 , model=2 ") == {"data": 2, "model": 2}

    @pytest.mark.parametrize("bad", ["tp=2", "model", "model=0", "model=-1",
                                     "model=x", "model:2"])
    def test_bad_specs_name_the_field(self, bad):
        with pytest.raises(ValueError, match="mesh="):
            parse_mesh_spec(bad)

    def test_engine_config_validates_at_construction(self):
        with pytest.raises(ValueError, match="mesh="):
            ecfg(mesh="tp=2")
        with pytest.raises(ValueError, match="mesh="):
            ecfg(mesh=2)  # must be the spec STRING, not an int

    def test_engine_config_mesh_stays_json_safe(self):
        cfg = ecfg(mesh="model=2")
        assert json.loads(json.dumps(dataclasses.asdict(cfg)))["mesh"] == "model=2"

    def test_capabilities_report_tensor_parallel(self):
        for eng in (ServingEngine, PagedServingEngine, SpeculativeEngine):
            assert eng.capabilities()["features"]["tensor_parallel"] is True
        assert ReferenceEngine.capabilities()["features"]["tensor_parallel"] \
            is False


# ------------------------------------------------------------ multi-device --


@pytest.fixture(scope="module")
def trained():
    """Widened reduced arch (4 q + 4 kv heads so model=4 divides) with a
    2-tier factored bank — the shared fixture for the whole parity matrix."""
    cfg = dataclasses.replace(
        get_arch("salaad_llama_60m").reduced(), num_heads=4, num_kv_heads=4
    )
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    scfg = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=5.0, exact_svd=True
    )
    state, blocks = init_slr_state(params, scfg)
    for step in range(3):
        state, _ = admm_update(params, state, blocks, scfg, step)
    bank = ModelBank.build(cfg, params, state, blocks, budgets=(1.0, 0.5),
                           fmt="factored")
    return cfg, params, state, blocks, bank


# engine-config deltas exercised at every mesh size; each routes through a
# different serving subsystem that must inherit TP unchanged
PARITY_CONFIGS = {
    "paged": {},
    "int8_kv": dict(kv_dtype="int8"),
    "chunked_prefill": dict(prefill_chunk=8),
    "prefix_cache": dict(prefix_cache=True),
    "pressure_tiers": dict(tier_policy="pressure", num_blocks=10),
    "speculative": dict(spec_k=3),
}


@needs8
class TestShardedParity:
    @pytest.mark.parametrize("name", sorted(PARITY_CONFIGS))
    def test_greedy_tokens_identical(self, trained, name):
        *_, bank = trained
        kw = PARITY_CONFIGS[name]
        cls = SpeculativeEngine if name == "speculative" else PagedServingEngine
        tiers = name != "speculative"
        base = drive(cls(bank, ecfg(**kw)), tiers)
        for spec in ("model=2", "model=4"):
            eng = cls(bank, ecfg(mesh=spec, **kw))
            assert drive(eng, tiers) == base, (name, spec)
            assert eng.stats_snapshot()["jit_retraces"] == 0, (name, spec)

    def test_data_axis_parity(self, trained):
        """Batch parallelism: the 'data' axis replicates weights and KV
        pools and shards only the in-flight batch, alone or combined with
        'model' — greedy streams stay identical and nothing retraces."""
        *_, bank = trained
        for kw in ({}, dict(prefix_cache=True)):
            base = drive(PagedServingEngine(bank, ecfg(**kw)))
            for spec in ("data=2", "model=2,data=2"):
                eng = PagedServingEngine(bank, ecfg(mesh=spec, **kw))
                assert drive(eng) == base, (kw, spec)
                assert eng.stats_snapshot()["jit_retraces"] == 0, (kw, spec)

    def test_pallas_kernel_paths(self, trained):
        """kernel_impl='pallas' routes decode through the scalar-prefetch
        paged kernel and chunked prefill through the k-wide variant — both
        shard_map-wrapped over the head axis under a mesh."""
        cfg, params, state, blocks, _ = trained
        pcfg = dataclasses.replace(cfg, kernel_impl="pallas")
        bank = ModelBank.build(pcfg, params, state, blocks, budgets=(1.0,),
                               fmt="factored")
        for kw in ({}, dict(prefill_chunk=8)):
            base = drive(PagedServingEngine(bank, ecfg(**kw)), False)
            for spec in ("model=2", "model=4"):
                eng = PagedServingEngine(bank, ecfg(mesh=spec, **kw))
                assert drive(eng, False) == base, (kw, spec)
                assert eng.stats_snapshot()["jit_retraces"] == 0

    def test_slot_padded_engine(self, trained):
        *_, bank = trained
        base = drive(ServingEngine(bank, EngineConfig(max_slots=4, max_len=32)))
        eng = ServingEngine(bank, EngineConfig(max_slots=4, max_len=32,
                                               mesh="model=2"))
        assert drive(eng) == base
        assert eng.stats_snapshot()["jit_retraces"] == 0

    def test_logits_allclose(self, trained):
        """Full-forward oracle: logits under the sharded param placement are
        allclose to single-device (bitwise identity is NOT expected — the
        row-parallel o/down psums reassociate the contraction)."""
        cfg, *_, bank = trained
        tier0 = next(iter(bank)).model
        toks = np.arange(1, 9, dtype=np.int32)[None, :]
        ref = np.asarray(tier0.forward(toks))

        def fwd(p, t):
            return model_lib._forward(p, {"tokens": t}, cfg)[0]

        for spec in ("model=2", "model=4"):
            smesh = ServingMesh.from_spec(spec)
            sparams = _device_put_tiers([tier0.params], smesh)[0]
            with smesh:
                got = np.asarray(jax.jit(fwd)(sparams, toks))
            np.testing.assert_allclose(ref, got, atol=2e-5, rtol=2e-5)


@needs8
class TestShardingInvariants:
    def test_pools_shard_tables_replicate(self, trained):
        *_, bank = trained
        per_dev = {}
        for n in (2, 4):
            eng = PagedServingEngine(bank, ecfg(mesh=f"model={n}"))
            payload_spec = eng.cache.k.sharding.spec
            assert payload_spec == P(None, None, "model", None, None)
            assert eng.cache.block_table.sharding.spec == P()
            drive(eng, tiers=True)  # table commits stay replicated mid-stream
            assert eng.cache.block_table.sharding.spec == P()
            bytes_by_dev = _kv_pool_device_bytes(eng.cache)
            assert len(bytes_by_dev) == n
            assert len(set(bytes_by_dev.values())) == 1  # balanced
            per_dev[n] = next(iter(bytes_by_dev.values()))
        # equal total budget -> per-device residency shrinks with the axis
        assert per_dev[4] * 2 == per_dev[2]

    def test_data_axis_replicates_pools(self, trained):
        """'data' carries the batch only: payload pools stay replicated —
        each data replica holds the FULL pool, so per-device residency is
        2x the model=2 placement (which splits the head axis) and the spec
        carries no mesh axis."""
        *_, bank = trained
        eng = PagedServingEngine(bank, ecfg(mesh="data=2"))
        assert eng.cache.k.sharding.spec == P()
        drive(eng, tiers=True)
        data_bytes = _kv_pool_device_bytes(eng.cache)
        assert len(data_bytes) == 2
        assert len(set(data_bytes.values())) == 1
        model_bytes = _kv_pool_device_bytes(
            PagedServingEngine(bank, ecfg(mesh="model=2")).cache)
        assert next(iter(data_bytes.values())) \
            == 2 * next(iter(model_bytes.values()))

    def test_allocator_and_prefix_cache_unchanged(self, trained):
        """Block accounting and radix-cache hits are pure host bookkeeping:
        identical whether or not the payload pools are sharded."""
        *_, bank = trained
        shared = list(range(1, 17))  # two full pages at block_size=8

        def hits_and_free(mesh):
            eng = PagedServingEngine(bank, ecfg(mesh=mesh, prefix_cache=True))
            for _ in range(2):  # second round re-walks the published prefix
                eng.submit(shared + [21], max_new_tokens=4, tier=0)
                eng.submit(shared + [22], max_new_tokens=4, tier=0)
                eng.run()
            return eng.prefix_hits, eng.allocator.free_blocks

        assert hits_and_free("model=2") == hits_and_free(None)

    def test_provenance_and_gauge(self, trained):
        *_, bank = trained
        eng = PagedServingEngine(bank, ecfg(mesh="model=2,data=2"))
        prov = engine_provenance(eng)
        assert prov["mesh"] == {
            "axis_names": ["data", "model"],
            "shape": {"data": 2, "model": 2},
            "num_devices": 4,
        }
        gauge = prov["telemetry"]["serve_kv_pool_device_bytes"]
        assert len(gauge) == 4 and all(v > 0 for v in gauge.values())
        flat = PagedServingEngine(bank, ecfg())
        assert engine_provenance(flat)["mesh"] is None


@needs8
class TestMeshValidation:
    """Device-dependent EngineConfig/engine checks (format-only validation is
    in TestMeshSpec above)."""

    def test_model_axis_must_divide_heads(self, trained):
        *_, bank = trained  # 4 heads: model=8 cannot split them
        with pytest.raises(ValueError, match="must divide num_heads=4"):
            PagedServingEngine(bank, ecfg(mesh="model=8"))

    def test_mesh_larger_than_device_count(self, trained):
        *_, bank = trained
        with pytest.raises(ValueError, match="exceeds the 8 available"):
            PagedServingEngine(bank, ecfg(mesh="model=4,data=4"))

    def test_bsr_formats_rejected(self, trained):
        cfg, params, state, blocks, _ = trained
        bank = ModelBank.build(cfg, params, state, blocks, budgets=(1.0,),
                               fmt="bsr", bsr_block=32)
        with pytest.raises(ValueError, match="'bsr'"):
            PagedServingEngine(bank, ecfg(mesh="model=2"))
        # unsharded bsr serving is untouched
        assert drive(PagedServingEngine(bank, ecfg()), False)

    def test_reference_engine_rejects_mesh(self, trained):
        *_, bank = trained
        with pytest.raises(EngineCapabilityError, match="mesh="):
            ReferenceEngine(bank, ecfg=EngineConfig(max_slots=1,
                                                    mesh="model=2"))
