"""Runtime tests: data pipeline, checkpointing, fault tolerance, trainer,
gradient compression, serving engine."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.models import model as model_lib
from repro.optim.adam import AdamConfig, AdamState, adam_update, init_adam
from repro.optim.schedule import warmup_cosine
from repro.parallel.compression import (
    compressed_psum_tree,
    dequantize_int8,
    quantize_int8,
)
from repro.train import checkpoint
from repro.train.fault import RetryPolicy, StragglerDetector, Watchdog
from repro.train.trainer import Trainer, TrainerConfig


class TestData:
    def test_deterministic(self):
        d = SyntheticC4(DataConfig(vocab_size=100, seq_len=16, global_batch=4))
        b1, b2 = d.batch(7), d.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        d = SyntheticC4(DataConfig(vocab_size=100, seq_len=16, global_batch=4))
        assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])

    def test_labels_are_shifted_tokens(self):
        d = SyntheticC4(DataConfig(vocab_size=100, seq_len=16, global_batch=2))
        b = d.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_slicing_disjoint_and_shaped(self):
        d = SyntheticC4(DataConfig(vocab_size=100, seq_len=8, global_batch=8))
        b0 = d.batch(3, host_id=0, num_hosts=4)
        b1 = d.batch(3, host_id=1, num_hosts=4)
        assert b0["tokens"].shape == (2, 8)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_learnable_structure(self):
        """Markov structure => bigram entropy < unigram entropy."""
        d = SyntheticC4(DataConfig(vocab_size=50, seq_len=512, global_batch=8))
        toks = d.batch(0)["tokens"].ravel()
        # successor agreement: P(pair repeats) far above uniform
        pairs = set(zip(toks[:-1].tolist(), toks[1:].tolist()))
        assert len(pairs) < 0.8 * (len(toks) - 1)


class TestAdam:
    def test_moves_toward_minimum(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_adam(params)
        cfg = AdamConfig(lr=0.1, grad_clip=0.0)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state = adam_update(g, state, params, cfg)
        np.testing.assert_allclose(params["w"], [0, 0], atol=1e-2)

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        state = init_adam(params)
        cfg = AdamConfig(lr=1.0, grad_clip=1.0)
        g = {"w": jnp.array([1e6, 0.0, 0.0])}
        new, _ = adam_update(g, state, params, cfg)
        assert float(jnp.abs(new["w"]).max()) < 10.0

    def test_moments_are_f32_for_bf16_params(self):
        params = {"w": jnp.zeros(3, jnp.bfloat16)}
        st = init_adam(params)
        assert st.mu["w"].dtype == jnp.float32

    def test_schedule_shape(self):
        s0 = float(warmup_cosine(0, warmup=10, total=100))
        s_mid = float(warmup_cosine(10, warmup=10, total=100))
        s_end = float(warmup_cosine(100, warmup=10, total=100))
        assert s0 == 0.0 and s_mid == pytest.approx(1.0) and s_end == pytest.approx(0.1)


@pytest.fixture()
def tiny_state():
    cfg = get_arch("salaad_llama_60m").reduced()
    tcfg = TrainerConfig(
        total_steps=4,
        salaad=SalaadConfig(
            selection=SelectionConfig(min_dim=16), rho_constant=5.0,
            update_every=2, exact_svd=True,
        ),
        log_every=1,
    )
    tr = Trainer(cfg, tcfg)
    state = tr.init(jax.random.PRNGKey(0))
    data = SyntheticC4(DataConfig(cfg.vocab_size, 16, 4))
    return cfg, tr, state, data


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tiny_state, tmp_path):
        cfg, tr, state, data = tiny_state
        state = tr.fit(state, data, steps=2)
        path = checkpoint.save(str(tmp_path), 2, state)
        assert os.path.isdir(path)
        restored = checkpoint.restore(str(tmp_path), state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2
            )

    def test_latest_and_gc(self, tiny_state, tmp_path):
        cfg, tr, state, data = tiny_state
        for s in (1, 2, 3, 4, 5):
            checkpoint.save(str(tmp_path), s, {"x": jnp.ones(3) * s}, keep=2)
        assert checkpoint.latest_step(str(tmp_path)) == 5
        assert sorted(checkpoint.all_steps(str(tmp_path))) == [4, 5]

    def test_crash_safety_partial_write_ignored(self, tmp_path):
        """A temp dir left by a crashed writer is invisible to restore."""
        checkpoint.save(str(tmp_path), 1, {"x": jnp.ones(2)})
        os.makedirs(tmp_path / ".tmp.2.999", exist_ok=True)
        (tmp_path / ".tmp.2.999" / "arrays.npz").write_bytes(b"garbage")
        assert checkpoint.latest_step(str(tmp_path)) == 1
        restored = checkpoint.restore(str(tmp_path), {"x": jnp.zeros(2)})
        np.testing.assert_array_equal(restored["x"], [1, 1])

    def test_restart_replays_identically(self, tiny_state, tmp_path):
        """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
        cfg, tr, state, data = tiny_state
        s_full = tr.fit(state, data, steps=4)

        tr2 = Trainer(cfg, tr.tcfg)
        s2 = tr2.init(jax.random.PRNGKey(0))
        s2 = tr2.fit(s2, data, steps=2)
        checkpoint.save(str(tmp_path), 2, s2)
        s3 = checkpoint.restore(str(tmp_path), s2)
        s3 = tr2.fit(s3, data, steps=4)  # resumes at step 2
        np.testing.assert_allclose(
            np.asarray(s_full.params["embed"]["embedding"]),
            np.asarray(s3.params["embed"]["embedding"]),
            atol=1e-5,
        )

    def test_dtype_cast_on_restore(self, tmp_path):
        checkpoint.save(str(tmp_path), 1, {"x": jnp.ones(3, jnp.bfloat16)})
        out = checkpoint.restore(str(tmp_path), {"x": jnp.zeros(3, jnp.bfloat16)})
        assert out["x"].dtype == jnp.bfloat16


class TestFault:
    def test_straggler_detection(self):
        det = StragglerDetector(threshold=2.0, evict_after=3)
        for _ in range(10):
            det.update(1.0)
        assert det.update(5.0) is True
        assert not det.should_evict
        det.update(5.0)
        det.update(5.0)
        assert det.should_evict

    def test_straggler_warmup_tolerates_compile(self):
        det = StragglerDetector()
        assert det.update(100.0) is False  # first (compile) step

    def test_watchdog(self):
        with Watchdog(0.05) as wd:
            time.sleep(0.15)
        assert wd.expired
        with Watchdog(5.0) as wd:
            pass
        assert not wd.expired

    def test_retry_policy(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "ok"

        assert RetryPolicy(max_retries=3, backoff_s=0.01).run(flaky) == "ok"
        assert len(calls) == 3

    def test_retry_gives_up_on_permanent(self):
        def perm():
            raise ValueError("permanent")

        with pytest.raises(ValueError):
            RetryPolicy(max_retries=2, backoff_s=0.01).run(
                perm, is_transient=lambda e: isinstance(e, OSError)
            )


class TestGradCompression:
    def test_quantize_roundtrip_error_bound(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, scale = quantize_int8(g)
        err = jnp.abs(dequantize_int8(q, scale) - g)
        assert float(err.max()) <= float(scale) * 0.5 + 1e-6

    def test_compressed_psum_matches_mean(self):
        """int8 all-reduce mean within quantization error of the exact mean."""
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        n = mesh.shape["data"]
        g = jax.random.normal(jax.random.PRNGKey(1), (n, 64))

        fn = shard_map(
            lambda x: compressed_psum_tree({"g": x[0]}, "data")["g"][None],
            mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False,
        )
        out = fn(g)  # (n, 64): each shard returns the reduced mean
        exact = jnp.mean(g, axis=0)
        scale = float(jnp.abs(g).max()) / 127
        np.testing.assert_allclose(out[0], exact, atol=2 * scale)

    def test_error_feedback_reduces_bias(self):
        mesh = jax.make_mesh((1,), ("data",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        g = {"w": jnp.full((64,), 0.003)}  # small constant grad: EF must not lose it
        r = {"w": jnp.zeros((64,))}

        def step(gv, rv):
            return compressed_psum_tree({"w": gv}, "data", {"w": rv})

        fn = shard_map(
            lambda gv, rv: step(gv[0], rv[0]),
            mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P("data"),
            check_rep=False,
        )
        total = jnp.zeros(64)
        gg, rr = g["w"][None], r["w"][None]
        for _ in range(10):
            out, new_r = fn(gg, rr)
            total = total + out["w"][0]
            rr = new_r["w"][None] if isinstance(new_r, dict) else new_r
        # accumulated EF output ~ 10 * g despite each step quantizing hard
        np.testing.assert_allclose(total, 0.03 * jnp.ones(64), rtol=0.2)


class TestServingEngine:
    def test_batch_serving_completes(self):
        from repro.serving.elastic import ModelBank
        from repro.serving.engine import EngineConfig, ServingEngine

        cfg = get_arch("olmo_1b").reduced()
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32))
        uids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(5)]
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.out_tokens) == 4 for r in done)

    def test_engine_matches_direct_decode(self):
        """Engine output == greedy decode with the plain model API."""
        from repro.serving.elastic import ModelBank
        from repro.serving.engine import EngineConfig, ServingEngine

        cfg = get_arch("olmo_1b").reduced()
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        prompt = [5, 7, 11]
        eng = ServingEngine(ModelBank.single(cfg, params), EngineConfig(max_slots=2, max_len=32))
        eng.submit(prompt, max_new_tokens=3)
        out = eng.run()[0].out_tokens

        # reference: same per-token decode path with a private batch-1 cache
        # (tests slot isolation / cache bookkeeping in the engine)
        cache = model_lib.init_cache(cfg, 1, 32, dtype=jnp.float32)
        tok = None
        ref = []
        for t in prompt:
            lg, cache = model_lib.decode_step(
                params, jnp.asarray([[t]], jnp.int32), cache, cfg
            )
        tok = int(jnp.argmax(lg[0, -1]))
        ref.append(tok)
        for _ in range(2):
            lg, cache = model_lib.decode_step(
                params, jnp.asarray([[tok]], jnp.int32), cache, cfg
            )
            tok = int(jnp.argmax(lg[0, -1]))
            ref.append(tok)
        assert out == ref
