"""Multi-device SPMD tests, run in subprocesses with forced host devices
(XLA_FLAGS must be set before jax init, and the main test process must keep
seeing 1 device — hence subprocess isolation).

Covers: sharded train step == single-device train step, elastic checkpoint
restore across device counts (8 -> 4), and MoE expert-parallel equivalence.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int, timeout=600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    line = [l for l in out.stdout.strip().splitlines() if l.startswith("{")][-1]
    return json.loads(line)


COMMON = textwrap.dedent(
    """
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_arch
    from repro.core.admm import SalaadConfig
    from repro.core.selection import SelectionConfig, select_blocks
    from repro.data.synthetic import SyntheticC4, DataConfig
    from repro.models import model as model_lib
    from repro.optim.adam import AdamConfig
    from repro.parallel.sharding import param_sharding_tree
    from repro.train.state import init_train_state
    from repro.train.steps import make_train_step

    def build(arch="olmo_1b", salaad=True):
        cfg = get_arch(arch).reduced()
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        scfg = SalaadConfig(selection=SelectionConfig(min_dim=16), rho_constant=5.0,
                            update_every=2, exact_svd=True) if salaad else None
        state, blocks = init_train_state(params, scfg)
        step = make_train_step(cfg, blocks, AdamConfig())
        data = SyntheticC4(DataConfig(cfg.vocab_size, 16, 8))
        return cfg, state, step, data, blocks
    """
)


class TestShardedTraining:
    def test_sharded_matches_single_device(self):
        """3 train steps on a 4x2 mesh == 3 steps on 1 device (same math)."""
        prog = COMMON + textwrap.dedent(
            """
            cfg, state, step, data, blocks = build()
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            pshard = param_sharding_tree(state.params, mesh)
            with mesh:
                jstep = jax.jit(step)
                for s in range(3):
                    state, metrics = jstep(state, data.batch(s))
            print(json.dumps({
                "loss": float(metrics["loss"]),
                "w0": float(jnp.sum(state.params["embed"]["embedding"].astype(jnp.float32))),
            }))
            """
        )
        multi = run_py(prog, devices=8)
        single = run_py(prog.replace('jax.make_mesh((4, 2), ("data", "model"))',
                                     'jax.make_mesh((1, 1), ("data", "model"))'),
                        devices=8)
        assert abs(multi["loss"] - single["loss"]) < 2e-3
        assert abs(multi["w0"] - single["w0"]) / (abs(single["w0"]) + 1e-9) < 1e-3

    def test_explicit_shardings_train(self):
        """Train with explicit in_shardings (the dry-run configuration) and
        verify loss decreases and stays finite."""
        prog = COMMON + textwrap.dedent(
            """
            from repro.launch.dryrun import batch_shardings, slr_shardings
            cfg, state, step, data, blocks = build()
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            pshard = param_sharding_tree(state.params, mesh)
            state = jax.device_put(state, state._replace(
                params=pshard,
                opt=state.opt._replace(mu=pshard, nu=pshard,
                                       count=NamedSharding(mesh, P())),
                slr=slr_shardings(state.slr, pshard, mesh),
                step=NamedSharding(mesh, P()),
            ))
            losses = []
            with mesh:
                jstep = jax.jit(step, donate_argnums=(0,))
                for s in range(8):
                    state, metrics = jstep(state, data.batch(s))
                    losses.append(float(metrics["loss"]))
            print(json.dumps({"first": losses[0], "last": losses[-1]}))
            """
        )
        out = run_py(prog, devices=8)
        # warmup keeps lr tiny for the first 100 steps and each step sees a
        # fresh batch, so require stability (finite, no divergence) — strict
        # decrease past warmup is covered by the trainer tests
        import math

        assert math.isfinite(out["last"])
        assert out["last"] < out["first"] + 1.0

    def test_moe_expert_parallel_equivalence(self):
        """MoE forward on a model-sharded mesh == single device (dropless)."""
        prog = COMMON + textwrap.dedent(
            """
            cfg, state, step, data, blocks = build("dbrx_132b", salaad=False)
            batch = data.batch(0)
            batch = {k: v[:4] for k, v in batch.items()}
            loss_single, _ = model_lib.loss_fn(state.params, batch, cfg)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            with mesh:
                loss_sharded, _ = jax.jit(
                    lambda p, b: model_lib.loss_fn(p, b, cfg)
                )(state.params, batch)
            print(json.dumps({"single": float(loss_single), "sharded": float(loss_sharded)}))
            """
        )
        out = run_py(prog, devices=8)
        # capacity semantics differ slightly (per-shard vs global), so allow
        # a small tolerance; gross divergence would mean broken EP routing
        assert abs(out["single"] - out["sharded"]) < 0.05 * abs(out["single"])


class TestElasticRestore:
    def test_reshard_8_to_4(self):
        """Save on an (4,2) mesh, restore and continue on (2,2): elastic."""
        import tempfile

        ckpt = tempfile.mkdtemp()
        save_prog = COMMON + textwrap.dedent(
            f"""
            from repro.train import checkpoint
            cfg, state, step, data, blocks = build()
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            with mesh:
                jstep = jax.jit(step)
                for s in range(2):
                    state, m = jstep(state, data.batch(s))
            checkpoint.save({ckpt!r}, 2, state)
            print(json.dumps({{"loss": float(m["loss"])}}))
            """
        )
        run_py(save_prog, devices=8)

        restore_prog = COMMON + textwrap.dedent(
            f"""
            from repro.train import checkpoint
            from repro.launch.dryrun import slr_shardings
            cfg, state, step, data, blocks = build()
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            pshard = param_sharding_tree(state.params, mesh)
            shardings = state._replace(
                params=pshard,
                opt=state.opt._replace(mu=pshard, nu=pshard,
                                       count=NamedSharding(mesh, P())),
                slr=slr_shardings(state.slr, pshard, mesh),
                step=NamedSharding(mesh, P()),
            )
            state = checkpoint.restore({ckpt!r}, state, shardings=shardings)
            assert int(state.step) == 2
            with mesh:
                jstep = jax.jit(step)
                state, m = jstep(state, data.batch(2))
            print(json.dumps({{"loss": float(m["loss"]), "step": int(state.step)}}))
            """
        )
        out = run_py(restore_prog, devices=4)
        assert out["step"] == 3
        assert out["loss"] < 10.0  # finite, sane


class TestMultiPodMesh:
    def test_pod_axis_folds_into_data(self):
        """Batch sharded over (pod, data): one forward on the 3-axis mesh."""
        prog = COMMON + textwrap.dedent(
            """
            cfg, state, step, data, blocks = build(salaad=False)
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            with mesh:
                jstep = jax.jit(step)
                state, m = jstep(state, data.batch(0))
            print(json.dumps({"loss": float(m["loss"])}))
            """
        )
        out = run_py(prog, devices=8)
        assert out["loss"] < 10.0
