"""End-to-end behaviour tests for the full SALAAD system:
train (Alg. 1) -> checkpoint -> restore -> HPA compress -> deploy -> serve,
and the paper's headline qualitative claims at smoke scale.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.admm import SalaadConfig, slr_param_count, surrogate_params
from repro.core.hpa import hpa_keep_ratio
from repro.core.selection import SelectionConfig
from repro.data.synthetic import DataConfig, SyntheticC4
from repro.models import model as model_lib
from repro.optim.adam import AdamConfig
from repro.serving.elastic import ModelBank
from repro.serving.engine import EngineConfig, ServingEngine
from repro.train import checkpoint
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def pipeline(tmp_path_factory):
    """One full training run shared by the system tests."""
    cfg = get_arch("salaad_llama_60m").reduced()
    salaad = SalaadConfig(
        selection=SelectionConfig(min_dim=16), rho_constant=0.5,
        update_every=5, exact_svd=True,
    )
    ckpt_dir = str(tmp_path_factory.mktemp("ckpt"))
    tcfg = TrainerConfig(
        total_steps=30, salaad=salaad, adam=AdamConfig(lr=1e-3),
        ckpt_dir=ckpt_dir, ckpt_every=10, log_every=5,
    )
    trainer = Trainer(cfg, tcfg)
    state = trainer.init(jax.random.PRNGKey(0))
    data = SyntheticC4(DataConfig(cfg.vocab_size, 32, 8))
    state = trainer.fit(state, data)
    return cfg, trainer, state, data, ckpt_dir


def eval_loss(params, cfg, data):
    return float(model_lib.loss_fn(params, data.batch(9999), cfg)[0])


class TestEndToEnd:
    def test_training_reduces_loss(self, pipeline):
        cfg, trainer, state, data, _ = pipeline
        losses = [m["loss"] for m in trainer.metrics_log if "loss" in m]
        assert losses[-1] < losses[0]

    def test_admm_reconstruction_bounded_and_shrinking(self, pipeline):
        """Paper App. F: ||X - L - S||_F stays bounded and decreases."""
        cfg, trainer, state, data, _ = pipeline
        recon = [m["admm_recon_err"] for m in trainer.metrics_log if "admm_recon_err" in m]
        assert len(recon) >= 3
        assert recon[-1] <= recon[0]
        assert all(np.isfinite(r) for r in recon)

    def test_surrogate_quality_close_to_dense(self, pipeline):
        """Paper Table 1: L+S within a reasonable margin of X."""
        cfg, trainer, state, data, _ = pipeline
        lx = eval_loss(state.params, cfg, data)
        ls = eval_loss(trainer.surrogate(state), cfg, data)
        assert ls < lx + 0.5

    def test_elastic_budgets_degrade_smoothly(self, pipeline):
        """Paper Fig. 3: loss is monotone-ish (no collapse) across budgets."""
        cfg, trainer, state, data, _ = pipeline
        losses = []
        for keep in (1.0, 0.7, 0.4):
            slr_c, _ = hpa_keep_ratio(state.slr, trainer.blocks, keep, kappa=0.7)
            params_c = surrogate_params(state.params, slr_c, trainer.blocks)
            losses.append(eval_loss(params_c, cfg, data))
        assert losses[2] < losses[0] + 2.0  # graceful, not collapsed
        assert all(np.isfinite(l) for l in losses)

    def test_checkpoint_restore_and_continue(self, pipeline):
        cfg, trainer, state, data, ckpt_dir = pipeline
        assert checkpoint.latest_step(ckpt_dir) == 30
        restored = checkpoint.restore(ckpt_dir, state)
        assert int(restored.step) == 30
        state2 = trainer.fit(restored, data, steps=32)  # two more steps
        assert int(state2.step) == 32

    def test_compressed_model_serves(self, pipeline):
        cfg, trainer, state, data, _ = pipeline
        slr_c, _ = hpa_keep_ratio(state.slr, trainer.blocks, 0.6, kappa=0.7)
        deploy = surrogate_params(state.params, slr_c, trainer.blocks)
        engine = ServingEngine(ModelBank.single(cfg, deploy),
                               EngineConfig(max_slots=2, max_len=48))
        engine.submit([1, 2, 3], max_new_tokens=4)
        engine.submit([4, 5], max_new_tokens=4)
        done = engine.run()
        assert len(done) == 2 and all(len(r.out_tokens) == 4 for r in done)

    def test_param_accounting_consistent(self, pipeline):
        cfg, trainer, state, data, _ = pipeline
        counts = slr_param_count(state.slr, trainer.blocks)
        assert counts["_total"] > 0
        slr_c, rep = hpa_keep_ratio(state.slr, trainer.blocks, 0.5, kappa=0.7)
        counts_c = slr_param_count(slr_c, trainer.blocks)
        assert counts_c["_total"] == rep["params_after"]
